//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The fedlite `pjrt` feature compiles against this crate so the PJRT
//! runtime path type-checks and links without the XLA C++ toolchain.
//! `Literal` is implemented for real (host-side arrays round-trip, so the
//! conversion layer stays testable); everything that would need a real
//! PJRT client — `PjRtClient::cpu()`, compilation, execution — returns an
//! actionable [`Error`] instead. To execute AOT artifacts, replace this
//! path dependency with the real xla-rs bindings (see the repo README).

use std::fmt;

/// Error type mirroring xla-rs: stringly, `Display`-able, `?`-compatible.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build (the vendored `xla` stub \
         is linked); swap rust/vendor/xla for the real xla-rs bindings to \
         execute AOT artifacts, or run the native engine instead"
    ))
}

/// Element types the fedlite artifacts use (plus common extras so callers
/// can match non-exhaustively without dead arms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Scalar types that can cross the host <-> literal boundary.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;

    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal::F32 { dims, data }
    }

    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;

    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal::S32 { dims, data }
    }

    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::S32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not s32: {other:?}"))),
        }
    }
}

/// Shape of a dense (non-tuple) literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    element_type: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.element_type
    }
}

/// Host-side literal. Fully functional (no PJRT needed).
#[derive(Debug, Clone)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    S32 { dims: Vec<i64>, data: Vec<i32> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        T::wrap(Vec::new(), vec![v])
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::wrap(vec![data.len() as i64], data.to_vec())
    }

    /// Reinterpret with new dimensions of equal element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let out = match self {
            Literal::F32 { data, .. } => {
                if data.len() as i64 != n {
                    return Err(Error(format!(
                        "reshape {:?} to {dims:?}: element count mismatch",
                        data.len()
                    )));
                }
                Literal::F32 { dims: dims.to_vec(), data: data.clone() }
            }
            Literal::S32 { data, .. } => {
                if data.len() as i64 != n {
                    return Err(Error(format!(
                        "reshape {:?} to {dims:?}: element count mismatch",
                        data.len()
                    )));
                }
                Literal::S32 { dims: dims.to_vec(), data: data.clone() }
            }
            Literal::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        };
        Ok(out)
    }

    /// Dense shape; errors on tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::F32 { dims, .. } => Ok(ArrayShape {
                dims: dims.clone(),
                element_type: ElementType::F32,
            }),
            Literal::S32 { dims, .. } => Ok(ArrayShape {
                dims: dims.clone(),
                element_type: ElementType::S32,
            }),
            Literal::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
        }
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Unwrap a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            other => Err(Error(format!("literal is not a tuple: {other:?}"))),
        }
    }
}

/// Parsed HLO module (text retained; nothing can compile it here).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle wrapping a parsed module.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Device handle (never constructed by the stub).
pub struct PjRtDevice;

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client. The stub cannot create one — `cpu()` fails with a message
/// pointing at the real bindings.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.element_type(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
        let t = Literal::Tuple(vec![s.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.array_shape().is_err());
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
