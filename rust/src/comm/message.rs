//! Binary wire format for every message class in the protocol.
//!
//! Little-endian framing: `magic u32 | type u8 | round u32 | client u32 |
//! body`. Floats travel as raw f32; PQ codewords as the bit-packed stream
//! of [`crate::quantizer::packing`]. Encode/decode round-trips are tested
//! for every variant — the byte length of `encode()` is the number that
//! feeds the communication meters.
//!
//! Decoding is hardened against adversarial frames: every declared
//! element count is capped against the bytes actually remaining in the
//! buffer *before* any allocation sized from it, so a corrupt or
//! malicious length field can never trigger a huge `Vec` pre-allocation.
//! This matters once frames arrive over real sockets
//! ([`crate::comm::transport`]) instead of in-process buffers.

use crate::quantizer::packing;
use crate::quantizer::pq::PqConfig;
use crate::tensor::{Tensor, TensorList};

const MAGIC: u32 = 0xFED1_17E0;

/// Protocol messages (paper §3 steps + FedLite's quantized upload).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// SplitFed step 1: raw activations + labels payload descriptor.
    ActivationUpload { z: Vec<f32>, b: usize, d: usize },
    /// FedLite step 1: codebooks + bit-packed codewords.
    QuantizedUpload {
        q: usize,
        r: usize,
        l: usize,
        b: usize,
        d: usize,
        codebooks: Vec<f32>,
        packed_codes: Vec<u8>,
        /// Number of codes per group (Ng), needed to unpack.
        ng: usize,
    },
    /// Server -> client: gradient w.r.t. (quantized) activations.
    GradDownload { grad: Vec<f32>, b: usize, d: usize },
    /// Client -> server: client-side model gradients (sync step).
    ClientGrads { grads: Vec<Vec<f32>> },
    /// Server -> client: client-side model broadcast.
    ModelBroadcast { params: Vec<Vec<f32>> },
}

impl Message {
    /// Build a quantized upload from a PQ result.
    pub fn from_pq(
        cfg: &PqConfig,
        b: usize,
        d: usize,
        codebooks: &[f32],
        codes: &[u32],
    ) -> Message {
        let ng = cfg.group_size(b);
        assert_eq!(codes.len(), cfg.r * ng);
        Message::QuantizedUpload {
            q: cfg.q,
            r: cfg.r,
            l: cfg.l,
            b,
            d,
            codebooks: codebooks.to_vec(),
            packed_codes: packing::pack(codes, cfg.l),
            ng,
        }
    }

    /// Unpack the codewords of a quantized upload.
    pub fn unpack_codes(&self) -> anyhow::Result<Vec<u32>> {
        match self {
            Message::QuantizedUpload { r, l, packed_codes, ng, .. } => {
                packing::unpack(packed_codes, r * ng, *l)
            }
            _ => anyhow::bail!("not a quantized upload"),
        }
    }

    /// Coordinator-side codeword validation against the PQ geometry (the
    /// byzantine defense): the packed stream must be *exactly*
    /// `packed_len(r·ng, l)` bytes — the wire codec itself only requires
    /// a lower bound — and every unpacked code must index a real centroid
    /// (`< 2^bits_per_code(l)`, checked by `unpack`). Honest uploads
    /// always pass (pure integer checks, no RNG), so running the defense
    /// unconditionally changes no honest bits. Non-quantized messages
    /// pass vacuously.
    pub fn validate_codewords(&self) -> anyhow::Result<()> {
        if let Message::QuantizedUpload { r, l, ng, packed_codes, .. } = self {
            let need = packing::packed_len(r * ng, *l);
            anyhow::ensure!(
                packed_codes.len() == need,
                "codeword stream length {} != packed length {need}",
                packed_codes.len()
            );
            self.unpack_codes().map(|_| ())
        } else {
            Ok(())
        }
    }

    fn type_id(&self) -> u8 {
        match self {
            Message::ActivationUpload { .. } => 1,
            Message::QuantizedUpload { .. } => 2,
            Message::GradDownload { .. } => 3,
            Message::ClientGrads { .. } => 4,
            Message::ModelBroadcast { .. } => 5,
        }
    }

    /// Serialize to wire bytes.
    pub fn encode(&self, round: u32, client: u32) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        self.encode_into(round, client, &mut out);
        out
    }

    /// Serialize into a caller-owned buffer (cleared first). Produces
    /// byte-for-byte the same output as [`Message::encode`]; the hot
    /// transfer path ([`crate::comm::Link`]) uses this with a reused
    /// scratch buffer so steady-state sends perform no allocation.
    pub fn encode_into(&self, round: u32, client: u32, out: &mut Vec<u8>) {
        out.clear();
        let mut w = Writer::new(out);
        w.u32(MAGIC);
        w.u8(self.type_id());
        w.u32(round);
        w.u32(client);
        match self {
            Message::ActivationUpload { z, b, d } => {
                w.u32(*b as u32);
                w.u32(*d as u32);
                w.f32s(z);
            }
            Message::QuantizedUpload { q, r, l, b, d, codebooks, packed_codes, ng } => {
                for v in [*q, *r, *l, *b, *d, *ng] {
                    w.u32(v as u32);
                }
                w.f32s(codebooks);
                w.bytes(packed_codes);
            }
            Message::GradDownload { grad, b, d } => {
                w.u32(*b as u32);
                w.u32(*d as u32);
                w.f32s(grad);
            }
            Message::ClientGrads { grads } => {
                w.f32_lists(grads);
            }
            Message::ModelBroadcast { params } => {
                w.f32_lists(params);
            }
        }
    }

    /// Deserialize; returns `(message, round, client)`.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<(Message, u32, u32)> {
        let mut r = Reader::new(bytes);
        anyhow::ensure!(r.u32()? == MAGIC, "bad magic");
        let ty = r.u8()?;
        let round = r.u32()?;
        let client = r.u32()?;
        let msg = match ty {
            1 => {
                let b = r.u32()? as usize;
                let d = r.u32()? as usize;
                Message::ActivationUpload { z: r.f32s()?, b, d }
            }
            2 => {
                let q = r.u32()? as usize;
                let rr = r.u32()? as usize;
                let l = r.u32()? as usize;
                let b = r.u32()? as usize;
                let d = r.u32()? as usize;
                let ng = r.u32()? as usize;
                Message::QuantizedUpload {
                    q,
                    r: rr,
                    l,
                    b,
                    d,
                    ng,
                    codebooks: r.f32s()?,
                    packed_codes: r.bytes()?,
                }
            }
            3 => {
                let b = r.u32()? as usize;
                let d = r.u32()? as usize;
                Message::GradDownload { grad: r.f32s()?, b, d }
            }
            4 => Message::ClientGrads { grads: r.f32_lists()? },
            5 => Message::ModelBroadcast { params: r.f32_lists()? },
            t => anyhow::bail!("unknown message type {t}"),
        };
        anyhow::ensure!(r.at_end(), "trailing bytes in message");
        Ok((msg, round, client))
    }

    /// Wire size in bytes (without re-encoding twice in hot paths, callers
    /// may cache; this is exact).
    pub fn wire_len(&self) -> usize {
        // header 13 bytes
        13 + match self {
            Message::ActivationUpload { z, .. } => 8 + 4 + z.len() * 4,
            Message::QuantizedUpload { codebooks, packed_codes, .. } => {
                24 + 4 + codebooks.len() * 4 + 4 + packed_codes.len()
            }
            Message::GradDownload { grad, .. } => 8 + 4 + grad.len() * 4,
            Message::ClientGrads { grads } => {
                4 + grads.iter().map(|g| 4 + g.len() * 4).sum::<usize>()
            }
            Message::ModelBroadcast { params } => {
                4 + params.iter().map(|p| 4 + p.len() * 4).sum::<usize>()
            }
        }
    }
}

/// Helper: tensor list -> plain vec-of-vecs for ClientGrads/ModelBroadcast.
pub fn tensors_to_payload(tl: &TensorList) -> Vec<Vec<f32>> {
    tl.tensors.iter().map(|t| t.data().to_vec()).collect()
}

/// Helper: payload -> tensors with provided shapes.
pub fn payload_to_tensors(
    payload: &[Vec<f32>],
    shapes: &[Vec<usize>],
    names: &[String],
) -> TensorList {
    assert_eq!(payload.len(), shapes.len());
    let tensors = payload
        .iter()
        .zip(shapes)
        .map(|(p, s)| Tensor::from_vec(s, p.clone()))
        .collect();
    TensorList::new(names.to_vec(), tensors)
}

/// Little-endian wire writer over a caller-owned buffer. Shared with the
/// socket transport layer ([`crate::comm::transport`]) so control frames
/// and protocol messages use one codec.
pub(crate) struct Writer<'a> {
    out: &'a mut Vec<u8>,
}

impl<'a> Writer<'a> {
    pub(crate) fn new(out: &'a mut Vec<u8>) -> Self {
        Writer { out }
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.out.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    /// f64 as its IEEE-754 bit pattern — the round-trip is bit-exact, so
    /// losses/weights computed remotely reduce to the same bits as local.
    pub(crate) fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub(crate) fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.out.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub(crate) fn f64s(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f64(*x);
        }
    }

    pub(crate) fn f32_lists(&mut self, lists: &[Vec<f32>]) {
        self.u32(lists.len() as u32);
        for l in lists {
            self.f32s(l);
        }
    }

    pub(crate) fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.out.extend_from_slice(v);
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }
}

/// Bounds-checked little-endian reader. Every length-prefixed read caps
/// the declared count against the bytes remaining *before* allocating.
pub(crate) struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Self {
        Reader { b, i: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(n <= self.remaining(), "message truncated");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    /// Read a declared element count, rejecting counts that could not
    /// possibly fit in the remaining buffer at `min_elem_bytes` each.
    /// This runs before any count-sized allocation.
    fn count(&mut self, min_elem_bytes: usize) -> anyhow::Result<usize> {
        let n = self.u32()? as usize;
        anyhow::ensure!(
            n <= self.remaining() / min_elem_bytes,
            "declared count {n} exceeds remaining {} bytes",
            self.remaining()
        );
        Ok(n)
    }

    pub(crate) fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.count(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub(crate) fn f64s(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    /// A list of f32 vectors (each inner vector needs at least its own
    /// 4-byte length on the wire, so the outer count is capped at
    /// `remaining / 4`).
    pub(crate) fn f32_lists(&mut self) -> anyhow::Result<Vec<Vec<f32>>> {
        let n = self.count(4)?;
        (0..n).map(|_| self.f32s()).collect()
    }

    pub(crate) fn bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub(crate) fn str(&mut self) -> anyhow::Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b).map_err(|_| anyhow::anyhow!("invalid utf-8 string"))
    }

    pub(crate) fn at_end(&self) -> bool {
        self.i == self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantizer::{GroupedPq, PqConfig};
    use crate::util::rng::Rng;

    fn roundtrip(m: Message) {
        let bytes = m.encode(7, 3);
        assert_eq!(bytes.len(), m.wire_len(), "wire_len mismatch");
        let (back, round, client) = Message::decode(&bytes).unwrap();
        assert_eq!(back, m);
        assert_eq!((round, client), (7, 3));
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Message::ActivationUpload { z: vec![1.0, -2.5, 3.0], b: 1, d: 3 });
        roundtrip(Message::GradDownload { grad: vec![0.5; 10], b: 2, d: 5 });
        roundtrip(Message::ClientGrads { grads: vec![vec![1.0, 2.0], vec![3.0]] });
        roundtrip(Message::ModelBroadcast { params: vec![vec![]; 2] });
        roundtrip(Message::QuantizedUpload {
            q: 4,
            r: 2,
            l: 3,
            b: 5,
            d: 8,
            ng: 10,
            codebooks: vec![0.25; 12],
            packed_codes: vec![0xAB, 0xCD, 0x01],
        });
    }

    #[test]
    fn encode_into_matches_encode() {
        let m = Message::ClientGrads { grads: vec![vec![1.5, -2.0], vec![], vec![9.0]] };
        let mut buf = vec![0xFFu8; 3]; // stale contents must be cleared
        m.encode_into(4, 9, &mut buf);
        assert_eq!(buf, m.encode(4, 9));
    }

    #[test]
    fn pq_message_roundtrips_codes() {
        let mut rng = Rng::new(0);
        let (b, d) = (6, 16);
        let z: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let cfg = PqConfig::new(4, 2, 3);
        let pq = GroupedPq::new(cfg, d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let msg = Message::from_pq(&cfg, b, d, &out.codebooks, &out.codes);
        let bytes = msg.encode(0, 0);
        let (decoded, _, _) = Message::decode(&bytes).unwrap();
        let codes = decoded.unpack_codes().unwrap();
        assert_eq!(codes, out.codes);
        // server can reconstruct identical z_tilde from the wire content
        if let Message::QuantizedUpload { codebooks, .. } = &decoded {
            let rec = pq.reconstruct(codebooks, &codes, b);
            assert_eq!(rec, out.z_tilde);
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn quantized_much_smaller_than_raw() {
        let mut rng = Rng::new(1);
        let (b, d) = (20, 9216);
        let z: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let raw = Message::ActivationUpload { z: z.clone(), b, d };
        let cfg = PqConfig::new(1152, 1, 2).with_iters(1);
        let pq = GroupedPq::new(cfg, d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let msg = Message::from_pq(&cfg, b, d, &out.codebooks, &out.codes);
        let ratio = raw.wire_len() as f64 / msg.wire_len() as f64;
        // f32 wire: codebook 2*8*4B + codes 23040 bits -> ~250x
        assert!(ratio > 200.0, "wire ratio only {ratio:.1}");
    }

    #[test]
    fn decode_rejects_corruption() {
        let m = Message::GradDownload { grad: vec![1.0; 4], b: 1, d: 4 };
        let mut bytes = m.encode(0, 0);
        bytes[0] ^= 0xFF; // magic
        assert!(Message::decode(&bytes).is_err());
        let bytes = m.encode(0, 0);
        assert!(Message::decode(&bytes[..bytes.len() - 2]).is_err());
        let mut bytes2 = m.encode(0, 0);
        bytes2.push(0); // trailing
        assert!(Message::decode(&bytes2).is_err());
    }

    /// A frame cut off inside the 13-byte header must error, not panic.
    #[test]
    fn decode_rejects_truncated_header() {
        let bytes = Message::ModelBroadcast { params: vec![vec![1.0]] }.encode(0, 0);
        for cut in 0..13 {
            assert!(
                Message::decode(&bytes[..cut]).is_err(),
                "truncated header at {cut} bytes must be rejected"
            );
        }
    }

    /// An adversarial length field (u32::MAX elements declared in a short
    /// frame) must be rejected by the remaining-bytes cap before any
    /// count-sized allocation happens — for both the outer vec-of-vecs
    /// count and the inner f32 counts.
    #[test]
    fn decode_rejects_oversized_declared_lengths() {
        // outer count of ClientGrads / ModelBroadcast
        for ty in [4u8, 5u8] {
            let mut bytes = Vec::new();
            let mut w = Writer::new(&mut bytes);
            w.u32(MAGIC);
            w.u8(ty);
            w.u32(0);
            w.u32(0);
            w.u32(u32::MAX); // declares ~4G inner vectors in a 17-byte frame
            let err = Message::decode(&bytes).unwrap_err().to_string();
            assert!(err.contains("exceeds remaining"), "got: {err}");
        }
        // inner f32 count (GradDownload payload)
        let mut bytes = Vec::new();
        let mut w = Writer::new(&mut bytes);
        w.u32(MAGIC);
        w.u8(3);
        w.u32(0);
        w.u32(0);
        w.u32(1); // b
        w.u32(4); // d
        w.u32(u32::MAX); // declares ~4G floats with no payload bytes
        assert!(Message::decode(&bytes).is_err());
        // packed-codes byte count of QuantizedUpload
        let m = Message::QuantizedUpload {
            q: 1,
            r: 1,
            l: 2,
            b: 1,
            d: 4,
            ng: 1,
            codebooks: vec![0.0; 8],
            packed_codes: vec![0x01],
        };
        let mut bytes = m.encode(0, 0);
        let cb_end = bytes.len() - 1 - 4; // packed_codes = 1 byte + u32 len
        bytes[cb_end..cb_end + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::decode(&bytes).is_err());
    }

    /// Unknown type tags are rejected with the offending tag named.
    #[test]
    fn decode_rejects_bad_tag() {
        let m = Message::GradDownload { grad: vec![1.0; 2], b: 1, d: 2 };
        let mut bytes = m.encode(0, 0);
        bytes[4] = 99; // type byte lives right after the magic
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unknown message type 99"), "got: {err}");
    }
}
