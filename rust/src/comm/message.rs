//! Binary wire format for every message class in the protocol.
//!
//! Little-endian framing: `magic u32 | type u8 | round u32 | client u32 |
//! body`. Floats travel as raw f32; PQ codewords as the bit-packed stream
//! of [`crate::quantizer::packing`]. Encode/decode round-trips are tested
//! for every variant — the byte length of `encode()` is the number that
//! feeds the communication meters.

use crate::quantizer::packing;
use crate::quantizer::pq::PqConfig;
use crate::tensor::{Tensor, TensorList};

const MAGIC: u32 = 0xFED1_17E0;

/// Protocol messages (paper §3 steps + FedLite's quantized upload).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// SplitFed step 1: raw activations + labels payload descriptor.
    ActivationUpload { z: Vec<f32>, b: usize, d: usize },
    /// FedLite step 1: codebooks + bit-packed codewords.
    QuantizedUpload {
        q: usize,
        r: usize,
        l: usize,
        b: usize,
        d: usize,
        codebooks: Vec<f32>,
        packed_codes: Vec<u8>,
        /// Number of codes per group (Ng), needed to unpack.
        ng: usize,
    },
    /// Server -> client: gradient w.r.t. (quantized) activations.
    GradDownload { grad: Vec<f32>, b: usize, d: usize },
    /// Client -> server: client-side model gradients (sync step).
    ClientGrads { grads: Vec<Vec<f32>> },
    /// Server -> client: client-side model broadcast.
    ModelBroadcast { params: Vec<Vec<f32>> },
}

impl Message {
    /// Build a quantized upload from a PQ result.
    pub fn from_pq(
        cfg: &PqConfig,
        b: usize,
        d: usize,
        codebooks: &[f32],
        codes: &[u32],
    ) -> Message {
        let ng = cfg.group_size(b);
        assert_eq!(codes.len(), cfg.r * ng);
        Message::QuantizedUpload {
            q: cfg.q,
            r: cfg.r,
            l: cfg.l,
            b,
            d,
            codebooks: codebooks.to_vec(),
            packed_codes: packing::pack(codes, cfg.l),
            ng,
        }
    }

    /// Unpack the codewords of a quantized upload.
    pub fn unpack_codes(&self) -> anyhow::Result<Vec<u32>> {
        match self {
            Message::QuantizedUpload { r, l, packed_codes, ng, .. } => {
                packing::unpack(packed_codes, r * ng, *l)
            }
            _ => anyhow::bail!("not a quantized upload"),
        }
    }

    fn type_id(&self) -> u8 {
        match self {
            Message::ActivationUpload { .. } => 1,
            Message::QuantizedUpload { .. } => 2,
            Message::GradDownload { .. } => 3,
            Message::ClientGrads { .. } => 4,
            Message::ModelBroadcast { .. } => 5,
        }
    }

    /// Serialize to wire bytes.
    pub fn encode(&self, round: u32, client: u32) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u8(self.type_id());
        w.u32(round);
        w.u32(client);
        match self {
            Message::ActivationUpload { z, b, d } => {
                w.u32(*b as u32);
                w.u32(*d as u32);
                w.f32s(z);
            }
            Message::QuantizedUpload { q, r, l, b, d, codebooks, packed_codes, ng } => {
                for v in [*q, *r, *l, *b, *d, *ng] {
                    w.u32(v as u32);
                }
                w.f32s(codebooks);
                w.bytes(packed_codes);
            }
            Message::GradDownload { grad, b, d } => {
                w.u32(*b as u32);
                w.u32(*d as u32);
                w.f32s(grad);
            }
            Message::ClientGrads { grads } => {
                w.u32(grads.len() as u32);
                for g in grads {
                    w.f32s(g);
                }
            }
            Message::ModelBroadcast { params } => {
                w.u32(params.len() as u32);
                for p in params {
                    w.f32s(p);
                }
            }
        }
        w.out
    }

    /// Deserialize; returns `(message, round, client)`.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<(Message, u32, u32)> {
        let mut r = Reader::new(bytes);
        anyhow::ensure!(r.u32()? == MAGIC, "bad magic");
        let ty = r.u8()?;
        let round = r.u32()?;
        let client = r.u32()?;
        let msg = match ty {
            1 => {
                let b = r.u32()? as usize;
                let d = r.u32()? as usize;
                Message::ActivationUpload { z: r.f32s()?, b, d }
            }
            2 => {
                let q = r.u32()? as usize;
                let rr = r.u32()? as usize;
                let l = r.u32()? as usize;
                let b = r.u32()? as usize;
                let d = r.u32()? as usize;
                let ng = r.u32()? as usize;
                Message::QuantizedUpload {
                    q,
                    r: rr,
                    l,
                    b,
                    d,
                    ng,
                    codebooks: r.f32s()?,
                    packed_codes: r.bytes()?,
                }
            }
            3 => {
                let b = r.u32()? as usize;
                let d = r.u32()? as usize;
                Message::GradDownload { grad: r.f32s()?, b, d }
            }
            4 => {
                let n = r.u32()? as usize;
                let grads = (0..n).map(|_| r.f32s()).collect::<anyhow::Result<_>>()?;
                Message::ClientGrads { grads }
            }
            5 => {
                let n = r.u32()? as usize;
                let params = (0..n).map(|_| r.f32s()).collect::<anyhow::Result<_>>()?;
                Message::ModelBroadcast { params }
            }
            t => anyhow::bail!("unknown message type {t}"),
        };
        anyhow::ensure!(r.at_end(), "trailing bytes in message");
        Ok((msg, round, client))
    }

    /// Wire size in bytes (without re-encoding twice in hot paths, callers
    /// may cache; this is exact).
    pub fn wire_len(&self) -> usize {
        // header 13 bytes
        13 + match self {
            Message::ActivationUpload { z, .. } => 8 + 4 + z.len() * 4,
            Message::QuantizedUpload { codebooks, packed_codes, .. } => {
                24 + 4 + codebooks.len() * 4 + 4 + packed_codes.len()
            }
            Message::GradDownload { grad, .. } => 8 + 4 + grad.len() * 4,
            Message::ClientGrads { grads } => {
                4 + grads.iter().map(|g| 4 + g.len() * 4).sum::<usize>()
            }
            Message::ModelBroadcast { params } => {
                4 + params.iter().map(|p| 4 + p.len() * 4).sum::<usize>()
            }
        }
    }
}

/// Helper: tensor list -> plain vec-of-vecs for ClientGrads/ModelBroadcast.
pub fn tensors_to_payload(tl: &TensorList) -> Vec<Vec<f32>> {
    tl.tensors.iter().map(|t| t.data().to_vec()).collect()
}

/// Helper: payload -> tensors with provided shapes.
pub fn payload_to_tensors(
    payload: &[Vec<f32>],
    shapes: &[Vec<usize>],
    names: &[String],
) -> TensorList {
    assert_eq!(payload.len(), shapes.len());
    let tensors = payload
        .iter()
        .zip(shapes)
        .map(|(p, s)| Tensor::from_vec(s, p.clone()))
        .collect();
    TensorList::new(names.to_vec(), tensors)
}

struct Writer {
    out: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { out: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.out.extend_from_slice(v);
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Reader { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.i + n <= self.b.len(), "message truncated");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn at_end(&self) -> bool {
        self.i == self.b.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantizer::{GroupedPq, PqConfig};
    use crate::util::rng::Rng;

    fn roundtrip(m: Message) {
        let bytes = m.encode(7, 3);
        assert_eq!(bytes.len(), m.wire_len(), "wire_len mismatch");
        let (back, round, client) = Message::decode(&bytes).unwrap();
        assert_eq!(back, m);
        assert_eq!((round, client), (7, 3));
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Message::ActivationUpload { z: vec![1.0, -2.5, 3.0], b: 1, d: 3 });
        roundtrip(Message::GradDownload { grad: vec![0.5; 10], b: 2, d: 5 });
        roundtrip(Message::ClientGrads { grads: vec![vec![1.0, 2.0], vec![3.0]] });
        roundtrip(Message::ModelBroadcast { params: vec![vec![]; 2] });
        roundtrip(Message::QuantizedUpload {
            q: 4,
            r: 2,
            l: 3,
            b: 5,
            d: 8,
            ng: 10,
            codebooks: vec![0.25; 12],
            packed_codes: vec![0xAB, 0xCD, 0x01],
        });
    }

    #[test]
    fn pq_message_roundtrips_codes() {
        let mut rng = Rng::new(0);
        let (b, d) = (6, 16);
        let z: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let cfg = PqConfig::new(4, 2, 3);
        let pq = GroupedPq::new(cfg, d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let msg = Message::from_pq(&cfg, b, d, &out.codebooks, &out.codes);
        let bytes = msg.encode(0, 0);
        let (decoded, _, _) = Message::decode(&bytes).unwrap();
        let codes = decoded.unpack_codes().unwrap();
        assert_eq!(codes, out.codes);
        // server can reconstruct identical z_tilde from the wire content
        if let Message::QuantizedUpload { codebooks, .. } = &decoded {
            let rec = pq.reconstruct(codebooks, &codes, b);
            assert_eq!(rec, out.z_tilde);
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn quantized_much_smaller_than_raw() {
        let mut rng = Rng::new(1);
        let (b, d) = (20, 9216);
        let z: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let raw = Message::ActivationUpload { z: z.clone(), b, d };
        let cfg = PqConfig::new(1152, 1, 2).with_iters(1);
        let pq = GroupedPq::new(cfg, d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let msg = Message::from_pq(&cfg, b, d, &out.codebooks, &out.codes);
        let ratio = raw.wire_len() as f64 / msg.wire_len() as f64;
        // f32 wire: codebook 2*8*4B + codes 23040 bits -> ~250x
        assert!(ratio > 200.0, "wire ratio only {ratio:.1}");
    }

    #[test]
    fn decode_rejects_corruption() {
        let m = Message::GradDownload { grad: vec![1.0; 4], b: 1, d: 4 };
        let mut bytes = m.encode(0, 0);
        bytes[0] ^= 0xFF; // magic
        assert!(Message::decode(&bytes).is_err());
        let bytes = m.encode(0, 0);
        assert!(Message::decode(&bytes[..bytes.len() - 2]).is_err());
        let mut bytes2 = m.encode(0, 0);
        bytes2.push(0); // trailing
        assert!(Message::decode(&bytes2).is_err());
    }
}
