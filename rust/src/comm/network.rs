//! Star topology: one server, M clients, each with an up- and down-link.
//!
//! The coordinator sends every protocol message through here so that all
//! traffic is serialized, metered, and time-modelled uniformly. Estimated
//! round wall-clock uses the slowest selected client (synchronous FL).
//!
//! `upload`/`download` take `&self` and meter through atomics, so the
//! per-round cohort workers call them concurrently; each worker counts
//! its own client's bytes and the trainer merges those partials after
//! the round barrier (see `coordinator::split`).

use std::sync::Arc;

use crate::comm::accounting::{ByteMeter, Direction, RoundBytes};
use crate::comm::channel::{Link, LinkSpec};
use crate::comm::message::Message;

/// The simulated star network.
///
/// State is O(1) in the population: every client shares one uplink and
/// one downlink descriptor per direction (the links are stateless spec +
/// meter handles — `Link::send` takes `&self` and meters through
/// atomics, and all clients always carried identical specs). The old
/// layout held two `Vec<Link>`s, an O(population) allocation that a
/// million-client run would pay for links that are never touched (only
/// the cohort's messages cross the wire).
pub struct StarNetwork {
    clients: usize,
    uplink: Link,
    downlink: Link,
    pub meter: Arc<ByteMeter>,
}

impl StarNetwork {
    pub fn new(clients: usize, up: LinkSpec, down: LinkSpec) -> Self {
        let meter = Arc::new(ByteMeter::new());
        let uplink = Link::new(up, Direction::Uplink, Arc::clone(&meter));
        let downlink = Link::new(down, Direction::Downlink, Arc::clone(&meter));
        StarNetwork { clients, uplink, downlink, meter }
    }

    pub fn with_defaults(clients: usize) -> Self {
        Self::new(clients, LinkSpec::mobile_uplink(), LinkSpec::mobile_downlink())
    }

    pub fn num_clients(&self) -> usize {
        self.clients
    }

    /// Client -> server transfer. Returns decoded message (round-tripped
    /// through the wire bytes) and its wire size. Encodes through the
    /// uplink's reused scratch buffer (no per-message allocation on the
    /// encode side).
    pub fn upload(
        &self,
        client: usize,
        round: u32,
        msg: &Message,
    ) -> anyhow::Result<(Message, usize)> {
        debug_assert!(client < self.clients, "client {client} out of range");
        self.uplink.transfer(msg, round, client as u32)
    }

    /// Server -> client transfer.
    pub fn download(
        &self,
        client: usize,
        round: u32,
        msg: &Message,
    ) -> anyhow::Result<(Message, usize)> {
        debug_assert!(client < self.clients, "client {client} out of range");
        self.downlink.transfer(msg, round, client as u32)
    }

    /// Fold a remotely-metered delta into this network's meter. Socket
    /// deployments run `client_step` on worker processes whose transfers
    /// hit the *worker's* meter; the coordinator absorbs each returned
    /// [`RoundBytes`] so its own per-round deltas, cumulative totals, and
    /// the engine's meter-vs-partials assertion match the in-process run
    /// byte-for-byte.
    pub fn absorb(&self, bytes: &RoundBytes) {
        self.meter.absorb(bytes);
    }

    /// Simulated transfer seconds for a synchronous round over `selected`
    /// clients: max over clients of (their up+down busy time this call).
    pub fn estimate_round_time(&self, per_client_bytes: &[(usize, usize)]) -> f64 {
        self.estimate_round_time_with_delays(
            &per_client_bytes
                .iter()
                .map(|&(up, down)| (up, down, 0.0))
                .collect::<Vec<_>>(),
            0.0,
        )
    }

    /// Round-time estimate with per-client simulated compute delays
    /// (stragglers). Each entry is `(up_bytes, down_bytes, delay_seconds)`;
    /// a client's busy time is transfer + delay. The `deadline` is the
    /// *delay budget* of `coordinator::faults`: a client whose delay
    /// exceeds it is evicted, so the server only waits `deadline` for it
    /// (its full busy time doesn't extend the round). Punctual clients
    /// are waited for in full — transfer time is not counted against the
    /// budget, keeping this consistent with the eviction predicate. With
    /// all delays 0 and no deadline this is exactly
    /// [`StarNetwork::estimate_round_time`].
    pub fn estimate_round_time_with_delays(
        &self,
        per_client: &[(usize, usize, f64)],
        deadline: f64,
    ) -> f64 {
        per_client
            .iter()
            .map(|&(up_bytes, down_bytes, delay)| {
                let t = self.uplink.spec().transfer_time(up_bytes)
                    + self.downlink.spec().transfer_time(down_bytes)
                    + delay;
                if deadline > 0.0 && delay > deadline {
                    // evicted straggler: the coordinator stopped waiting
                    t.min(deadline)
                } else {
                    t
                }
            })
            .fold(0.0, f64::max)
    }

    pub fn begin_round(&self) {
        self.meter.begin_round();
    }

    pub fn end_round(&self) -> RoundBytes {
        self.meter.end_round()
    }

    pub fn totals(&self) -> RoundBytes {
        self.meter.totals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_download_metered_separately() {
        let net = StarNetwork::with_defaults(3);
        net.begin_round();
        let up_msg = Message::ActivationUpload { z: vec![0.0; 100], b: 1, d: 100 };
        let down_msg = Message::GradDownload { grad: vec![0.0; 100], b: 1, d: 100 };
        let (_, up_n) = net.upload(0, 0, &up_msg).unwrap();
        let (_, down_n) = net.download(0, 0, &down_msg).unwrap();
        let rb = net.end_round();
        assert_eq!(rb.up, up_n as u64);
        assert_eq!(rb.down, down_n as u64);
    }

    #[test]
    fn round_time_is_slowest_client() {
        let net = StarNetwork::with_defaults(2);
        let t = net.estimate_round_time(&[(1000, 1000), (1_000_000, 1000)]);
        let slow = net.estimate_round_time(&[(1_000_000, 1000)]);
        assert!((t - slow).abs() < 1e-12);
    }

    #[test]
    fn delays_and_deadline_shape_round_time() {
        let net = StarNetwork::with_defaults(2);
        let base = net.estimate_round_time(&[(1000, 1000)]);
        // a straggler's delay extends the round...
        let slow = net.estimate_round_time_with_delays(&[(1000, 1000, 5.0)], 0.0);
        assert!((slow - (base + 5.0)).abs() < 1e-12);
        // ...until its delay blows the budget and it gets evicted
        let capped = net.estimate_round_time_with_delays(&[(1000, 1000, 5.0)], 2.0);
        assert!((capped - 2.0).abs() < 1e-12);
        // a punctual client (delay within budget) is waited for in full,
        // even when its transfer alone outlasts the deadline — transfer
        // time doesn't count against the delay budget
        let big = 100_000_000; // ~160 s on the 5 Mbps uplink
        let waited = net.estimate_round_time_with_delays(&[(big, 1000, 0.0)], 2.0);
        let plain = net.estimate_round_time(&[(big, 1000)]);
        assert_eq!(waited.to_bits(), plain.to_bits());
        // zero delays + no deadline is exactly the plain estimate
        let same = net.estimate_round_time_with_delays(&[(1000, 1000, 0.0)], 0.0);
        assert_eq!(same.to_bits(), base.to_bits());
    }

    #[test]
    fn messages_survive_the_wire() {
        let net = StarNetwork::with_defaults(1);
        let msg = Message::ClientGrads { grads: vec![vec![1.5, -2.0]] };
        let (decoded, _) = net.upload(0, 5, &msg).unwrap();
        assert_eq!(decoded, msg);
    }

    #[test]
    fn million_client_network_is_o1_state() {
        // shared link descriptors: population size only sets the id range
        let net = StarNetwork::with_defaults(1_000_000);
        assert_eq!(net.num_clients(), 1_000_000);
        net.begin_round();
        let msg = Message::ActivationUpload { z: vec![0.0; 8], b: 1, d: 8 };
        let (_, n) = net.upload(999_999, 0, &msg).unwrap();
        assert!(n > 0);
        assert_eq!(net.end_round().up, n as u64);
    }
}
