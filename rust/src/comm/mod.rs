//! Communication substrate: wire format, byte accounting, simulated links.
//!
//! Everything a client "sends" in the simulation is actually serialized to
//! bytes ([`message`]), metered ([`accounting`]), and pushed through a
//! bandwidth/latency-modelled link ([`channel`]) of a star topology
//! ([`network`]). This is what makes the reported communication costs
//! byte-accurate rather than formula-only: Figure 6's x-axis integrates
//! these meters.

//! The [`transport`] module carries the same [`message::Message`] bytes
//! over real sockets (length-prefixed frames + the serve/join control
//! protocol) for the loopback deployment mode.

pub mod accounting;
pub mod channel;
pub mod message;
pub mod network;
pub mod transport;

pub use accounting::{ByteMeter, Direction, RoundBytes};
pub use channel::{Link, LinkSpec};
pub use message::Message;
pub use network::StarNetwork;
pub use transport::Frame;
