//! Communication substrate: wire format, byte accounting, simulated links.
//!
//! Everything a client "sends" in the simulation is actually serialized to
//! bytes ([`message`]), metered ([`accounting`]), and pushed through a
//! bandwidth/latency-modelled link ([`channel`]) of a star topology
//! ([`network`]). This is what makes the reported communication costs
//! byte-accurate rather than formula-only: Figure 6's x-axis integrates
//! these meters.

pub mod accounting;
pub mod channel;
pub mod message;
pub mod network;

pub use accounting::{ByteMeter, Direction, RoundBytes};
pub use channel::{Link, LinkSpec};
pub use message::Message;
pub use network::StarNetwork;
