//! Byte meters: per-direction, per-round communication accounting.
//!
//! Every message that crosses a [`crate::comm::channel::Link`] is counted
//! here. Figure 6's x-axis (cumulative communication) and the measured
//! columns of Table 1 read these meters; they are thread-safe because
//! client workers run on the pool.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Transfer direction relative to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// client -> server (the scarce resource in FL).
    Uplink,
    /// server -> client.
    Downlink,
}

/// Byte totals for one round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundBytes {
    pub up: u64,
    pub down: u64,
    pub up_msgs: u64,
    pub down_msgs: u64,
}

impl RoundBytes {
    pub fn total(&self) -> u64 {
        self.up + self.down
    }

    /// One client's transfers as a partial round delta.
    pub fn client(up: usize, down: usize, up_msgs: u64, down_msgs: u64) -> RoundBytes {
        RoundBytes { up: up as u64, down: down as u64, up_msgs, down_msgs }
    }

    /// Fold another partial into this one. The parallel round loop counts
    /// bytes per client inside the worker unit and merges the partials
    /// after the barrier in cohort-slot order — sums of the same u64s in
    /// any order are identical, so round records don't depend on thread
    /// scheduling.
    pub fn merge(&mut self, other: &RoundBytes) {
        self.up += other.up;
        self.down += other.down;
        self.up_msgs += other.up_msgs;
        self.down_msgs += other.down_msgs;
    }
}

/// Thread-safe cumulative + per-round byte meter.
#[derive(Debug, Default)]
pub struct ByteMeter {
    up: AtomicU64,
    down: AtomicU64,
    up_msgs: AtomicU64,
    down_msgs: AtomicU64,
    rounds: Mutex<Vec<RoundBytes>>,
    /// Cumulative snapshot taken at `begin_round`; `None` while no round
    /// is open. The `Option` makes begin/end pairing checkable.
    round_start: Mutex<Option<RoundBytes>>,
}

impl ByteMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, dir: Direction, bytes: usize) {
        match dir {
            Direction::Uplink => {
                self.up.fetch_add(bytes as u64, Ordering::Relaxed);
                self.up_msgs.fetch_add(1, Ordering::Relaxed);
            }
            Direction::Downlink => {
                self.down.fetch_add(bytes as u64, Ordering::Relaxed);
                self.down_msgs.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Fold a remotely-metered delta into the cumulative counters (bytes
    /// *and* message counts). Used by socket deployments to replay worker
    /// processes' transfers into the coordinator's meter; falls inside
    /// whatever round window is open, like any other `record`.
    pub fn absorb(&self, rb: &RoundBytes) {
        self.up.fetch_add(rb.up, Ordering::Relaxed);
        self.down.fetch_add(rb.down, Ordering::Relaxed);
        self.up_msgs.fetch_add(rb.up_msgs, Ordering::Relaxed);
        self.down_msgs.fetch_add(rb.down_msgs, Ordering::Relaxed);
    }

    /// Snapshot of cumulative totals.
    pub fn totals(&self) -> RoundBytes {
        RoundBytes {
            up: self.up.load(Ordering::Relaxed),
            down: self.down.load(Ordering::Relaxed),
            up_msgs: self.up_msgs.load(Ordering::Relaxed),
            down_msgs: self.down_msgs.load(Ordering::Relaxed),
        }
    }

    /// Mark the start of a round (call before the round's transfers).
    /// Calls must pair with [`ByteMeter::end_round`]; an unmatched second
    /// `begin_round` is a caller bug (debug-asserted) and restarts the
    /// round window in release.
    pub fn begin_round(&self) {
        let mut start = self.round_start.lock().unwrap();
        debug_assert!(
            start.is_none(),
            "begin_round without a matching end_round (round meter already open)"
        );
        *start = Some(self.totals());
    }

    /// Close the round; returns and archives this round's delta. The round
    /// engine calls this on *every* exit path — including error aborts —
    /// so the per-round archive never desyncs from the round records. An
    /// `end_round` with no open round is a caller bug (debug-asserted) and
    /// degrades to an empty delta in release; the subtraction saturates so
    /// an unbalanced meter can never wrap.
    pub fn end_round(&self) -> RoundBytes {
        let mut slot = self.round_start.lock().unwrap();
        debug_assert!(
            slot.is_some(),
            "end_round without a matching begin_round (no round meter open)"
        );
        let now = self.totals();
        let start = slot.take().unwrap_or(now);
        let delta = RoundBytes {
            up: now.up.saturating_sub(start.up),
            down: now.down.saturating_sub(start.down),
            up_msgs: now.up_msgs.saturating_sub(start.up_msgs),
            down_msgs: now.down_msgs.saturating_sub(start.down_msgs),
        };
        self.rounds.lock().unwrap().push(delta);
        delta
    }

    pub fn per_round(&self) -> Vec<RoundBytes> {
        self.rounds.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_per_direction() {
        let m = ByteMeter::new();
        m.record(Direction::Uplink, 100);
        m.record(Direction::Uplink, 50);
        m.record(Direction::Downlink, 7);
        let t = m.totals();
        assert_eq!(t.up, 150);
        assert_eq!(t.down, 7);
        assert_eq!(t.up_msgs, 2);
        assert_eq!(t.down_msgs, 1);
        assert_eq!(t.total(), 157);
    }

    #[test]
    fn round_deltas() {
        let m = ByteMeter::new();
        m.begin_round();
        m.record(Direction::Uplink, 10);
        let r1 = m.end_round();
        assert_eq!(r1.up, 10);
        m.begin_round();
        m.record(Direction::Uplink, 5);
        m.record(Direction::Downlink, 2);
        let r2 = m.end_round();
        assert_eq!((r2.up, r2.down), (5, 2));
        assert_eq!(m.per_round(), vec![r1, r2]);
        assert_eq!(m.totals().up, 15);
    }

    #[test]
    fn merge_folds_partials() {
        let mut total = RoundBytes::default();
        total.merge(&RoundBytes::client(100, 30, 2, 1));
        total.merge(&RoundBytes::client(7, 0, 1, 0));
        assert_eq!(total.up, 107);
        assert_eq!(total.down, 30);
        assert_eq!(total.up_msgs, 3);
        assert_eq!(total.down_msgs, 1);
        assert_eq!(total.total(), 137);
    }

    /// Unpaired `end_round` is caught by the debug assertion; in release
    /// it degrades to an empty delta instead of wrapping the unsigned
    /// subtraction into ~u64::MAX bytes.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "end_round without"))]
    fn unbalanced_end_round_saturates_instead_of_wrapping() {
        let m = ByteMeter::new();
        m.record(Direction::Uplink, 10);
        let delta = m.end_round(); // no begin_round
        assert_eq!(delta, RoundBytes::default());
    }

    /// Unpaired second `begin_round` is caught in debug; in release it
    /// restarts the round window.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "begin_round without"))]
    fn unbalanced_begin_round_restarts_the_window() {
        let m = ByteMeter::new();
        m.begin_round();
        m.record(Direction::Uplink, 7);
        m.begin_round();
        m.record(Direction::Uplink, 3);
        assert_eq!(m.end_round().up, 3, "second begin restarted the window");
    }

    #[test]
    fn thread_safe_counting() {
        let m = Arc::new(ByteMeter::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.record(Direction::Uplink, 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.totals().up, 24_000);
        assert_eq!(m.totals().up_msgs, 8_000);
    }
}
