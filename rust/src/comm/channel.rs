//! Simulated point-to-point links with a bandwidth/latency time model.
//!
//! The simulation is functionally synchronous (messages arrive when sent)
//! but each transfer charges simulated wall-clock time
//! `latency + bytes / bandwidth` to the link, so experiments can report
//! estimated round times for asymmetric mobile up-links (the paper's
//! motivation: up-link is the bottleneck). Byte counts flow to the shared
//! [`super::ByteMeter`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::comm::accounting::{ByteMeter, Direction};
use crate::comm::message::Message;

/// Link parameters. Defaults model a mobile client: 5 Mbps up, 20 Mbps
/// down, 50 ms latency.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    pub bandwidth_bps: f64,
    pub latency_s: f64,
}

impl LinkSpec {
    pub fn mobile_uplink() -> Self {
        LinkSpec { bandwidth_bps: 5e6, latency_s: 0.05 }
    }

    pub fn mobile_downlink() -> Self {
        LinkSpec { bandwidth_bps: 20e6, latency_s: 0.05 }
    }

    /// Simulated seconds to move `bytes` over this link.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// One direction of a client <-> server connection.
pub struct Link {
    spec: LinkSpec,
    direction: Direction,
    meter: Arc<ByteMeter>,
    /// Accumulated simulated busy time, in microseconds.
    busy_us: AtomicU64,
}

impl Link {
    pub fn new(spec: LinkSpec, direction: Direction, meter: Arc<ByteMeter>) -> Self {
        Link { spec, direction, meter, busy_us: AtomicU64::new(0) }
    }

    /// "Transmit" a message: meter the bytes, charge simulated time, and
    /// hand back the serialized form (the receiver decodes it — the bytes
    /// really do round-trip through the wire format).
    pub fn send(&self, msg: &Message, round: u32, client: u32) -> Vec<u8> {
        let bytes = msg.encode(round, client);
        self.meter.record(self.direction, bytes.len());
        let t = self.spec.transfer_time(bytes.len());
        self.busy_us
            .fetch_add((t * 1e6) as u64, Ordering::Relaxed);
        bytes
    }

    /// Total simulated seconds this link has been busy.
    pub fn busy_seconds(&self) -> f64 {
        self.busy_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn spec(&self) -> LinkSpec {
        self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_model() {
        let s = LinkSpec { bandwidth_bps: 8e6, latency_s: 0.01 };
        // 1 MB = 8e6 bits -> 1 s + latency
        assert!((s.transfer_time(1_000_000) - 1.01).abs() < 1e-9);
    }

    #[test]
    fn send_meters_and_charges_time() {
        let meter = Arc::new(ByteMeter::new());
        let link = Link::new(
            LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 },
            Direction::Uplink,
            Arc::clone(&meter),
        );
        let msg = Message::GradDownload { grad: vec![0.0; 250], b: 1, d: 250 };
        let bytes = link.send(&msg, 1, 2);
        assert_eq!(meter.totals().up, bytes.len() as u64);
        let expect = bytes.len() as f64 * 8.0 / 1e6;
        assert!((link.busy_seconds() - expect).abs() < 1e-3);
        // the serialized bytes decode to the original message
        let (back, round, client) = Message::decode(&bytes).unwrap();
        assert_eq!(back, msg);
        assert_eq!((round, client), (1, 2));
    }

    #[test]
    fn uplink_slower_than_downlink_default() {
        let up = LinkSpec::mobile_uplink();
        let down = LinkSpec::mobile_downlink();
        assert!(up.transfer_time(1 << 20) > down.transfer_time(1 << 20));
    }
}
