//! Simulated point-to-point links with a bandwidth/latency time model.
//!
//! The simulation is functionally synchronous (messages arrive when sent)
//! but each transfer charges simulated wall-clock time
//! `latency + bytes / bandwidth` to the link, so experiments can report
//! estimated round times for asymmetric mobile up-links (the paper's
//! motivation: up-link is the bottleneck). Byte counts flow to the shared
//! [`super::ByteMeter`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::comm::accounting::{ByteMeter, Direction};
use crate::comm::message::Message;

/// Link parameters. Defaults model a mobile client: 5 Mbps up, 20 Mbps
/// down, 50 ms latency.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    pub bandwidth_bps: f64,
    pub latency_s: f64,
}

impl LinkSpec {
    pub fn mobile_uplink() -> Self {
        LinkSpec { bandwidth_bps: 5e6, latency_s: 0.05 }
    }

    pub fn mobile_downlink() -> Self {
        LinkSpec { bandwidth_bps: 20e6, latency_s: 0.05 }
    }

    /// Simulated seconds to move `bytes` over this link.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// One direction of a client <-> server connection.
pub struct Link {
    spec: LinkSpec,
    direction: Direction,
    meter: Arc<ByteMeter>,
    /// Accumulated simulated busy time, in microseconds.
    busy_us: AtomicU64,
    /// Reused encode buffer for [`Link::transfer`]: the hot round path
    /// serializes every message into this scratch instead of allocating a
    /// fresh `Vec<u8>` per send. Contended callers (concurrent cohort
    /// workers) fall back to a local buffer rather than serializing on
    /// the lock.
    scratch: Mutex<Vec<u8>>,
}

impl Link {
    pub fn new(spec: LinkSpec, direction: Direction, meter: Arc<ByteMeter>) -> Self {
        Link {
            spec,
            direction,
            meter,
            busy_us: AtomicU64::new(0),
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// "Transmit" a message: meter the bytes, charge simulated time, and
    /// hand back the serialized form (the receiver decodes it — the bytes
    /// really do round-trip through the wire format).
    pub fn send(&self, msg: &Message, round: u32, client: u32) -> Vec<u8> {
        let bytes = msg.encode(round, client);
        self.meter.record(self.direction, bytes.len());
        let t = self.spec.transfer_time(bytes.len());
        self.busy_us
            .fetch_add((t * 1e6) as u64, Ordering::Relaxed);
        bytes
    }

    /// Full simulated transfer: encode into the link's scratch buffer,
    /// meter + charge time, and decode the receiver's view from those
    /// exact bytes. Same wire bytes and accounting as
    /// `send` + `Message::decode`, minus the per-message allocation — the
    /// warm path is allocation-free on the encode side
    /// (`tests/alloc.rs` counts it).
    pub fn transfer(
        &self,
        msg: &Message,
        round: u32,
        client: u32,
    ) -> anyhow::Result<(Message, usize)> {
        match self.scratch.try_lock() {
            Ok(mut buf) => self.transfer_with(&mut buf, msg, round, client),
            // another worker holds the scratch: a fresh buffer beats
            // serializing the whole cohort on one mutex
            Err(_) => self.transfer_with(&mut Vec::new(), msg, round, client),
        }
    }

    fn transfer_with(
        &self,
        buf: &mut Vec<u8>,
        msg: &Message,
        round: u32,
        client: u32,
    ) -> anyhow::Result<(Message, usize)> {
        msg.encode_into(round, client, buf);
        self.meter.record(self.direction, buf.len());
        let t = self.spec.transfer_time(buf.len());
        self.busy_us.fetch_add((t * 1e6) as u64, Ordering::Relaxed);
        let n = buf.len();
        let (decoded, _, _) = Message::decode(buf)?;
        Ok((decoded, n))
    }

    /// Total simulated seconds this link has been busy.
    pub fn busy_seconds(&self) -> f64 {
        self.busy_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn spec(&self) -> LinkSpec {
        self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_model() {
        let s = LinkSpec { bandwidth_bps: 8e6, latency_s: 0.01 };
        // 1 MB = 8e6 bits -> 1 s + latency
        assert!((s.transfer_time(1_000_000) - 1.01).abs() < 1e-9);
    }

    #[test]
    fn send_meters_and_charges_time() {
        let meter = Arc::new(ByteMeter::new());
        let link = Link::new(
            LinkSpec { bandwidth_bps: 1e6, latency_s: 0.0 },
            Direction::Uplink,
            Arc::clone(&meter),
        );
        let msg = Message::GradDownload { grad: vec![0.0; 250], b: 1, d: 250 };
        let bytes = link.send(&msg, 1, 2);
        assert_eq!(meter.totals().up, bytes.len() as u64);
        let expect = bytes.len() as f64 * 8.0 / 1e6;
        assert!((link.busy_seconds() - expect).abs() < 1e-3);
        // the serialized bytes decode to the original message
        let (back, round, client) = Message::decode(&bytes).unwrap();
        assert_eq!(back, msg);
        assert_eq!((round, client), (1, 2));
    }

    /// `transfer` must be observationally identical to
    /// `send` + `decode`: same decoded message, same byte count, same
    /// meter and busy-time charges — only the allocation differs.
    #[test]
    fn transfer_matches_send_plus_decode() {
        let spec = LinkSpec { bandwidth_bps: 1e6, latency_s: 0.01 };
        let msg = Message::ClientGrads { grads: vec![vec![1.0, -2.5], vec![0.25]] };

        let meter_a = Arc::new(ByteMeter::new());
        let a = Link::new(spec, Direction::Uplink, Arc::clone(&meter_a));
        let bytes = a.send(&msg, 3, 4);
        let (dec_a, _, _) = Message::decode(&bytes).unwrap();

        let meter_b = Arc::new(ByteMeter::new());
        let b = Link::new(spec, Direction::Uplink, Arc::clone(&meter_b));
        let (dec_b, n) = b.transfer(&msg, 3, 4).unwrap();

        assert_eq!(dec_b, dec_a);
        assert_eq!(n, bytes.len());
        assert_eq!(meter_b.totals(), meter_a.totals());
        assert_eq!(b.busy_seconds().to_bits(), a.busy_seconds().to_bits());
        // the scratch persists: a second transfer reuses its capacity
        let (_, n2) = b.transfer(&msg, 3, 5).unwrap();
        assert_eq!(n2, n);
    }

    #[test]
    fn uplink_slower_than_downlink_default() {
        let up = LinkSpec::mobile_uplink();
        let down = LinkSpec::mobile_downlink();
        assert!(up.transfer_time(1 << 20) > down.transfer_time(1 << 20));
    }
}
