//! Socket transport: length-prefixed frames + the coordinator⇄client
//! control protocol.
//!
//! The simulator's [`super::message::Message`] bytes already have an
//! exact wire contract (`wire_len`); this module is what carries those
//! same bytes over *real* sockets. A frame is `len: u32 (LE) | body`,
//! where `body[0]` is a [`Frame`] tag and the rest uses the same
//! little-endian codec as `message.rs` (one `Writer`/`Reader`, one set
//! of adversarial-length caps).
//!
//! Protocol (loopback deployment mode, PR 8):
//!
//! ```text
//! client                          coordinator
//!   │ ── Join{version} ─────────────▶ │   WaitingForMembers
//!   │ ◀───────── Welcome{config} ──── │
//!   │    (build replica trainer)      │   Warmup
//!   │ ── Ready ─────────────────────▶ │
//!   │                                 │   Training (roster ≥ min_clients)
//!   │ ◀─ RoundState{ws} ───────────── │ ┐
//!   │ ◀─ Broadcast{Message bytes} ─── │ │ once per round
//!   │ ◀─ StepAssign{client, plan} ─── │ │ per assigned cohort slot
//!   │ ── StepResult{...} ───────────▶ │ │
//!   │ ◀─ RoundEnd{round} ──────────── │ ┘
//!   │ ── Leave ─────────────────────▶ │   (between rounds only)
//!   │ ◀─ Shutdown ─────────────────── │   (run finished / aborted)
//! ```
//!
//! Every numeric result field crosses the wire as its exact bit pattern
//! (f64 via `to_bits`), so a remote client step reduces to the same bits
//! as the in-process fan-out — the property the CI loopback byte-diff
//! locks.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::accounting::RoundBytes;
use super::message::{Reader, Writer};
use crate::config::ByzantineKind;
use crate::coordinator::faults::{DropPhase, FaultPlan};

/// Bumped on any frame-layout change; [`Frame::Join`] carries it so a
/// stale client fails the handshake instead of desyncing mid-round.
/// v2: `StepAssign` plans carry a byzantine-kind byte.
pub const PROTOCOL_VERSION: u32 = 2;

/// Upper bound on a single frame body. Large enough for a stress-preset
/// model broadcast with room to spare; small enough that a corrupt or
/// hostile length prefix cannot trigger a multi-GiB allocation.
pub const MAX_FRAME_LEN: usize = 256 << 20;

/// One client's step outcome, shipped back to the coordinator. Mirrors
/// [`crate::coordinator::engine::ClientOutput`] field-for-field, with the
/// algorithm payload flattened by `RoundAlgorithm::payload_to_wire`.
#[derive(Clone, Debug, PartialEq)]
pub struct StepResult {
    pub client: u64,
    pub weight: f64,
    pub loss: f64,
    pub metric_sums: Vec<f64>,
    pub quant_rel_err: f64,
    pub surrogate_loss: f64,
    pub dropped: Option<DropPhase>,
    pub delay_seconds: f64,
    /// The transfers this client's step metered on the *worker's* side;
    /// the coordinator absorbs them into its own meter
    /// ([`super::StarNetwork::absorb`]) so byte accounting matches the
    /// in-process run exactly.
    pub bytes: RoundBytes,
    /// Flattened survivor payload; `None` for dropped/evicted clients.
    pub payload: Option<Vec<Vec<f32>>>,
}

/// Control frames of the loopback deployment protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// client → coordinator: first frame on a fresh connection.
    Join { version: u32 },
    /// coordinator → client: the run's full [`crate::config::RunConfig`]
    /// as JSON. The client builds a deterministic replica trainer from it
    /// (same seed ⇒ same init, same synthetic dataset).
    Welcome { config_json: String },
    /// client → coordinator: replica built, ready for assignments.
    Ready,
    /// coordinator → client: server-side round state to install before
    /// this round's steps (split: the server-model parameters; fedavg:
    /// empty — everything travels in the broadcast).
    RoundState { round: u32, tensors: Vec<Vec<f32>> },
    /// coordinator → client: the round's model broadcast, as the exact
    /// [`super::message::Message::encode`] bytes.
    Broadcast { round: u32, message: Vec<u8> },
    /// coordinator → client: run one client's step. The fault plan
    /// travels with the assignment, so drops/stragglers/eviction apply
    /// identically to remote clients.
    StepAssign { round: u32, attempt: u32, client: u64, plan: FaultPlan },
    /// client → coordinator: the step's outcome.
    StepResult(StepResult),
    /// client → coordinator: the step failed with an error.
    StepError { client: u64, error: String },
    /// coordinator → client: the round committed; clients wanting to
    /// leave may do so now (before the next round's roster is fixed).
    RoundEnd { round: u32 },
    /// client → coordinator: graceful departure (between rounds).
    Leave,
    /// coordinator → client: the run is over; close the connection.
    Shutdown,
}

fn drop_phase_to_u8(p: Option<DropPhase>) -> u8 {
    match p {
        None => 0,
        Some(DropPhase::AfterFwd) => 1,
        Some(DropPhase::AfterUpload) => 2,
        Some(DropPhase::BeforeGradUpload) => 3,
        Some(DropPhase::Deadline) => 4,
        Some(DropPhase::RejectedCodeword) => 5,
        Some(DropPhase::PeerFailure) => 6,
    }
}

fn drop_phase_from_u8(v: u8) -> anyhow::Result<Option<DropPhase>> {
    Ok(match v {
        0 => None,
        1 => Some(DropPhase::AfterFwd),
        2 => Some(DropPhase::AfterUpload),
        3 => Some(DropPhase::BeforeGradUpload),
        4 => Some(DropPhase::Deadline),
        5 => Some(DropPhase::RejectedCodeword),
        6 => Some(DropPhase::PeerFailure),
        t => anyhow::bail!("bad drop-phase tag {t}"),
    })
}

fn byz_to_u8(b: Option<ByzantineKind>) -> u8 {
    match b {
        None => 0,
        Some(ByzantineKind::GradScale) => 1,
        Some(ByzantineKind::SignFlip) => 2,
        Some(ByzantineKind::LabelFlip) => 3,
        Some(ByzantineKind::CorruptCodeword) => 4,
        Some(ByzantineKind::Replay) => 5,
    }
}

fn byz_from_u8(v: u8) -> anyhow::Result<Option<ByzantineKind>> {
    Ok(match v {
        0 => None,
        1 => Some(ByzantineKind::GradScale),
        2 => Some(ByzantineKind::SignFlip),
        3 => Some(ByzantineKind::LabelFlip),
        4 => Some(ByzantineKind::CorruptCodeword),
        5 => Some(ByzantineKind::Replay),
        t => anyhow::bail!("bad byzantine-kind tag {t}"),
    })
}

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Join { .. } => 1,
            Frame::Welcome { .. } => 2,
            Frame::Ready => 3,
            Frame::RoundState { .. } => 4,
            Frame::Broadcast { .. } => 5,
            Frame::StepAssign { .. } => 6,
            Frame::StepResult(_) => 7,
            Frame::StepError { .. } => 8,
            Frame::RoundEnd { .. } => 9,
            Frame::Leave => 10,
            Frame::Shutdown => 11,
        }
    }

    /// Short name for protocol-error messages.
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Join { .. } => "Join",
            Frame::Welcome { .. } => "Welcome",
            Frame::Ready => "Ready",
            Frame::RoundState { .. } => "RoundState",
            Frame::Broadcast { .. } => "Broadcast",
            Frame::StepAssign { .. } => "StepAssign",
            Frame::StepResult(_) => "StepResult",
            Frame::StepError { .. } => "StepError",
            Frame::RoundEnd { .. } => "RoundEnd",
            Frame::Leave => "Leave",
            Frame::Shutdown => "Shutdown",
        }
    }

    /// Serialize the frame body (no length prefix) — the exact buffer
    /// [`Frame::decode`] consumes; [`Frame::write_to`] adds the length.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Serialize the frame body (no length prefix) into `out`.
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        let mut w = Writer::new(out);
        w.u8(self.tag());
        match self {
            Frame::Join { version } => w.u32(*version),
            Frame::Welcome { config_json } => w.str(config_json),
            Frame::Ready | Frame::Leave | Frame::Shutdown => {}
            Frame::RoundState { round, tensors } => {
                w.u32(*round);
                w.f32_lists(tensors);
            }
            Frame::Broadcast { round, message } => {
                w.u32(*round);
                w.bytes(message);
            }
            Frame::StepAssign { round, attempt, client, plan } => {
                w.u32(*round);
                w.u32(*attempt);
                w.u64(*client);
                w.u8(drop_phase_to_u8(plan.drop_at));
                w.f64(plan.delay_seconds);
                w.u8(plan.evicted as u8);
                w.u8(byz_to_u8(plan.byz));
            }
            Frame::StepResult(r) => {
                w.u64(r.client);
                w.f64(r.weight);
                w.f64(r.loss);
                w.f64s(&r.metric_sums);
                w.f64(r.quant_rel_err);
                w.f64(r.surrogate_loss);
                w.u8(drop_phase_to_u8(r.dropped));
                w.f64(r.delay_seconds);
                w.u64(r.bytes.up);
                w.u64(r.bytes.down);
                w.u64(r.bytes.up_msgs);
                w.u64(r.bytes.down_msgs);
                match &r.payload {
                    None => w.u8(0),
                    Some(p) => {
                        w.u8(1);
                        w.f32_lists(p);
                    }
                }
            }
            Frame::StepError { client, error } => {
                w.u64(*client);
                w.str(error);
            }
            Frame::RoundEnd { round } => w.u32(*round),
        }
    }

    /// Parse a frame body (no length prefix).
    pub fn decode(body: &[u8]) -> anyhow::Result<Frame> {
        let mut r = Reader::new(body);
        let tag = r.u8()?;
        let frame = match tag {
            1 => Frame::Join { version: r.u32()? },
            2 => Frame::Welcome { config_json: r.str()? },
            3 => Frame::Ready,
            4 => Frame::RoundState { round: r.u32()?, tensors: r.f32_lists()? },
            5 => Frame::Broadcast { round: r.u32()?, message: r.bytes()? },
            6 => {
                let round = r.u32()?;
                let attempt = r.u32()?;
                let client = r.u64()?;
                let drop_at = drop_phase_from_u8(r.u8()?)?;
                anyhow::ensure!(
                    drop_at != Some(DropPhase::Deadline),
                    "plans never carry Deadline directly"
                );
                anyhow::ensure!(
                    drop_at != Some(DropPhase::RejectedCodeword),
                    "plans never carry RejectedCodeword (it is a defense outcome)"
                );
                anyhow::ensure!(
                    drop_at != Some(DropPhase::PeerFailure),
                    "plans never carry PeerFailure (it is a coordinator-side verdict)"
                );
                let delay_seconds = r.f64()?;
                let evicted = match r.u8()? {
                    0 => false,
                    1 => true,
                    t => anyhow::bail!("bad bool tag {t}"),
                };
                let byz = byz_from_u8(r.u8()?)?;
                Frame::StepAssign {
                    round,
                    attempt,
                    client,
                    plan: FaultPlan { drop_at, delay_seconds, evicted, byz },
                }
            }
            7 => {
                let client = r.u64()?;
                let weight = r.f64()?;
                let loss = r.f64()?;
                let metric_sums = r.f64s()?;
                let quant_rel_err = r.f64()?;
                let surrogate_loss = r.f64()?;
                let dropped = drop_phase_from_u8(r.u8()?)?;
                let delay_seconds = r.f64()?;
                let bytes = RoundBytes {
                    up: r.u64()?,
                    down: r.u64()?,
                    up_msgs: r.u64()?,
                    down_msgs: r.u64()?,
                };
                let payload = match r.u8()? {
                    0 => None,
                    1 => Some(r.f32_lists()?),
                    t => anyhow::bail!("bad option tag {t}"),
                };
                Frame::StepResult(StepResult {
                    client,
                    weight,
                    loss,
                    metric_sums,
                    quant_rel_err,
                    surrogate_loss,
                    dropped,
                    delay_seconds,
                    bytes,
                    payload,
                })
            }
            8 => Frame::StepError { client: r.u64()?, error: r.str()? },
            9 => Frame::RoundEnd { round: r.u32()? },
            10 => Frame::Leave,
            11 => Frame::Shutdown,
            t => anyhow::bail!("unknown frame tag {t}"),
        };
        anyhow::ensure!(r.at_end(), "trailing bytes in {} frame", frame.name());
        Ok(frame)
    }

    /// Write this frame, length-prefixed, to a stream (flushes).
    pub fn write_to(&self, w: &mut impl Write) -> anyhow::Result<()> {
        let mut body = Vec::new();
        self.encode_into(&mut body);
        anyhow::ensure!(body.len() <= MAX_FRAME_LEN, "frame exceeds MAX_FRAME_LEN");
        w.write_all(&(body.len() as u32).to_le_bytes())?;
        w.write_all(&body)?;
        w.flush()?;
        Ok(())
    }

    /// Read one length-prefixed frame from a stream. The declared length
    /// is capped at [`MAX_FRAME_LEN`] before the body buffer is sized, so
    /// a hostile peer cannot force a huge allocation.
    pub fn read_from(r: &mut impl Read) -> anyhow::Result<Frame> {
        let mut lenb = [0u8; 4];
        r.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        anyhow::ensure!(len >= 1, "empty frame");
        anyhow::ensure!(len <= MAX_FRAME_LEN, "frame length {len} exceeds cap");
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        Frame::decode(&body)
    }
}

/// Apply the transport's socket options: no Nagle batching (frames are
/// the unit of latency here) and the given read deadline.
pub fn configure_stream(
    s: &TcpStream,
    read_timeout: Option<Duration>,
) -> anyhow::Result<()> {
    s.set_nodelay(true)?;
    s.set_read_timeout(read_timeout)?;
    Ok(())
}

/// The per-connection read deadline, derived from the fault layer's
/// `round_deadline` knob so one setting governs both simulated eviction
/// and real socket timeouts. Simulated deadlines are routinely
/// sub-second — far shorter than real process scheduling on a loaded CI
/// box — so the real timeout is floored at `floor` seconds
/// (`--socket-deadline-floor`, default [`MIN_SOCKET_DEADLINE`]); with no
/// deadline configured it falls back to [`DEFAULT_SOCKET_DEADLINE`] (a
/// liveness backstop, not a latency SLA), still honoring a larger floor.
/// Non-positive/non-finite floors degrade to [`MIN_SOCKET_DEADLINE`].
pub fn socket_deadline(round_deadline: f64, floor: f64) -> Duration {
    let floor = if floor > 0.0 && floor.is_finite() {
        floor
    } else {
        MIN_SOCKET_DEADLINE
    };
    if round_deadline > 0.0 {
        Duration::from_secs_f64(round_deadline.max(floor))
    } else {
        Duration::from_secs_f64(DEFAULT_SOCKET_DEADLINE.max(floor))
    }
}

/// Default floor for real-socket read deadlines (seconds).
pub const MIN_SOCKET_DEADLINE: f64 = 30.0;

/// Read deadline when no `round_deadline` is configured (seconds).
pub const DEFAULT_SOCKET_DEADLINE: f64 = 600.0;

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let back = Frame::read_from(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::Join { version: PROTOCOL_VERSION });
        roundtrip(Frame::Welcome { config_json: "{\"seed\":7}".into() });
        roundtrip(Frame::Ready);
        roundtrip(Frame::RoundState { round: 3, tensors: vec![vec![1.5, -2.0], vec![]] });
        roundtrip(Frame::Broadcast { round: 3, message: vec![0xFE, 0xD1, 0x17, 0xE0] });
        roundtrip(Frame::StepAssign {
            round: 2,
            attempt: 3,
            client: 99,
            plan: FaultPlan {
                drop_at: Some(DropPhase::AfterUpload),
                delay_seconds: 1.25,
                evicted: false,
                byz: None,
            },
        });
        roundtrip(Frame::StepAssign {
            round: 0,
            attempt: 1,
            client: 0,
            plan: FaultPlan { drop_at: None, delay_seconds: 7.5, evicted: true, byz: None },
        });
        for kind in ByzantineKind::ALL {
            roundtrip(Frame::StepAssign {
                round: 1,
                attempt: 1,
                client: 7,
                plan: FaultPlan { byz: Some(kind), ..FaultPlan::default() },
            });
        }
        roundtrip(Frame::StepResult(StepResult {
            client: 12,
            weight: 0.125,
            loss: 2.5,
            metric_sums: vec![3.0, 4.0],
            quant_rel_err: 0.01,
            surrogate_loss: -1.0,
            dropped: None,
            delay_seconds: 0.0,
            bytes: RoundBytes { up: 100, down: 200, up_msgs: 2, down_msgs: 3 },
            payload: Some(vec![vec![1.0], vec![2.0, 3.0]]),
        }));
        roundtrip(Frame::StepResult(StepResult {
            client: 5,
            weight: 0.5,
            loss: 0.0,
            metric_sums: vec![],
            quant_rel_err: 0.0,
            surrogate_loss: 0.0,
            dropped: Some(DropPhase::Deadline),
            delay_seconds: 9.75,
            bytes: RoundBytes::default(),
            payload: None,
        }));
        // a rejected-codeword drop is a legal *result* (defense outcome)
        roundtrip(Frame::StepResult(StepResult {
            client: 6,
            weight: 0.0,
            loss: 0.0,
            metric_sums: vec![],
            quant_rel_err: 0.0,
            surrogate_loss: 0.0,
            dropped: Some(DropPhase::RejectedCodeword),
            delay_seconds: 0.0,
            bytes: RoundBytes::default(),
            payload: None,
        }));
        roundtrip(Frame::StepError { client: 4, error: "boom".into() });
        roundtrip(Frame::RoundEnd { round: 9 });
        roundtrip(Frame::Leave);
        roundtrip(Frame::Shutdown);
    }

    /// f64 fields survive bit-exactly — the loopback byte-identity
    /// contract depends on it.
    #[test]
    fn f64_fields_are_bit_exact() {
        for v in [0.1f64, -0.0, f64::MIN_POSITIVE, 1.0 / 3.0, 1e300] {
            let mut buf = Vec::new();
            Frame::StepError { client: 0, error: String::new() }.write_to(&mut buf).unwrap();
            buf.clear();
            let f = Frame::StepResult(StepResult {
                client: 0,
                weight: v,
                loss: v,
                metric_sums: vec![v],
                quant_rel_err: v,
                surrogate_loss: v,
                dropped: None,
                delay_seconds: v,
                bytes: RoundBytes::default(),
                payload: None,
            });
            f.write_to(&mut buf).unwrap();
            match Frame::read_from(&mut Cursor::new(&buf)).unwrap() {
                Frame::StepResult(r) => {
                    assert_eq!(r.weight.to_bits(), v.to_bits());
                    assert_eq!(r.loss.to_bits(), v.to_bits());
                    assert_eq!(r.metric_sums[0].to_bits(), v.to_bits());
                    assert_eq!(r.delay_seconds.to_bits(), v.to_bits());
                }
                other => panic!("wrong frame {}", other.name()),
            }
        }
    }

    #[test]
    fn malformed_frames_rejected() {
        // declared length over the cap: rejected before any allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Frame::read_from(&mut Cursor::new(&huge)).is_err());
        // empty frame
        let empty = 0u32.to_le_bytes().to_vec();
        assert!(Frame::read_from(&mut Cursor::new(&empty)).is_err());
        // truncated body
        let mut buf = Vec::new();
        Frame::RoundEnd { round: 1 }.write_to(&mut buf).unwrap();
        assert!(Frame::read_from(&mut Cursor::new(&buf[..buf.len() - 1])).is_err());
        // unknown tag
        let bad = [1u32.to_le_bytes().to_vec(), vec![0xEE]].concat();
        let err = Frame::read_from(&mut Cursor::new(&bad)).unwrap_err().to_string();
        assert!(err.contains("unknown frame tag"), "got: {err}");
        // trailing bytes inside a frame body
        let mut body = Vec::new();
        Frame::Leave.encode_into(&mut body);
        body.push(0);
        assert!(Frame::decode(&body).is_err());
        // a plan claiming a defense-only drop phase (RejectedCodeword)
        let mut body = Vec::new();
        {
            let mut w = Writer::new(&mut body);
            w.u8(6); // StepAssign
            w.u32(0);
            w.u32(1);
            w.u64(3);
            w.u8(5); // RejectedCodeword
            w.f64(0.0);
            w.u8(0);
            w.u8(0);
        }
        let err = Frame::decode(&body).unwrap_err().to_string();
        assert!(err.contains("RejectedCodeword"), "got: {err}");
        // a plan with an unknown byzantine-kind tag
        let mut body = Vec::new();
        {
            let mut w = Writer::new(&mut body);
            w.u8(6); // StepAssign
            w.u32(0);
            w.u32(1);
            w.u64(3);
            w.u8(0);
            w.f64(0.0);
            w.u8(0);
            w.u8(9); // no such ByzantineKind
        }
        let err = Frame::decode(&body).unwrap_err().to_string();
        assert!(err.contains("byzantine-kind"), "got: {err}");
        // adversarial inner count: RoundState declaring 4G tensors
        let mut body = Vec::new();
        {
            let mut w = Writer::new(&mut body);
            w.u8(4); // RoundState
            w.u32(0);
            w.u32(u32::MAX);
        }
        let err = Frame::decode(&body).unwrap_err().to_string();
        assert!(err.contains("exceeds remaining"), "got: {err}");
    }

    #[test]
    fn socket_deadline_reuses_fault_semantics() {
        // configured deadlines pass through, floored for real sockets
        assert_eq!(
            socket_deadline(120.0, MIN_SOCKET_DEADLINE),
            Duration::from_secs_f64(120.0)
        );
        assert_eq!(
            socket_deadline(0.5, MIN_SOCKET_DEADLINE),
            Duration::from_secs_f64(MIN_SOCKET_DEADLINE)
        );
        // unconfigured: liveness backstop only
        assert_eq!(
            socket_deadline(0.0, MIN_SOCKET_DEADLINE),
            Duration::from_secs_f64(DEFAULT_SOCKET_DEADLINE)
        );
    }

    #[test]
    fn socket_deadline_floor_is_configurable() {
        // a lowered floor lets sub-second deadlines hit real sockets
        // (the induced-timeout tests depend on this)
        assert_eq!(socket_deadline(0.05, 0.2), Duration::from_secs_f64(0.2));
        assert_eq!(socket_deadline(0.5, 0.2), Duration::from_secs_f64(0.5));
        // a raised floor wins even over the unconfigured backstop
        assert_eq!(socket_deadline(0.0, 900.0), Duration::from_secs_f64(900.0));
        // degenerate floors degrade to the historical clamp
        assert_eq!(
            socket_deadline(0.5, 0.0),
            Duration::from_secs_f64(MIN_SOCKET_DEADLINE)
        );
        assert_eq!(
            socket_deadline(0.5, f64::NAN),
            Duration::from_secs_f64(MIN_SOCKET_DEADLINE)
        );
    }
}
