//! `Array` ⇄ `xla::Literal` conversion.
//!
//! Arrays are row-major; XLA literals use the default (major-to-minor
//! descending) layout, which matches row-major for `vec1().reshape(...)`.
//! Rank-0 tensors go through `Literal::scalar`.

use crate::data::Array;

/// Convert a typed array into an XLA literal of the same shape/dtype.
pub fn array_to_literal(a: &Array) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = a.shape().iter().map(|&d| d as i64).collect();
    let lit = match a {
        Array::F32 { data, .. } => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            xla::Literal::vec1(data)
        }
        Array::I32 { data, .. } => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(data[0]));
            }
            xla::Literal::vec1(data)
        }
    };
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e}"))
}

/// Convert an XLA literal back into a typed array.
pub fn literal_to_array(lit: &xla::Literal) -> anyhow::Result<Array> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.element_type() {
        xla::ElementType::F32 => {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal f32 data: {e}"))?;
            Ok(Array::f32(&dims, data))
        }
        xla::ElementType::S32 => {
            let data = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("literal i32 data: {e}"))?;
            Ok(Array::i32(&dims, data))
        }
        other => anyhow::bail!("unsupported literal element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let a = Array::f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = array_to_literal(&a).unwrap();
        let back = literal_to_array(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32().unwrap(), a.as_f32().unwrap());
    }

    #[test]
    fn i32_roundtrip() {
        let a = Array::i32(&[4], vec![-1, 0, 7, 100]);
        let lit = array_to_literal(&a).unwrap();
        let back = literal_to_array(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), a.as_i32().unwrap());
    }

    #[test]
    fn scalar_roundtrip() {
        let a = Array::f32(&[], vec![2.5]);
        let lit = array_to_literal(&a).unwrap();
        let back = literal_to_array(&lit).unwrap();
        assert_eq!(back.shape(), &[] as &[usize]);
        assert_eq!(back.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn rank3_layout_preserved() {
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let a = Array::f32(&[2, 3, 4], data.clone());
        let lit = array_to_literal(&a).unwrap();
        let back = literal_to_array(&lit).unwrap();
        assert_eq!(back.as_f32().unwrap(), &data[..]);
    }
}
