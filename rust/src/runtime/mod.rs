//! PJRT runtime: load AOT HLO-text artifacts and execute them (Layer 2/1
//! entry point from rust).
//!
//! The flow, adapted from `/opt/xla-example/load_hlo`:
//! `HloModuleProto::from_text_file` (text, *not* serialized proto — see
//! `python/compile/aot.py`) → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Compiled executables are cached per
//! artifact; all lowered functions return tuples (`return_tuple=True`), so
//! outputs are unwrapped with `Literal::to_tuple`.

pub mod artifact;
pub mod literal;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::data::Array;
pub use artifact::{ArtifactMeta, IoSpec, Manifest};

/// The PJRT execution engine: client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    root: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// PJRT CPU execute is internally threaded; serialize submissions to
    /// keep profiles stable (relaxed in the perf pass if beneficial).
    exec_lock: Mutex<()>,
}

// xla handles are thread-safe to share behind our own locks.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifacts directory (expects `manifest.json` inside).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(root.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            root,
            cache: Mutex::new(HashMap::new()),
            exec_lock: Mutex::new(()),
        })
    }

    /// Fetch (compiling + caching on first use) an artifact's executable.
    pub fn executable(
        &self,
        variant: &str,
        name: &str,
    ) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{variant}/{name}");
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(Arc::clone(e));
        }
        let meta = self.manifest.artifact(variant, name)?;
        let path = self.root.join(&meta.path);
        log::debug!("compiling artifact {key} from {}", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {key}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {key}: {e}"))?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(key, Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact with typed arrays, verifying shapes/dtypes
    /// against the manifest, and decode all tuple outputs.
    pub fn run(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
    ) -> anyhow::Result<Vec<Array>> {
        let meta = self.manifest.artifact(variant, name)?.clone();
        meta.check_inputs(inputs)
            .map_err(|e| anyhow::anyhow!("{variant}/{name}: {e}"))?;
        let exe = self.executable(variant, name)?;
        // Host->device transfer via owned PjRtBuffers + execute_b. The
        // crate's `execute(Literal)` path leaks every input device buffer
        // (xla_rs.cc `buffer.release()` without a matching free): at
        // FEMNIST scale that is ~9 MB per client-step, which OOMs long
        // runs. Owning the buffers ourselves both fixes the leak and
        // skips one host-side copy (§Perf).
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|a| {
                match a {
                    Array::F32 { shape, data } => {
                        self.client.buffer_from_host_buffer::<f32>(data, shape, None)
                    }
                    Array::I32 { shape, data } => {
                        self.client.buffer_from_host_buffer::<i32>(data, shape, None)
                    }
                }
                .map_err(|e| anyhow::anyhow!("upload input for {variant}/{name}: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;
        let result = {
            let _g = self.exec_lock.lock().unwrap();
            exe.execute_b::<xla::PjRtBuffer>(&buffers)
                .map_err(|e| anyhow::anyhow!("execute {variant}/{name}: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {variant}/{name}: {e}"))?
        };
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {variant}/{name}: {e}"))?;
        anyhow::ensure!(
            parts.len() == meta.outputs.len(),
            "{variant}/{name}: got {} outputs, manifest says {}",
            parts.len(),
            meta.outputs.len()
        );
        parts.iter().map(literal::literal_to_array).collect()
    }

    /// Warm the cache for a set of artifacts (measures compile time).
    pub fn precompile(&self, variant: &str, names: &[&str]) -> anyhow::Result<f64> {
        let t0 = std::time::Instant::now();
        for n in names {
            self.executable(variant, n)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
