//! Model-execution runtime behind the coordinator.
//!
//! Two backends share one [`Runtime`] front:
//!
//! * **Native** (always available) — a pure-rust reference engine
//!   ([`native`]) that executes the built-in split-MLP family
//!   (`<task>_<preset>` over FEMNIST / SO tag / SO NWP, see
//!   [`native::NativeModelCfg::registry`]) through the tiled
//!   deterministic kernels in [`crate::tensor::gemm`]. It needs no
//!   artifacts directory, which is what lets CI build, test, and
//!   smoke-train the full round loop from a fresh clone.
//! * **PJRT** (cargo feature `pjrt`) — loads AOT HLO-text artifacts and
//!   executes them: `HloModuleProto::from_text_file` (text, *not*
//!   serialized proto — see `python/compile/aot.py`) →
//!   `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//!   Compiled executables are cached per artifact; all lowered functions
//!   return tuples (`return_tuple=True`), so outputs are unwrapped with
//!   `Literal::to_tuple`. The vendored `xla` stub satisfies the build;
//!   executing real artifacts needs the real xla-rs bindings.
//!
//! Both backends are `Send + Sync`: `run` takes `&self` and is called
//! concurrently from the cohort worker threads.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod literal;
pub mod native;

use std::path::Path;

use crate::data::Array;
pub use artifact::{ArtifactMeta, IoSpec, Manifest};

/// Special artifacts-dir spelling that selects the native engine.
pub const NATIVE_ARTIFACTS: &str = "native";

/// The execution engine: backend + manifest (the single source of truth
/// for artifact shapes, whichever backend provides it).
pub struct Runtime {
    pub manifest: Manifest,
    backend: Backend,
}

enum Backend {
    Native(native::NativeEngine),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

impl Runtime {
    /// The built-in native engine (no artifacts directory needed).
    pub fn native() -> Runtime {
        let engine = native::NativeEngine::new();
        Runtime { manifest: engine.manifest(), backend: Backend::Native(engine) }
    }

    /// Open an artifacts directory (expects `manifest.json` inside), or
    /// the native engine when `artifacts_dir` is exactly
    /// [`NATIVE_ARTIFACTS`] (`"native"`). A real directory that happens
    /// to be named `native` can still be loaded as `"./native"`.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let root = artifacts_dir.as_ref();
        if root.to_str() == Some(NATIVE_ARTIFACTS) {
            return Ok(Runtime::native());
        }
        #[cfg(feature = "pjrt")]
        {
            let backend = pjrt::PjrtBackend::open(root)?;
            let manifest = backend.manifest.clone();
            Ok(Runtime { manifest, backend: Backend::Pjrt(backend) })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            anyhow::bail!(
                "artifacts dir '{}' needs the PJRT runtime, but this binary was \
                 built without the `pjrt` feature; rebuild with \
                 `--features pjrt` (and the real xla-rs bindings) or use the \
                 native engine (`--preset tiny` / artifacts dir 'native')",
                root.display()
            )
        }
    }

    /// Execute an artifact with typed arrays, verifying shapes/dtypes
    /// against the manifest, and decode all outputs.
    pub fn run(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
    ) -> anyhow::Result<Vec<Array>> {
        self.run_scratch(variant, name, inputs, &mut native::EngineScratch::default())
    }

    /// [`Runtime::run`] against a caller-owned [`native::EngineScratch`]:
    /// on the native backend the engine's intermediate buffers come from
    /// (and stay in) `scratch`, so a warm scratch makes repeated calls
    /// allocation-quiet (the trainers lend one per cohort slot from the
    /// round engine's scratch pool). The PJRT backend ignores the scratch
    /// — the device boundary allocates regardless.
    pub fn run_scratch(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
        scratch: &mut native::EngineScratch,
    ) -> anyhow::Result<Vec<Array>> {
        let meta = self.manifest.artifact(variant, name)?;
        meta.check_inputs(inputs)
            .map_err(|e| anyhow::anyhow!("{variant}/{name}: {e}"))?;
        let outs = match &self.backend {
            Backend::Native(engine) => engine.run_scratch(variant, name, inputs, scratch)?,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(backend) => backend.run(variant, name, inputs)?,
        };
        anyhow::ensure!(
            outs.len() == meta.outputs.len(),
            "{variant}/{name}: got {} outputs, manifest says {}",
            outs.len(),
            meta.outputs.len()
        );
        Ok(outs)
    }

    /// Warm the backend for a set of artifacts (measures compile time on
    /// the PJRT path; validates artifact names on the native path).
    pub fn precompile(&self, variant: &str, names: &[&str]) -> anyhow::Result<f64> {
        let t0 = std::time::Instant::now();
        for n in names {
            match &self.backend {
                Backend::Native(_) => {
                    self.manifest.artifact(variant, n)?;
                }
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(backend) => {
                    backend.executable(variant, n)?;
                }
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native(_) => "native-cpu".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(backend) => backend.platform(),
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    //! The PJRT artifact backend (moved verbatim from the pre-workspace
    //! `Runtime`; see the module docs above for the execution flow).

    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    use crate::data::Array;
    use crate::runtime::literal;
    use crate::runtime::Manifest;

    pub struct PjrtBackend {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        root: PathBuf,
        cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
        /// PJRT CPU execute is internally threaded; serialize submissions
        /// to keep profiles stable (relaxed in the perf pass if
        /// beneficial).
        exec_lock: Mutex<()>,
    }

    // xla handles are thread-safe to share behind our own locks.
    unsafe impl Send for PjrtBackend {}
    unsafe impl Sync for PjrtBackend {}

    impl PjrtBackend {
        pub fn open(root: &Path) -> anyhow::Result<PjrtBackend> {
            let root = root.to_path_buf();
            let manifest = Manifest::load(root.join("manifest.json"))?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
            Ok(PjrtBackend {
                client,
                manifest,
                root,
                cache: Mutex::new(HashMap::new()),
                exec_lock: Mutex::new(()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Fetch (compiling + caching on first use) an artifact's
        /// executable.
        pub fn executable(
            &self,
            variant: &str,
            name: &str,
        ) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
            let key = format!("{variant}/{name}");
            if let Some(e) = self.cache.lock().unwrap().get(&key) {
                return Ok(Arc::clone(e));
            }
            let meta = self.manifest.artifact(variant, name)?;
            let path = self.root.join(&meta.path);
            log::debug!("compiling artifact {key} from {}", path.display());
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow::anyhow!("parse {key}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {key}: {e}"))?;
            let exe = Arc::new(exe);
            self.cache.lock().unwrap().insert(key, Arc::clone(&exe));
            Ok(exe)
        }

        pub fn run(
            &self,
            variant: &str,
            name: &str,
            inputs: &[Array],
        ) -> anyhow::Result<Vec<Array>> {
            let exe = self.executable(variant, name)?;
            // Host->device transfer via owned PjRtBuffers + execute_b. The
            // crate's `execute(Literal)` path leaks every input device
            // buffer (xla_rs.cc `buffer.release()` without a matching
            // free): at FEMNIST scale that is ~9 MB per client-step, which
            // OOMs long runs. Owning the buffers ourselves both fixes the
            // leak and skips one host-side copy (§Perf).
            let buffers: Vec<xla::PjRtBuffer> = inputs
                .iter()
                .map(|a| {
                    match a {
                        Array::F32 { shape, data } => self
                            .client
                            .buffer_from_host_buffer::<f32>(data, shape, None),
                        Array::I32 { shape, data } => self
                            .client
                            .buffer_from_host_buffer::<i32>(data, shape, None),
                    }
                    .map_err(|e| {
                        anyhow::anyhow!("upload input for {variant}/{name}: {e}")
                    })
                })
                .collect::<anyhow::Result<_>>()?;
            let result = {
                let _g = self.exec_lock.lock().unwrap();
                exe.execute_b::<xla::PjRtBuffer>(&buffers)
                    .map_err(|e| anyhow::anyhow!("execute {variant}/{name}: {e}"))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("fetch {variant}/{name}: {e}"))?
            };
            let parts = result
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("untuple {variant}/{name}: {e}"))?;
            parts.iter().map(literal::literal_to_array).collect()
        }
    }
}
