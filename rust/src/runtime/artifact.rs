//! Typed view of `artifacts/manifest.json`.
//!
//! The manifest, written by `python/compile/aot.py`, is the single source
//! of truth for artifact paths, input order/shapes/dtypes/roles, output
//! names, PQ geometries, and model parameter specs. The coordinator never
//! hard-codes a shape: everything flows from here.

use std::collections::HashMap;
use std::path::Path;

use crate::data::Array;
use crate::models::ModelSpec;
use crate::util::json::{self, Value};

/// One input or output slot of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// `f32` or `s32`.
    pub dtype: String,
    /// `param_client` | `param_server` | `data` | `cut` | `grad_cut` | `hyper`.
    pub role: String,
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
    /// PQ geometry for quantizer artifacts (q, r, l, iters, ng, dsub...).
    pub meta: Value,
}

impl ArtifactMeta {
    /// Validate a prepared input list against the manifest.
    pub fn check_inputs(&self, inputs: &[Array]) -> anyhow::Result<()> {
        anyhow::ensure!(
            inputs.len() == self.inputs.len(),
            "expected {} inputs, got {}",
            self.inputs.len(),
            inputs.len()
        );
        for (spec, arr) in self.inputs.iter().zip(inputs) {
            anyhow::ensure!(
                spec.shape == arr.shape(),
                "input '{}': shape {:?} != manifest {:?}",
                spec.name,
                arr.shape(),
                spec.shape
            );
            let dt = match arr {
                Array::F32 { .. } => "f32",
                Array::I32 { .. } => "s32",
            };
            anyhow::ensure!(
                dt == spec.dtype,
                "input '{}': dtype {dt} != manifest {}",
                spec.name,
                spec.dtype
            );
        }
        Ok(())
    }

    /// Index of an output by name.
    pub fn output_index(&self, name: &str) -> anyhow::Result<usize> {
        self.outputs
            .iter()
            .position(|o| o == name)
            .ok_or_else(|| anyhow::anyhow!("artifact {} has no output '{name}'", self.name))
    }
}

/// One task variant: model spec + its artifacts.
#[derive(Clone, Debug)]
pub struct Variant {
    pub spec: ModelSpec,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Variant {
    /// The PQ artifacts available, as (q, l, r) -> artifact name.
    pub fn pq_artifacts(&self) -> Vec<(usize, usize, usize, String)> {
        let mut out = Vec::new();
        for (name, a) in &self.artifacts {
            if !name.starts_with("pq_") {
                continue;
            }
            let (q, l, r) = (
                a.meta.get("q").as_usize().unwrap_or(0),
                a.meta.get("l").as_usize().unwrap_or(0),
                a.meta.get("r").as_usize().unwrap_or(0),
            );
            out.push((q, l, r, name.clone()));
        }
        out.sort();
        out
    }

    /// Find the quantizer artifact matching a PQ config.
    pub fn find_pq(&self, q: usize, l: usize, r: usize) -> Option<&ArtifactMeta> {
        self.artifacts.values().find(|a| {
            a.name.starts_with("pq_")
                && a.meta.get("q").as_usize() == Some(q)
                && a.meta.get("l").as_usize() == Some(l)
                && a.meta.get("r").as_usize() == Some(r)
        })
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub variants: HashMap<String, Variant>,
    pub jax_version: String,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            anyhow::anyhow!(
                "read manifest {}: {e} (run `make artifacts` first)",
                path.as_ref().display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let v = json::parse(text)?;
        let mut variants = HashMap::new();
        let vs = v
            .get("variants")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest missing variants"))?;
        for (vname, vval) in vs.iter() {
            let spec = ModelSpec::from_manifest_variant(vval)?;
            let mut artifacts = HashMap::new();
            if let Some(arts) = vval.get("artifacts").as_obj() {
                for (aname, aval) in arts.iter() {
                    artifacts.insert(aname.clone(), parse_artifact(aname, aval)?);
                }
            }
            variants.insert(vname.clone(), Variant { spec, artifacts });
        }
        Ok(Manifest {
            variants,
            jax_version: v.get("jax_version").as_str().unwrap_or("?").to_string(),
        })
    }

    pub fn variant(&self, name: &str) -> anyhow::Result<&Variant> {
        self.variants.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "variant '{name}' not in manifest (have: {:?})",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact(&self, variant: &str, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.variant(variant)?.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!("artifact '{name}' not in variant '{variant}'")
        })
    }
}

fn parse_artifact(name: &str, v: &Value) -> anyhow::Result<ArtifactMeta> {
    let inputs = v
        .get("inputs")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("artifact {name}: no inputs"))?
        .iter()
        .map(|i| {
            Ok(IoSpec {
                name: i.get("name").as_str().unwrap_or_default().to_string(),
                shape: i
                    .get("shape")
                    .as_usize_vec()
                    .ok_or_else(|| anyhow::anyhow!("artifact {name}: bad input shape"))?,
                dtype: i.get("dtype").as_str().unwrap_or("f32").to_string(),
                role: i.get("role").as_str().unwrap_or("data").to_string(),
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let outputs = v
        .get("outputs")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|o| o.as_str().map(str::to_string))
        .collect();
    Ok(ArtifactMeta {
        name: name.to_string(),
        path: v
            .get("path")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("artifact {name}: no path"))?
            .to_string(),
        inputs,
        outputs,
        meta: v.get("meta").clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax_version": "0.8.2",
      "variants": {
        "toy_small": {
          "task": "toy", "preset": "small",
          "config": {"batch": 4, "eval_batch": 8},
          "cut_dim": 16, "act_batch": 4,
          "client_params": [
            {"name": "w", "shape": [2, 16], "init": "glorot_uniform",
             "scale": 1.0, "fan_in": 2, "fan_out": 16}
          ],
          "server_params": [
            {"name": "v", "shape": [16, 3], "init": "glorot_uniform",
             "scale": 1.0, "fan_in": 16, "fan_out": 3}
          ],
          "client_param_count": 32, "server_param_count": 48,
          "metrics": ["correct"],
          "client_args": ["x"], "server_args": ["y"],
          "artifacts": {
            "client_fwd": {
              "path": "toy_small/client_fwd.hlo.txt",
              "inputs": [
                {"name": "w", "shape": [2, 16], "dtype": "f32", "role": "param_client"},
                {"name": "x", "shape": [4, 2], "dtype": "f32", "role": "data"}
              ],
              "outputs": ["z"], "meta": {}
            },
            "pq_q4_L2_R1": {
              "path": "toy_small/pq.hlo.txt",
              "inputs": [
                {"name": "z", "shape": [4, 16], "dtype": "f32", "role": "cut"},
                {"name": "init_centroids", "shape": [1, 2, 4], "dtype": "f32", "role": "data"}
              ],
              "outputs": ["codebooks", "codes", "z_tilde", "qerr"],
              "meta": {"q": 4, "l": 2, "r": 1, "iters": 8, "dsub": 4, "ng": 16,
                       "act_batch": 4, "d": 16}
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = m.variant("toy_small").unwrap();
        assert_eq!(v.spec.cut_dim, 16);
        assert_eq!(v.spec.client.numel(), 32);
        let a = m.artifact("toy_small", "client_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].role, "param_client");
        assert_eq!(a.output_index("z").unwrap(), 0);
        assert!(a.output_index("nope").is_err());
        assert!(m.variant("missing").is_err());
    }

    #[test]
    fn input_checking() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("toy_small", "client_fwd").unwrap();
        let good = vec![
            Array::f32(&[2, 16], vec![0.0; 32]),
            Array::f32(&[4, 2], vec![0.0; 8]),
        ];
        assert!(a.check_inputs(&good).is_ok());
        let bad_shape = vec![
            Array::f32(&[2, 16], vec![0.0; 32]),
            Array::f32(&[4, 3], vec![0.0; 12]),
        ];
        assert!(a.check_inputs(&bad_shape).is_err());
        let bad_dtype = vec![
            Array::f32(&[2, 16], vec![0.0; 32]),
            Array::i32(&[4, 2], vec![0; 8]),
        ];
        assert!(a.check_inputs(&bad_dtype).is_err());
        assert!(a.check_inputs(&good[..1]).is_err());
    }

    #[test]
    fn pq_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = m.variant("toy_small").unwrap();
        assert!(v.find_pq(4, 2, 1).is_some());
        assert!(v.find_pq(4, 8, 1).is_none());
        let list = v.pq_artifacts();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].0, 4);
    }
}
