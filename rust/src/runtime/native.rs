//! Native reference engine: a parameterized family of pure-rust split MLPs.
//!
//! Implements the same artifact contract the PJRT backend serves —
//! `client_fwd`, `server_step`, `client_bwd`, `full_grad`, `full_eval`
//! with manifest-declared input order/shapes/roles — for the built-in
//! [`NativeModelCfg::registry`] variants, so the full round state
//! machines (SplitFed / FedLite / FedAvg) run from a fresh clone with no
//! Python lowering step and no XLA toolchain. CI's build/test/smoke jobs
//! and the workers-invariance determinism tests execute through this
//! engine.
//!
//! Model shape (every variant): client = dense(input→cut) + ReLU (the
//! cut layer); server = dense(cut→hidden) + ReLU + dense(hidden→classes)
//! + softmax cross-entropy, `correct`-count metric. Gradient correction
//! (paper eq. (5)) is applied in `client_bwd`: the client loss term
//! λ/2·‖z − z~‖² contributes λ·(z − z~) to the gradient at the cut.
//!
//! Registered variants (`femnist_<preset>`; all consume the synthetic
//! FEMNIST data, x `[B, 28, 28, 1]`, 62 classes):
//!
//! | preset | cut | hidden | batch | eval_batch | role |
//! |---|---|---|---|---|---|
//! | `tiny` | 32 | 32 | 8 | 32 | CI smoke / golden fixtures (bits unchanged) |
//! | `small` | 64 | 128 | 32 | 64 | realistic batch, wider cut |
//! | `stress` | 1152 | 256 | 8 | 16 | paper-scale cut width (the q=1152 PQ geometry) |
//!
//! All dense math runs through the tiled deterministic kernels in
//! [`crate::tensor::gemm`] — bit-identical to the naive triple loops by
//! construction (see that module's exactness contract), so the `tiny`
//! golden fixtures reproduce exactly with tiling enabled. Every reduction
//! has a fixed order and `run` takes `&self`, so outputs are
//! bit-identical regardless of how many cohort workers call `run`
//! concurrently.
//!
//! The zero-allocation steady state mirrors the quantizer's (PR 4): an
//! [`EngineScratch`] arena holds every intermediate (zpre/z/h1pre/h1/
//! logits/grad buffers); [`NativeEngine::run_scratch`] and the public
//! `*_into` compute layer reuse it across calls, so after warm-up the
//! compute path performs no heap allocation (`rust/tests/alloc.rs`
//! audits the combined compute+quantize client path). The `Vec<Array>`
//! outputs of the `run` contract still allocate — that boundary is the
//! runtime API, not the kernels.

use std::collections::HashMap;

use crate::data::Array;
use crate::models::{ModelSpec, ParamSpec, SideSpec};
use crate::runtime::artifact::{ArtifactMeta, IoSpec, Manifest, Variant};
use crate::tensor::gemm::{self, GemmPolicy};
use crate::util::json::{Object, Value};

/// The historical single-variant key (the `tiny` preset); kept for the
/// golden fixtures and tests that pin it.
pub const VARIANT: &str = "femnist_tiny";

/// Dimensions of one native split-MLP variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NativeModelCfg {
    /// Preset name; the manifest key is `femnist_<preset>`.
    pub preset: &'static str,
    /// Flattened input dim (28·28 — every variant eats FEMNIST images).
    pub input: usize,
    /// Cut-layer width d (what the quantizer sees).
    pub cut: usize,
    /// Server hidden width.
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
}

/// The built-in variant family. `tiny` must stay bit-identical to the
/// pre-family engine (golden fixtures); new variants append here and are
/// picked up by the manifest, the presets, the generalized tests, and
/// `bench_engine` automatically.
const REGISTRY: &[NativeModelCfg] = &[
    NativeModelCfg {
        preset: "tiny",
        input: 28 * 28,
        cut: 32,
        hidden: 32,
        classes: 62,
        batch: 8,
        eval_batch: 32,
    },
    NativeModelCfg {
        preset: "small",
        input: 28 * 28,
        cut: 64,
        hidden: 128,
        classes: 62,
        batch: 32,
        eval_batch: 64,
    },
    NativeModelCfg {
        preset: "stress",
        input: 28 * 28,
        cut: 1152,
        hidden: 256,
        classes: 62,
        batch: 8,
        eval_batch: 16,
    },
];

impl NativeModelCfg {
    /// Every variant the native engine serves.
    pub fn registry() -> &'static [NativeModelCfg] {
        REGISTRY
    }

    /// Manifest key for this variant.
    pub fn variant_key(&self) -> String {
        format!("femnist_{}", self.preset)
    }

    /// Look a variant up by manifest key (`femnist_<preset>`).
    pub fn by_variant(variant: &str) -> Option<&'static NativeModelCfg> {
        REGISTRY.iter().find(|c| c.variant_key() == variant)
    }

    /// Look a variant up by preset name (`tiny` / `small` / `stress`).
    pub fn by_preset(preset: &str) -> Option<&'static NativeModelCfg> {
        REGISTRY.iter().find(|c| c.preset == preset)
    }
}

/// Reusable buffers for the engine's compute path: every intermediate of
/// the forward/backward passes, sized on first use and reused after
/// (capacities only grow; `rust/tests/alloc.rs` asserts the warm path
/// allocates nothing). Lent per cohort slot from the round engine's
/// `RoundAlgorithm::Scratch` pool, so the steady state holds across
/// rounds and attempts.
#[derive(Default)]
pub struct EngineScratch {
    /// Client pre-activation `[m, cut]`.
    pub zpre: Vec<f32>,
    /// Client cut activation `[m, cut]`.
    pub z: Vec<f32>,
    /// Server hidden pre-activation `[m, hidden]`.
    pub h1pre: Vec<f32>,
    /// Server hidden activation `[m, hidden]`.
    pub h1: Vec<f32>,
    /// Logits `[m, classes]`.
    pub logits: Vec<f32>,
    /// d(mean loss)/d(logits) `[m, classes]`.
    pub glogits: Vec<f32>,
    /// Gradient at the cut `[m, cut]` (server's grad_z, client's
    /// corrected gz).
    pub gz: Vec<f32>,
    /// Gradient at the server hidden layer `[m, hidden]`.
    pub dh1: Vec<f32>,
    pub g_w1: Vec<f32>,
    pub g_b1: Vec<f32>,
    pub g_w2: Vec<f32>,
    pub g_b2: Vec<f32>,
    pub g_w3: Vec<f32>,
    pub g_b3: Vec<f32>,
}

impl EngineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize every buffer for a pass of `m` rows through `cfg`. Lengths
    /// are exact (kernels assert them); capacities only ever grow.
    pub fn prepare(&mut self, cfg: &NativeModelCfg, m: usize) {
        self.zpre.resize(m * cfg.cut, 0.0);
        self.z.resize(m * cfg.cut, 0.0);
        self.h1pre.resize(m * cfg.hidden, 0.0);
        self.h1.resize(m * cfg.hidden, 0.0);
        self.logits.resize(m * cfg.classes, 0.0);
        self.glogits.resize(m * cfg.classes, 0.0);
        self.gz.resize(m * cfg.cut, 0.0);
        self.dh1.resize(m * cfg.hidden, 0.0);
        self.g_w1.resize(cfg.input * cfg.cut, 0.0);
        self.g_b1.resize(cfg.cut, 0.0);
        self.g_w2.resize(cfg.cut * cfg.hidden, 0.0);
        self.g_b2.resize(cfg.hidden, 0.0);
        self.g_w3.resize(cfg.hidden * cfg.classes, 0.0);
        self.g_b3.resize(cfg.classes, 0.0);
    }

    /// Capacity fingerprint (pointer + capacity per buffer) — the
    /// alloc/scratch-stability tests assert it is stable across
    /// same-shape reuse.
    pub fn capacity_fingerprint(&self) -> Vec<(usize, usize)> {
        [
            &self.zpre, &self.z, &self.h1pre, &self.h1, &self.logits, &self.glogits,
            &self.gz, &self.dh1, &self.g_w1, &self.g_b1, &self.g_w2, &self.g_b2,
            &self.g_w3, &self.g_b3,
        ]
        .iter()
        .map(|v| (v.as_ptr() as usize, v.capacity()))
        .collect()
    }
}

/// Stateless executor for the built-in variant family.
pub struct NativeEngine {
    policy: GemmPolicy,
}

impl NativeEngine {
    /// Tiled serial kernels — the coordinator default (the round engine
    /// already fans out over clients; nested threads would oversubscribe).
    pub fn new() -> NativeEngine {
        NativeEngine::with_policy(GemmPolicy::tiled())
    }

    /// Engine with an explicit kernel policy (benches compare naive vs
    /// tiled vs tiled+parallel; all three are bit-identical).
    pub fn with_policy(policy: GemmPolicy) -> NativeEngine {
        NativeEngine { policy }
    }

    pub fn policy(&self) -> GemmPolicy {
        self.policy
    }

    /// Synthesize the manifest the artifacts directory would otherwise
    /// provide: one variant per registry entry. Input order here is the
    /// assembly order — it must match the indexing in
    /// [`NativeEngine::run_scratch`].
    pub fn manifest(&self) -> Manifest {
        let mut variants = HashMap::new();
        for cfg in NativeModelCfg::registry() {
            variants.insert(cfg.variant_key(), variant_for(cfg));
        }
        Manifest { variants, jax_version: "native".to_string() }
    }

    /// Execute one artifact with a throwaway scratch. Inputs were already
    /// checked against the manifest by [`crate::runtime::Runtime::run`].
    pub fn run(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
    ) -> anyhow::Result<Vec<Array>> {
        let mut scratch = EngineScratch::default();
        self.run_scratch(variant, name, inputs, &mut scratch)
    }

    /// Execute one artifact against a caller-owned [`EngineScratch`]: the
    /// steady-state entry point the trainers drive (warm scratch ⇒ the
    /// compute performs no heap allocation; only the output `Array`s
    /// allocate).
    pub fn run_scratch(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
        s: &mut EngineScratch,
    ) -> anyhow::Result<Vec<Array>> {
        let cfg = NativeModelCfg::by_variant(variant).ok_or_else(|| {
            anyhow::anyhow!(
                "native engine has no variant '{variant}' (registered: {:?})",
                NativeModelCfg::registry()
                    .iter()
                    .map(|c| c.variant_key())
                    .collect::<Vec<_>>()
            )
        })?;
        let p = self.policy;
        match name {
            "client_fwd" => {
                let (w1, b1, x) = (f32s(&inputs[0])?, f32s(&inputs[1])?, f32s(&inputs[2])?);
                let m = cfg.batch;
                s.prepare(cfg, m);
                client_fwd_into(cfg, p, w1, b1, x, s);
                Ok(vec![Array::f32(&[m, cfg.cut], s.z.clone())])
            }
            "server_step" => {
                let (w2, b2, w3, b3) = (
                    f32s(&inputs[0])?,
                    f32s(&inputs[1])?,
                    f32s(&inputs[2])?,
                    f32s(&inputs[3])?,
                );
                let y = i32s(&inputs[4])?;
                let zt = f32s(&inputs[5])?;
                let m = cfg.batch;
                s.prepare(cfg, m);
                let (loss, correct) = server_step_into(cfg, p, w2, b2, w3, b3, y, zt, s)?;
                Ok(vec![
                    Array::f32(&[], vec![loss as f32]),
                    Array::f32(&[], vec![correct as f32]),
                    Array::f32(&[m, cfg.cut], s.gz.clone()),
                    Array::f32(&[cfg.cut, cfg.hidden], s.g_w2.clone()),
                    Array::f32(&[cfg.hidden], s.g_b2.clone()),
                    Array::f32(&[cfg.hidden, cfg.classes], s.g_w3.clone()),
                    Array::f32(&[cfg.classes], s.g_b3.clone()),
                ])
            }
            "client_bwd" => {
                let (w1, b1, x) = (f32s(&inputs[0])?, f32s(&inputs[1])?, f32s(&inputs[2])?);
                let zt = f32s(&inputs[3])?;
                let grad_z = f32s(&inputs[4])?;
                let lambda = f32s(&inputs[5])?[0];
                s.prepare(cfg, cfg.batch);
                let qerr = client_bwd_into(cfg, p, w1, b1, x, zt, grad_z, lambda, s);
                Ok(vec![
                    Array::f32(&[cfg.input, cfg.cut], s.g_w1.clone()),
                    Array::f32(&[cfg.cut], s.g_b1.clone()),
                    Array::f32(&[], vec![qerr as f32]),
                ])
            }
            "full_grad" => {
                let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
                let (w2, b2, w3, b3) = (
                    f32s(&inputs[2])?,
                    f32s(&inputs[3])?,
                    f32s(&inputs[4])?,
                    f32s(&inputs[5])?,
                );
                let x = f32s(&inputs[6])?;
                let y = i32s(&inputs[7])?;
                s.prepare(cfg, cfg.batch);
                let (loss, correct) =
                    full_grad_into(cfg, p, w1, b1, w2, b2, w3, b3, x, y, s)?;
                Ok(vec![
                    Array::f32(&[], vec![loss as f32]),
                    Array::f32(&[], vec![correct as f32]),
                    Array::f32(&[cfg.input, cfg.cut], s.g_w1.clone()),
                    Array::f32(&[cfg.cut], s.g_b1.clone()),
                    Array::f32(&[cfg.cut, cfg.hidden], s.g_w2.clone()),
                    Array::f32(&[cfg.hidden], s.g_b2.clone()),
                    Array::f32(&[cfg.hidden, cfg.classes], s.g_w3.clone()),
                    Array::f32(&[cfg.classes], s.g_b3.clone()),
                ])
            }
            "full_eval" => {
                let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
                let (w2, b2, w3, b3) = (
                    f32s(&inputs[2])?,
                    f32s(&inputs[3])?,
                    f32s(&inputs[4])?,
                    f32s(&inputs[5])?,
                );
                let x = f32s(&inputs[6])?;
                let y = i32s(&inputs[7])?;
                let m = cfg.eval_batch;
                s.prepare(cfg, m);
                let (loss, correct) =
                    full_eval_into(cfg, p, w1, b1, w2, b2, w3, b3, x, y, m, s)?;
                Ok(vec![
                    Array::f32(&[], vec![loss as f32]),
                    Array::f32(&[], vec![correct as f32]),
                ])
            }
            other => anyhow::bail!("native engine has no artifact '{other}'"),
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new()
    }
}

// -- the compute layer (public: benches and the alloc audit drive it) --------
//
// Each `*_into` fills `EngineScratch` buffers prepared by the caller at
// the right batch size and allocates nothing. `anyhow` is only touched on
// error paths (label validation), so the Ok path stays allocation-free.

/// Client forward: `zpre = x @ w1 + b1`, `z = relu(zpre)` (`m = batch`).
pub fn client_fwd_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    x: &[f32],
    s: &mut EngineScratch,
) {
    let m = s.zpre.len() / cfg.cut;
    gemm::dense_into(x, w1, b1, m, cfg.input, cfg.cut, &mut s.zpre, p);
    relu_into(&s.zpre, &mut s.z);
}

/// Borrowed server-side buffers for [`server_pass`], split out of
/// [`EngineScratch`] so that `full_grad_into` can lend its
/// scratch-resident `z` as the cut input while the rest of the arena is
/// mutably lent.
struct ServerBufs<'a> {
    h1pre: &'a mut [f32],
    h1: &'a mut [f32],
    logits: &'a mut [f32],
    glogits: &'a mut [f32],
    dh1: &'a mut [f32],
    gz: &'a mut [f32],
    g_w2: &'a mut [f32],
    g_b2: &'a mut [f32],
    g_w3: &'a mut [f32],
    g_b3: &'a mut [f32],
}

/// The server forward + loss + backward sequence, shared verbatim by
/// [`server_step_into`] and [`full_grad_into`] — one copy, so the
/// split-vs-monolithic exactness contract has a single source of truth.
#[allow(clippy::too_many_arguments)]
fn server_pass(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    y: &[i32],
    zt: &[f32],
    m: usize,
    b: ServerBufs<'_>,
) -> anyhow::Result<(f64, f64)> {
    let ServerBufs { h1pre, h1, logits, glogits, dh1, gz, g_w2, g_b2, g_w3, g_b3 } = b;
    // forward
    gemm::dense_into(zt, w2, b2, m, cfg.cut, cfg.hidden, h1pre, p);
    relu_into(h1pre, h1);
    gemm::dense_into(h1, w3, b3, m, cfg.hidden, cfg.classes, logits, p);
    let (loss, correct) = softmax_ce_into(logits, y, m, cfg.classes, glogits)?;
    // backward
    gemm::matmul_at_b_into(h1, glogits, m, cfg.hidden, cfg.classes, g_w3, p);
    gemm::colsum_into(glogits, m, cfg.classes, g_b3);
    gemm::matmul_a_bt_into(glogits, w3, m, cfg.classes, cfg.hidden, dh1, p);
    relu_backward(dh1, h1pre);
    gemm::matmul_at_b_into(zt, dh1, m, cfg.cut, cfg.hidden, g_w2, p);
    gemm::colsum_into(dh1, m, cfg.hidden, g_b2);
    gemm::matmul_a_bt_into(dh1, w2, m, cfg.hidden, cfg.cut, gz, p);
    Ok((loss, correct))
}

/// Server forward + loss + backward off the (possibly quantized) cut
/// activations `zt`. Fills `gz` (grad at the cut) and the server grads;
/// returns `(mean loss, correct count)`. Errors on an out-of-range label.
#[allow(clippy::too_many_arguments)]
pub fn server_step_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    y: &[i32],
    zt: &[f32],
    s: &mut EngineScratch,
) -> anyhow::Result<(f64, f64)> {
    let m = s.h1pre.len() / cfg.hidden;
    let bufs = ServerBufs {
        h1pre: &mut s.h1pre,
        h1: &mut s.h1,
        logits: &mut s.logits,
        glogits: &mut s.glogits,
        dh1: &mut s.dh1,
        gz: &mut s.gz,
        g_w2: &mut s.g_w2,
        g_b2: &mut s.g_b2,
        g_w3: &mut s.g_w3,
        g_b3: &mut s.g_b3,
    };
    server_pass(cfg, p, w2, b2, w3, b3, y, zt, m, bufs)
}

/// Client backward with the gradient correction (eq. (5)): recompute the
/// forward, add `λ·(z − z~)` to the returned cut gradient, backprop to
/// the client weights. Fills `g_w1`/`g_b1`; returns the squared
/// correction error `‖z − z~‖²`.
#[allow(clippy::too_many_arguments)]
pub fn client_bwd_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    x: &[f32],
    zt: &[f32],
    grad_z: &[f32],
    lambda: f32,
    s: &mut EngineScratch,
) -> f64 {
    let m = s.zpre.len() / cfg.cut;
    client_fwd_into(cfg, p, w1, b1, x, s);
    // gradient correction (eq. (5)): d/dz [λ/2 ‖z − z~‖²] = λ (z − z~)
    let mut qerr = 0.0f64;
    for i in 0..m * cfg.cut {
        let diff = s.z[i] - zt[i];
        qerr += (diff as f64) * (diff as f64);
        s.gz[i] = grad_z[i] + lambda * diff;
    }
    relu_backward(&mut s.gz, &s.zpre);
    gemm::matmul_at_b_into(x, &s.gz, m, cfg.input, cfg.cut, &mut s.g_w1, p);
    gemm::colsum_into(&s.gz, m, cfg.cut, &mut s.g_b1);
    qerr
}

/// Monolithic forward+backward: identical composition to the split path
/// with `z~ = z` and `λ = 0`, so split-vs-monolithic agreement is exact
/// by construction. Fills every gradient buffer; returns (loss, correct).
#[allow(clippy::too_many_arguments)]
pub fn full_grad_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    x: &[f32],
    y: &[i32],
    s: &mut EngineScratch,
) -> anyhow::Result<(f64, f64)> {
    let m = s.zpre.len() / cfg.cut;
    client_fwd_into(cfg, p, w1, b1, x, s);
    // destructure the arena to split the borrows: the scratch-resident z
    // is lent to the server pass as zt while gz/h1*/logits are mutably
    // lent, exactly the server_step_into sequence (one copy of the math)
    let EngineScratch {
        zpre, z, h1pre, h1, logits, glogits, gz, dh1,
        g_w1, g_b1, g_w2, g_b2, g_w3, g_b3,
    } = s;
    let bufs = ServerBufs {
        h1pre,
        h1,
        logits,
        glogits,
        dh1,
        gz: &mut gz[..],
        g_w2,
        g_b2,
        g_w3,
        g_b3,
    };
    let (loss, correct) = server_pass(cfg, p, w2, b2, w3, b3, y, z, m, bufs)?;
    relu_backward(gz, zpre);
    gemm::matmul_at_b_into(x, gz, m, cfg.input, cfg.cut, g_w1, p);
    gemm::colsum_into(gz, m, cfg.cut, g_b1);
    Ok((loss, correct))
}

/// Forward-only eval over `m` rows; returns (loss, correct). The loss
/// gradient is still computed into the scratch (same arithmetic as the
/// historical engine) but unused.
#[allow(clippy::too_many_arguments)]
pub fn full_eval_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    x: &[f32],
    y: &[i32],
    m: usize,
    s: &mut EngineScratch,
) -> anyhow::Result<(f64, f64)> {
    gemm::dense_into(x, w1, b1, m, cfg.input, cfg.cut, &mut s.zpre, p);
    relu_into(&s.zpre, &mut s.z);
    gemm::dense_into(&s.z, w2, b2, m, cfg.cut, cfg.hidden, &mut s.h1pre, p);
    relu_into(&s.h1pre, &mut s.h1);
    gemm::dense_into(&s.h1, w3, b3, m, cfg.hidden, cfg.classes, &mut s.logits, p);
    softmax_ce_into(&s.logits, y, m, cfg.classes, &mut s.glogits)
}

// -- manifest construction ---------------------------------------------------

fn variant_for(cfg: &NativeModelCfg) -> Variant {
    let x = |b: usize| io("x", &[b, 28, 28, 1], "f32", "data");
    let y = |b: usize| io("y", &[b], "s32", "data");
    let client_params = || {
        vec![
            io("w1", &[cfg.input, cfg.cut], "f32", "param_client"),
            io("b1", &[cfg.cut], "f32", "param_client"),
        ]
    };
    let server_params = || {
        vec![
            io("w2", &[cfg.cut, cfg.hidden], "f32", "param_server"),
            io("b2", &[cfg.hidden], "f32", "param_server"),
            io("w3", &[cfg.hidden, cfg.classes], "f32", "param_server"),
            io("b3", &[cfg.classes], "f32", "param_server"),
        ]
    };

    let mut artifacts = HashMap::new();
    let mut add = |meta: ArtifactMeta| {
        artifacts.insert(meta.name.clone(), meta);
    };
    let mut inputs = client_params();
    inputs.push(x(cfg.batch));
    add(art("client_fwd", inputs, &["z"]));

    let mut inputs = server_params();
    inputs.push(y(cfg.batch));
    inputs.push(io("z_tilde", &[cfg.batch, cfg.cut], "f32", "cut"));
    add(art(
        "server_step",
        inputs,
        &["loss", "correct", "grad_z", "g_w2", "g_b2", "g_w3", "g_b3"],
    ));

    let mut inputs = client_params();
    inputs.push(x(cfg.batch));
    inputs.push(io("z_tilde", &[cfg.batch, cfg.cut], "f32", "cut"));
    inputs.push(io("grad_z", &[cfg.batch, cfg.cut], "f32", "grad_cut"));
    inputs.push(io("lambda", &[], "f32", "hyper"));
    add(art("client_bwd", inputs, &["g_w1", "g_b1", "qerr"]));

    let mut inputs = client_params();
    inputs.extend(server_params());
    inputs.push(x(cfg.batch));
    inputs.push(y(cfg.batch));
    add(art(
        "full_grad",
        inputs,
        &[
            "loss", "correct", "g_w1", "g_b1", "g_w2", "g_b2", "g_w3", "g_b3",
        ],
    ));

    let mut inputs = client_params();
    inputs.extend(server_params());
    inputs.push(x(cfg.eval_batch));
    inputs.push(y(cfg.eval_batch));
    add(art("full_eval", inputs, &["loss", "correct"]));

    let mut config = Object::new();
    config.insert("batch", Value::from_usize(cfg.batch));
    config.insert("eval_batch", Value::from_usize(cfg.eval_batch));
    let spec = ModelSpec {
        task: "femnist".to_string(),
        preset: cfg.preset.to_string(),
        cut_dim: cfg.cut,
        act_batch: cfg.batch,
        batch: cfg.batch,
        eval_batch: cfg.eval_batch,
        client: SideSpec {
            params: vec![
                param("w1", &[cfg.input, cfg.cut], "glorot_uniform", cfg.input, cfg.cut),
                param("b1", &[cfg.cut], "zeros", cfg.cut, cfg.cut),
            ],
        },
        server: SideSpec {
            params: vec![
                param("w2", &[cfg.cut, cfg.hidden], "glorot_uniform", cfg.cut, cfg.hidden),
                param("b2", &[cfg.hidden], "zeros", cfg.hidden, cfg.hidden),
                param(
                    "w3",
                    &[cfg.hidden, cfg.classes],
                    "glorot_uniform",
                    cfg.hidden,
                    cfg.classes,
                ),
                param("b3", &[cfg.classes], "zeros", cfg.hidden, cfg.classes),
            ],
        },
        metrics: vec!["correct".to_string()],
        client_args: vec!["x".to_string()],
        server_args: vec!["y".to_string()],
        config: Value::Obj(config),
    };
    Variant { spec, artifacts }
}

fn io(name: &str, shape: &[usize], dtype: &str, role: &str) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
        role: role.to_string(),
    }
}

fn art(name: &str, inputs: Vec<IoSpec>, outputs: &[&str]) -> ArtifactMeta {
    ArtifactMeta {
        name: name.to_string(),
        path: format!("native/{name}"),
        inputs,
        outputs: outputs.iter().map(|o| o.to_string()).collect(),
        meta: Value::Null,
    }
}

fn param(name: &str, shape: &[usize], init: &str, fan_in: usize, fan_out: usize) -> ParamSpec {
    ParamSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        init: init.to_string(),
        scale: 1.0,
        fan_in,
        fan_out,
    }
}

// -- elementwise + loss (fixed reduction order => deterministic) -------------

fn f32s(a: &Array) -> anyhow::Result<&[f32]> {
    a.as_f32().ok_or_else(|| anyhow::anyhow!("expected f32 input"))
}

fn i32s(a: &Array) -> anyhow::Result<&[i32]> {
    a.as_i32().ok_or_else(|| anyhow::anyhow!("expected s32 input"))
}

fn relu_into(z: &[f32], out: &mut [f32]) {
    debug_assert_eq!(z.len(), out.len());
    for (o, &v) in out.iter_mut().zip(z) {
        *o = if v > 0.0 { v } else { 0.0 };
    }
}

/// Zero the gradient wherever the pre-activation was non-positive.
fn relu_backward(grad: &mut [f32], pre: &[f32]) {
    for (g, &p) in grad.iter_mut().zip(pre) {
        if p <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Mean softmax cross-entropy over the batch, gradient written into
/// `grad` (`[m, c]`, fully overwritten). Returns (mean loss,
/// correct-prediction count). Ties in the argmax resolve to the lowest
/// class index (fixed, deterministic). Labels are validated against `c`
/// up front: an out-of-range label is a data bug and surfaces as a
/// proper error, not an index-out-of-bounds panic mid-round.
fn softmax_ce_into(
    logits: &[f32],
    y: &[i32],
    m: usize,
    c: usize,
    grad: &mut [f32],
) -> anyhow::Result<(f64, f64)> {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(grad.len(), m * c);
    anyhow::ensure!(y.len() == m, "got {} labels for a batch of {m}", y.len());
    for (i, &yv) in y.iter().enumerate() {
        anyhow::ensure!(
            yv >= 0 && (yv as usize) < c,
            "label {yv} at row {i} out of range for {c} classes"
        );
    }
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let mut maxv = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                argmax = j;
            }
        }
        let g = &mut grad[i * c..(i + 1) * c];
        let mut sum = 0.0f32;
        for (gv, &v) in g.iter_mut().zip(row) {
            let e = (v - maxv).exp();
            *gv = e;
            sum += e;
        }
        let yi = y[i] as usize;
        loss -= (row[yi] - maxv) as f64 - (sum as f64).ln();
        if argmax == yi {
            correct += 1.0;
        }
        let inv = 1.0 / (sum * m as f32);
        for gv in g.iter_mut() {
            *gv *= inv;
        }
        g[yi] -= 1.0 / m as f32;
    }
    Ok((loss / m as f64, correct))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn rand_inputs(cfg: &NativeModelCfg, seed: u64) -> (Vec<Array>, Vec<Array>) {
        // (full_grad inputs, client_fwd inputs) over shared params/batch
        let rt = Runtime::native();
        let spec = rt.manifest.variant(&cfg.variant_key()).unwrap().spec.clone();
        let rng = Rng::new(seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let mut r = rng.fork(3);
        let x = r.uniform_vec(cfg.batch * cfg.input, 0.0, 1.0);
        let y: Vec<i32> = (0..cfg.batch).map(|_| r.below(cfg.classes) as i32).collect();
        let p = |t: &crate::tensor::Tensor| Array::f32(t.shape(), t.data().to_vec());
        let mut full: Vec<Array> = wc.tensors.iter().map(&p).collect();
        full.extend(ws.tensors.iter().map(&p));
        full.push(Array::f32(&[cfg.batch, 28, 28, 1], x.clone()));
        full.push(Array::i32(&[cfg.batch], y));
        let mut fwd: Vec<Array> = wc.tensors.iter().map(&p).collect();
        fwd.push(Array::f32(&[cfg.batch, 28, 28, 1], x));
        (full, fwd)
    }

    #[test]
    fn manifest_is_complete_and_consistent_for_every_variant() {
        let rt = Runtime::native();
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let v = rt.manifest.variant(&key).unwrap();
            for a in ["client_fwd", "server_step", "client_bwd", "full_grad", "full_eval"] {
                assert!(v.artifacts.contains_key(a), "{key}/{a} missing");
            }
            assert_eq!(v.spec.cut_dim, cfg.cut, "{key}");
            assert_eq!(v.spec.client.numel(), cfg.input * cfg.cut + cfg.cut, "{key}");
            assert_eq!(
                v.spec.server.numel(),
                cfg.cut * cfg.hidden + cfg.hidden + cfg.hidden * cfg.classes + cfg.classes,
                "{key}"
            );
            // param_client/param_server input order matches the SideSpec
            let fwd = v.artifacts.get("client_fwd").unwrap();
            assert_eq!(fwd.inputs[0].name, v.spec.client.params[0].name);
            assert_eq!(fwd.inputs[0].shape, v.spec.client.params[0].shape);
        }
        // the registry still serves the historical key
        assert!(NativeModelCfg::by_variant(VARIANT).is_some());
        assert_eq!(NativeModelCfg::by_preset("tiny").unwrap().cut, 32);
    }

    #[test]
    fn split_composition_equals_full_grad_exactly_on_every_variant() {
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let engine = NativeEngine::new();
            let (full_in, fwd_in) = rand_inputs(cfg, 11);
            let full = engine.run(&key, "full_grad", &full_in).unwrap();

            let z = engine.run(&key, "client_fwd", &fwd_in).unwrap().remove(0);
            let step_in = vec![
                full_in[2].clone(), // w2
                full_in[3].clone(), // b2
                full_in[4].clone(), // w3
                full_in[5].clone(), // b3
                full_in[7].clone(), // y
                z.clone(),          // z_tilde = z
            ];
            let step = engine.run(&key, "server_step", &step_in).unwrap();
            let bwd_in = vec![
                full_in[0].clone(),         // w1
                full_in[1].clone(),         // b1
                full_in[6].clone(),         // x
                z,                          // z_tilde = z
                step[2].clone(),            // grad_z
                Array::f32(&[], vec![0.0]), // lambda = 0
            ];
            let bwd = engine.run(&key, "client_bwd", &bwd_in).unwrap();

            // z~ == z, λ == 0 → zero correction error and bit-identical grads
            assert_eq!(bwd[2].as_f32().unwrap()[0], 0.0, "{key} qerr");
            assert_eq!(step[0].as_f32().unwrap(), full[0].as_f32().unwrap(), "{key} loss");
            assert_eq!(step[1].as_f32().unwrap(), full[1].as_f32().unwrap(), "{key} correct");
            assert_eq!(bwd[0].as_f32().unwrap(), full[2].as_f32().unwrap(), "{key} g_w1");
            assert_eq!(bwd[1].as_f32().unwrap(), full[3].as_f32().unwrap(), "{key} g_b1");
            for (k, out) in ["g_w2", "g_b2", "g_w3", "g_b3"].iter().enumerate() {
                assert_eq!(
                    step[3 + k].as_f32().unwrap(),
                    full[4 + k].as_f32().unwrap(),
                    "{key} {out}"
                );
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences_on_every_variant() {
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let engine = NativeEngine::new();
            let (full_in, _) = rand_inputs(cfg, 5);
            let outs = engine.run(&key, "full_grad", &full_in).unwrap();
            // probe the max-|grad| coordinate of each parameter tensor
            for (pi, gi) in [(0usize, 2usize), (1, 3), (2, 4), (3, 5), (4, 6), (5, 7)] {
                let grads = outs[gi].as_f32().unwrap();
                let (idx, &g) = grads
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if g.abs() < 1e-5 {
                    continue; // too flat to measure against f32 loss noise
                }
                let eps = 1e-3f32;
                let probe = |delta: f32| -> f64 {
                    let mut inputs = full_in.clone();
                    if let Array::F32 { data, .. } = &mut inputs[pi] {
                        data[idx] += delta;
                    }
                    let o = engine.run(&key, "full_grad", &inputs).unwrap();
                    o[0].as_f32().unwrap()[0] as f64
                };
                let fd = (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
                let rel = (fd - g as f64).abs() / (g.abs() as f64).max(1e-6);
                // the loss output is f32, so central differences carry
                // ~1e-4 absolute noise at eps = 1e-3; accept either bound
                assert!(
                    rel < 0.05 || (fd - g as f64).abs() < 5e-4,
                    "{key} param {pi} idx {idx}: analytic {g} vs fd {fd} (rel {rel})"
                );
            }
        }
    }

    /// All kernel policies produce bit-identical artifact outputs on
    /// every variant, including the dsub-8, 1152-wide `stress` geometry
    /// (the engine-level view of the gemm exactness contract).
    #[test]
    fn kernel_policies_are_bit_identical_per_artifact() {
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let (full_in, fwd_in) = rand_inputs(cfg, 23);
            let engines = [
                NativeEngine::with_policy(GemmPolicy::naive()),
                NativeEngine::with_policy(GemmPolicy::tiled()),
                NativeEngine::with_policy(GemmPolicy::parallel(3)),
            ];
            let runs: Vec<_> = engines
                .iter()
                .map(|e| {
                    let z = e.run(&key, "client_fwd", &fwd_in).unwrap();
                    let full = e.run(&key, "full_grad", &full_in).unwrap();
                    (z, full)
                })
                .collect();
            for other in &runs[1..] {
                assert_eq!(
                    runs[0].0[0].as_f32().unwrap(),
                    other.0[0].as_f32().unwrap(),
                    "{key} z"
                );
                for (a, b) in runs[0].1.iter().zip(&other.1) {
                    assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "{key} full_grad");
                }
            }
        }
    }

    #[test]
    fn lambda_correction_shifts_client_gradient() {
        let cfg = NativeModelCfg::by_preset("tiny").unwrap();
        let engine = NativeEngine::new();
        let (full_in, fwd_in) = rand_inputs(cfg, 7);
        let z = engine.run(VARIANT, "client_fwd", &fwd_in).unwrap().remove(0);
        // perturb z~ away from z so the correction term is non-zero
        let zt = match &z {
            Array::F32 { shape, data } => {
                let mut d = data.clone();
                for v in d.iter_mut() {
                    *v += 0.1;
                }
                Array::f32(shape, d)
            }
            _ => unreachable!(),
        };
        let n = cfg.batch * cfg.cut;
        let grad_z = Array::f32(&[cfg.batch, cfg.cut], vec![0.0; n]);
        let run = |lambda: f32| {
            let bwd_in = vec![
                full_in[0].clone(),
                full_in[1].clone(),
                full_in[6].clone(),
                zt.clone(),
                grad_z.clone(),
                Array::f32(&[], vec![lambda]),
            ];
            engine.run(VARIANT, "client_bwd", &bwd_in).unwrap()
        };
        let with = run(0.5);
        let without = run(0.0);
        assert!(with[2].as_f32().unwrap()[0] > 0.0, "qerr must be positive");
        // λ = 0 with zero grad_z → zero client grads; λ > 0 → non-zero
        assert!(without[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(with[0].as_f32().unwrap().iter().any(|&v| v != 0.0));
    }

    /// Satellite: an out-of-range label is a proper error on every
    /// label-consuming artifact, not an index-out-of-bounds panic.
    #[test]
    fn out_of_range_labels_error_instead_of_panicking() {
        let cfg = NativeModelCfg::by_preset("tiny").unwrap();
        let engine = NativeEngine::new();
        let (mut full_in, fwd_in) = rand_inputs(cfg, 13);
        for bad in [cfg.classes as i32, -1, i32::MAX] {
            if let Array::I32 { data, .. } = &mut full_in[7] {
                data[2] = bad;
            }
            let err = engine.run(VARIANT, "full_grad", &full_in).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{bad}: {err}");

            // server_step sees the same labels through its own input slot
            let z = engine.run(VARIANT, "client_fwd", &fwd_in).unwrap().remove(0);
            let step_in = vec![
                full_in[2].clone(),
                full_in[3].clone(),
                full_in[4].clone(),
                full_in[5].clone(),
                full_in[7].clone(), // y (bad)
                z,
            ];
            let err = engine.run(VARIANT, "server_step", &step_in).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{bad}: {err}");
        }
        // full_eval validates too (eval batches come from the same data
        // plumbing)
        let eval_m = cfg.eval_batch;
        let mut eval_in = full_in.clone();
        eval_in[6] = Array::f32(&[eval_m, 28, 28, 1], vec![0.1; eval_m * cfg.input]);
        eval_in[7] = Array::i32(&[eval_m], vec![cfg.classes as i32; eval_m]);
        let err = engine.run(VARIANT, "full_eval", &eval_in).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    /// Warm scratch reuse is bit-identical to fresh scratches and keeps
    /// its buffer capacities (the steady-state contract run_scratch
    /// provides the trainers).
    #[test]
    fn scratch_reuse_is_bit_identical_and_capacity_stable() {
        let cfg = NativeModelCfg::by_preset("small").unwrap();
        let key = cfg.variant_key();
        let engine = NativeEngine::new();
        let (full_in, _) = rand_inputs(cfg, 31);
        let fresh = engine.run(&key, "full_grad", &full_in).unwrap();
        let mut scratch = EngineScratch::new();
        // warm-up sizes the buffers (full_eval is the largest batch)
        let _ = engine.run_scratch(&key, "full_grad", &full_in, &mut scratch).unwrap();
        let fp = scratch.capacity_fingerprint();
        for _ in 0..2 {
            let warm = engine
                .run_scratch(&key, "full_grad", &full_in, &mut scratch)
                .unwrap();
            for (a, b) in fresh.iter().zip(&warm) {
                assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
            }
            assert_eq!(scratch.capacity_fingerprint(), fp, "scratch reallocated");
        }
    }

    #[test]
    fn runtime_checks_shapes() {
        let rt = Runtime::native();
        let bad = vec![Array::f32(&[2, 2], vec![0.0; 4])];
        assert!(rt.run(VARIANT, "client_fwd", &bad).is_err());
        assert!(rt.run("nope", "client_fwd", &bad).is_err());
        assert!(rt.run(VARIANT, "nope", &bad).is_err());
    }
}
