//! Native reference engine: a pure-rust split MLP (`femnist_tiny`).
//!
//! Implements the same artifact contract the PJRT backend serves —
//! `client_fwd`, `server_step`, `client_bwd`, `full_grad`, `full_eval`
//! with manifest-declared input order/shapes/roles — for one built-in
//! variant, so the full round state machines (SplitFed / FedLite /
//! FedAvg) run from a fresh clone with no Python lowering step and no
//! XLA toolchain. CI's build/test/smoke jobs and the workers-invariance
//! determinism tests execute through this engine.
//!
//! Model (`femnist_tiny`): client = dense(784→32) + ReLU (the cut layer);
//! server = dense(32→32) + ReLU + dense(32→62) + softmax cross-entropy,
//! `correct`-count metric. Gradient correction (paper eq. (5)) is applied
//! in `client_bwd`: the client loss term λ/2·‖z − z~‖² contributes
//! λ·(z − z~) to the gradient at the cut. All reductions run in a fixed
//! sequential order, so outputs are bit-identical regardless of how many
//! cohort workers call `run` concurrently (`&self`, no shared state).

use std::collections::HashMap;

use crate::data::Array;
use crate::models::{ModelSpec, ParamSpec, SideSpec};
use crate::runtime::artifact::{ArtifactMeta, IoSpec, Manifest, Variant};
use crate::util::json::{Object, Value};

/// The variant key the native engine serves.
pub const VARIANT: &str = "femnist_tiny";

const IN: usize = 28 * 28; // flattened [28, 28, 1] images
const CUT: usize = 32; // cut-layer width d
const HID: usize = 32; // server hidden width
const CLASSES: usize = 62;
const BATCH: usize = 8;
const EVAL_BATCH: usize = 32;

/// Stateless executor for the built-in variant.
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> NativeEngine {
        NativeEngine
    }

    /// Synthesize the manifest the artifacts directory would otherwise
    /// provide. Input order here is the assembly order — it must match
    /// the indexing in [`NativeEngine::run`].
    pub fn manifest(&self) -> Manifest {
        let x = |b: usize| io("x", &[b, 28, 28, 1], "f32", "data");
        let y = |b: usize| io("y", &[b], "s32", "data");
        let client_params = || {
            vec![
                io("w1", &[IN, CUT], "f32", "param_client"),
                io("b1", &[CUT], "f32", "param_client"),
            ]
        };
        let server_params = || {
            vec![
                io("w2", &[CUT, HID], "f32", "param_server"),
                io("b2", &[HID], "f32", "param_server"),
                io("w3", &[HID, CLASSES], "f32", "param_server"),
                io("b3", &[CLASSES], "f32", "param_server"),
            ]
        };

        let mut artifacts = HashMap::new();
        let mut add = |meta: ArtifactMeta| {
            artifacts.insert(meta.name.clone(), meta);
        };
        let mut inputs = client_params();
        inputs.push(x(BATCH));
        add(art("client_fwd", inputs, &["z"]));

        let mut inputs = server_params();
        inputs.push(y(BATCH));
        inputs.push(io("z_tilde", &[BATCH, CUT], "f32", "cut"));
        add(art(
            "server_step",
            inputs,
            &["loss", "correct", "grad_z", "g_w2", "g_b2", "g_w3", "g_b3"],
        ));

        let mut inputs = client_params();
        inputs.push(x(BATCH));
        inputs.push(io("z_tilde", &[BATCH, CUT], "f32", "cut"));
        inputs.push(io("grad_z", &[BATCH, CUT], "f32", "grad_cut"));
        inputs.push(io("lambda", &[], "f32", "hyper"));
        add(art("client_bwd", inputs, &["g_w1", "g_b1", "qerr"]));

        let mut inputs = client_params();
        inputs.extend(server_params());
        inputs.push(x(BATCH));
        inputs.push(y(BATCH));
        add(art(
            "full_grad",
            inputs,
            &[
                "loss", "correct", "g_w1", "g_b1", "g_w2", "g_b2", "g_w3", "g_b3",
            ],
        ));

        let mut inputs = client_params();
        inputs.extend(server_params());
        inputs.push(x(EVAL_BATCH));
        inputs.push(y(EVAL_BATCH));
        add(art("full_eval", inputs, &["loss", "correct"]));

        let mut config = Object::new();
        config.insert("batch", Value::from_usize(BATCH));
        config.insert("eval_batch", Value::from_usize(EVAL_BATCH));
        let spec = ModelSpec {
            task: "femnist".to_string(),
            preset: "tiny".to_string(),
            cut_dim: CUT,
            act_batch: BATCH,
            batch: BATCH,
            eval_batch: EVAL_BATCH,
            client: SideSpec {
                params: vec![
                    param("w1", &[IN, CUT], "glorot_uniform", IN, CUT),
                    param("b1", &[CUT], "zeros", CUT, CUT),
                ],
            },
            server: SideSpec {
                params: vec![
                    param("w2", &[CUT, HID], "glorot_uniform", CUT, HID),
                    param("b2", &[HID], "zeros", HID, HID),
                    param("w3", &[HID, CLASSES], "glorot_uniform", HID, CLASSES),
                    param("b3", &[CLASSES], "zeros", HID, CLASSES),
                ],
            },
            metrics: vec!["correct".to_string()],
            client_args: vec!["x".to_string()],
            server_args: vec!["y".to_string()],
            config: Value::Obj(config),
        };

        let mut variants = HashMap::new();
        variants.insert(VARIANT.to_string(), Variant { spec, artifacts });
        Manifest { variants, jax_version: "native".to_string() }
    }

    /// Execute one artifact. Inputs were already checked against the
    /// manifest by [`crate::runtime::Runtime::run`].
    pub fn run(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
    ) -> anyhow::Result<Vec<Array>> {
        anyhow::ensure!(
            variant == VARIANT,
            "native engine only serves '{VARIANT}', got '{variant}'"
        );
        match name {
            "client_fwd" => self.client_fwd(inputs),
            "server_step" => self.server_step(inputs),
            "client_bwd" => self.client_bwd(inputs),
            "full_grad" => self.full_grad(inputs),
            "full_eval" => self.full_eval(inputs),
            other => anyhow::bail!("native engine has no artifact '{other}'"),
        }
    }

    fn client_fwd(&self, inputs: &[Array]) -> anyhow::Result<Vec<Array>> {
        let (w1, b1, x) = (f32s(&inputs[0])?, f32s(&inputs[1])?, f32s(&inputs[2])?);
        let zpre = dense(x, w1, b1, BATCH, IN, CUT);
        let z = relu(&zpre);
        Ok(vec![Array::f32(&[BATCH, CUT], z)])
    }

    fn server_step(&self, inputs: &[Array]) -> anyhow::Result<Vec<Array>> {
        let (w2, b2, w3, b3) = (
            f32s(&inputs[0])?,
            f32s(&inputs[1])?,
            f32s(&inputs[2])?,
            f32s(&inputs[3])?,
        );
        let y = i32s(&inputs[4])?;
        let zt = f32s(&inputs[5])?;
        let fwd = server_forward(zt, w2, b2, w3, b3, BATCH);
        let (loss, glogits, correct) = softmax_ce(&fwd.logits, y, BATCH, CLASSES);
        let back = server_backward(zt, w2, w3, &fwd, &glogits, BATCH);
        Ok(vec![
            Array::f32(&[], vec![loss as f32]),
            Array::f32(&[], vec![correct as f32]),
            Array::f32(&[BATCH, CUT], back.grad_z),
            Array::f32(&[CUT, HID], back.g_w2),
            Array::f32(&[HID], back.g_b2),
            Array::f32(&[HID, CLASSES], back.g_w3),
            Array::f32(&[CLASSES], back.g_b3),
        ])
    }

    fn client_bwd(&self, inputs: &[Array]) -> anyhow::Result<Vec<Array>> {
        let (w1, b1, x) = (f32s(&inputs[0])?, f32s(&inputs[1])?, f32s(&inputs[2])?);
        let zt = f32s(&inputs[3])?;
        let grad_z = f32s(&inputs[4])?;
        let lambda = f32s(&inputs[5])?[0];
        let zpre = dense(x, w1, b1, BATCH, IN, CUT);
        let z = relu(&zpre);
        // gradient correction (eq. (5)): d/dz [λ/2 ‖z − z~‖²] = λ (z − z~)
        let mut qerr = 0.0f64;
        let mut gz = vec![0.0f32; BATCH * CUT];
        for i in 0..BATCH * CUT {
            let diff = z[i] - zt[i];
            qerr += (diff as f64) * (diff as f64);
            gz[i] = grad_z[i] + lambda * diff;
        }
        relu_backward(&mut gz, &zpre);
        let g_w1 = matmul_at_b(x, &gz, BATCH, IN, CUT);
        let g_b1 = colsum(&gz, BATCH, CUT);
        Ok(vec![
            Array::f32(&[IN, CUT], g_w1),
            Array::f32(&[CUT], g_b1),
            Array::f32(&[], vec![qerr as f32]),
        ])
    }

    fn full_grad(&self, inputs: &[Array]) -> anyhow::Result<Vec<Array>> {
        let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
        let (w2, b2, w3, b3) = (
            f32s(&inputs[2])?,
            f32s(&inputs[3])?,
            f32s(&inputs[4])?,
            f32s(&inputs[5])?,
        );
        let x = f32s(&inputs[6])?;
        let y = i32s(&inputs[7])?;
        // identical composition to the split path with z~ = z and λ = 0,
        // so split-vs-monolithic agreement is exact by construction
        let zpre = dense(x, w1, b1, BATCH, IN, CUT);
        let z = relu(&zpre);
        let fwd = server_forward(&z, w2, b2, w3, b3, BATCH);
        let (loss, glogits, correct) = softmax_ce(&fwd.logits, y, BATCH, CLASSES);
        let back = server_backward(&z, w2, w3, &fwd, &glogits, BATCH);
        let mut gz = back.grad_z;
        relu_backward(&mut gz, &zpre);
        let g_w1 = matmul_at_b(x, &gz, BATCH, IN, CUT);
        let g_b1 = colsum(&gz, BATCH, CUT);
        Ok(vec![
            Array::f32(&[], vec![loss as f32]),
            Array::f32(&[], vec![correct as f32]),
            Array::f32(&[IN, CUT], g_w1),
            Array::f32(&[CUT], g_b1),
            Array::f32(&[CUT, HID], back.g_w2),
            Array::f32(&[HID], back.g_b2),
            Array::f32(&[HID, CLASSES], back.g_w3),
            Array::f32(&[CLASSES], back.g_b3),
        ])
    }

    fn full_eval(&self, inputs: &[Array]) -> anyhow::Result<Vec<Array>> {
        let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
        let (w2, b2, w3, b3) = (
            f32s(&inputs[2])?,
            f32s(&inputs[3])?,
            f32s(&inputs[4])?,
            f32s(&inputs[5])?,
        );
        let x = f32s(&inputs[6])?;
        let y = i32s(&inputs[7])?;
        let m = EVAL_BATCH;
        let z = relu(&dense(x, w1, b1, m, IN, CUT));
        let fwd = server_forward(&z, w2, b2, w3, b3, m);
        let (loss, _glogits, correct) = softmax_ce(&fwd.logits, y, m, CLASSES);
        Ok(vec![
            Array::f32(&[], vec![loss as f32]),
            Array::f32(&[], vec![correct as f32]),
        ])
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new()
    }
}

// -- manifest construction helpers -------------------------------------------

fn io(name: &str, shape: &[usize], dtype: &str, role: &str) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
        role: role.to_string(),
    }
}

fn art(name: &str, inputs: Vec<IoSpec>, outputs: &[&str]) -> ArtifactMeta {
    ArtifactMeta {
        name: name.to_string(),
        path: format!("native/{name}"),
        inputs,
        outputs: outputs.iter().map(|o| o.to_string()).collect(),
        meta: Value::Null,
    }
}

fn param(name: &str, shape: &[usize], init: &str, fan_in: usize, fan_out: usize) -> ParamSpec {
    ParamSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        init: init.to_string(),
        scale: 1.0,
        fan_in,
        fan_out,
    }
}

// -- dense math (fixed reduction order => deterministic) ---------------------

fn f32s(a: &Array) -> anyhow::Result<&[f32]> {
    a.as_f32().ok_or_else(|| anyhow::anyhow!("expected f32 input"))
}

fn i32s(a: &Array) -> anyhow::Result<&[i32]> {
    a.as_i32().ok_or_else(|| anyhow::anyhow!("expected s32 input"))
}

/// `x [m, k] @ w [k, n] + bias [n]`.
fn dense(x: &[f32], w: &[f32], bias: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x[i * k..(i + 1) * k];
        let o = &mut out[i * n..(i + 1) * n];
        o.copy_from_slice(bias);
        for (kk, &xv) in row.iter().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            for (ov, &wv) in o.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
    out
}

fn relu(z: &[f32]) -> Vec<f32> {
    z.iter().map(|&v| if v > 0.0 { v } else { 0.0 }).collect()
}

/// Zero the gradient wherever the pre-activation was non-positive.
fn relu_backward(grad: &mut [f32], pre: &[f32]) {
    for (g, &p) in grad.iter_mut().zip(pre) {
        if p <= 0.0 {
            *g = 0.0;
        }
    }
}

/// `a^T [k, m] @ g [m, n]` for `a [m, k]` (weight gradients).
fn matmul_at_b(a: &[f32], g: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let grow = &g[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let o = &mut out[kk * n..(kk + 1) * n];
            for (ov, &gv) in o.iter_mut().zip(grow) {
                *ov += av * gv;
            }
        }
    }
    out
}

/// `g [m, n] @ w^T [n, k]` for `w [k, n]` (input gradients).
fn matmul_a_bt(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (kk, ov) in orow.iter_mut().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut s = 0.0f32;
            for (gv, wv) in grow.iter().zip(wrow) {
                s += gv * wv;
            }
            *ov = s;
        }
    }
    out
}

/// Column sums of `g [m, n]` (bias gradients).
fn colsum(g: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        for (ov, &gv) in out.iter_mut().zip(grow) {
            *ov += gv;
        }
    }
    out
}

struct ServerFwd {
    h1pre: Vec<f32>,
    h1: Vec<f32>,
    logits: Vec<f32>,
}

fn server_forward(
    zt: &[f32],
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    m: usize,
) -> ServerFwd {
    let h1pre = dense(zt, w2, b2, m, CUT, HID);
    let h1 = relu(&h1pre);
    let logits = dense(&h1, w3, b3, m, HID, CLASSES);
    ServerFwd { h1pre, h1, logits }
}

struct ServerBack {
    g_w2: Vec<f32>,
    g_b2: Vec<f32>,
    g_w3: Vec<f32>,
    g_b3: Vec<f32>,
    grad_z: Vec<f32>,
}

fn server_backward(
    zt: &[f32],
    w2: &[f32],
    w3: &[f32],
    fwd: &ServerFwd,
    glogits: &[f32],
    m: usize,
) -> ServerBack {
    let g_w3 = matmul_at_b(&fwd.h1, glogits, m, HID, CLASSES);
    let g_b3 = colsum(glogits, m, CLASSES);
    let mut dh1 = matmul_a_bt(glogits, w3, m, CLASSES, HID);
    relu_backward(&mut dh1, &fwd.h1pre);
    let g_w2 = matmul_at_b(zt, &dh1, m, CUT, HID);
    let g_b2 = colsum(&dh1, m, HID);
    let grad_z = matmul_a_bt(&dh1, w2, m, HID, CUT);
    ServerBack { g_w2, g_b2, g_w3, g_b3, grad_z }
}

/// Mean softmax cross-entropy over the batch. Returns (mean loss,
/// d(mean loss)/d(logits), correct-prediction count). Ties in the argmax
/// resolve to the lowest class index (fixed, deterministic).
fn softmax_ce(logits: &[f32], y: &[i32], m: usize, c: usize) -> (f64, Vec<f32>, f64) {
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut grad = vec![0.0f32; m * c];
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let mut maxv = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                argmax = j;
            }
        }
        let g = &mut grad[i * c..(i + 1) * c];
        let mut sum = 0.0f32;
        for (gv, &v) in g.iter_mut().zip(row) {
            let e = (v - maxv).exp();
            *gv = e;
            sum += e;
        }
        let yi = y[i] as usize;
        loss -= (row[yi] - maxv) as f64 - (sum as f64).ln();
        if argmax == yi {
            correct += 1.0;
        }
        let inv = 1.0 / (sum * m as f32);
        for gv in g.iter_mut() {
            *gv *= inv;
        }
        g[yi] -= 1.0 / m as f32;
    }
    (loss / m as f64, grad, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn rand_inputs(seed: u64) -> (Vec<Array>, Vec<Array>) {
        // (full_grad inputs, client_fwd inputs) over shared params/batch
        let rt = Runtime::native();
        let spec = rt.manifest.variant(VARIANT).unwrap().spec.clone();
        let rng = Rng::new(seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let mut r = rng.fork(3);
        let x = r.uniform_vec(BATCH * IN, 0.0, 1.0);
        let y: Vec<i32> = (0..BATCH).map(|_| r.below(CLASSES) as i32).collect();
        let p = |t: &crate::tensor::Tensor| Array::f32(t.shape(), t.data().to_vec());
        let mut full: Vec<Array> = wc.tensors.iter().map(&p).collect();
        full.extend(ws.tensors.iter().map(&p));
        full.push(Array::f32(&[BATCH, 28, 28, 1], x.clone()));
        full.push(Array::i32(&[BATCH], y));
        let mut fwd: Vec<Array> = wc.tensors.iter().map(&p).collect();
        fwd.push(Array::f32(&[BATCH, 28, 28, 1], x));
        (full, fwd)
    }

    #[test]
    fn manifest_is_complete_and_consistent() {
        let rt = Runtime::native();
        let v = rt.manifest.variant(VARIANT).unwrap();
        for a in ["client_fwd", "server_step", "client_bwd", "full_grad", "full_eval"] {
            assert!(v.artifacts.contains_key(a), "{a} missing");
        }
        assert_eq!(v.spec.cut_dim, CUT);
        assert_eq!(v.spec.client.numel(), IN * CUT + CUT);
        assert_eq!(
            v.spec.server.numel(),
            CUT * HID + HID + HID * CLASSES + CLASSES
        );
        // param_client/param_server input order matches the SideSpec order
        let fwd = v.artifacts.get("client_fwd").unwrap();
        assert_eq!(fwd.inputs[0].name, v.spec.client.params[0].name);
        assert_eq!(fwd.inputs[0].shape, v.spec.client.params[0].shape);
    }

    #[test]
    fn split_composition_equals_full_grad_exactly() {
        let engine = NativeEngine::new();
        let (full_in, fwd_in) = rand_inputs(11);
        let full = engine.run(VARIANT, "full_grad", &full_in).unwrap();

        let z = engine
            .run(VARIANT, "client_fwd", &fwd_in)
            .unwrap()
            .remove(0);
        let step_in = vec![
            full_in[2].clone(), // w2
            full_in[3].clone(), // b2
            full_in[4].clone(), // w3
            full_in[5].clone(), // b3
            full_in[7].clone(), // y
            z.clone(),          // z_tilde = z
        ];
        let step = engine.run(VARIANT, "server_step", &step_in).unwrap();
        let bwd_in = vec![
            full_in[0].clone(), // w1
            full_in[1].clone(), // b1
            full_in[6].clone(), // x
            z,                  // z_tilde = z
            step[2].clone(),    // grad_z
            Array::f32(&[], vec![0.0]), // lambda = 0
        ];
        let bwd = engine.run(VARIANT, "client_bwd", &bwd_in).unwrap();

        // z~ == z, λ == 0 → zero correction error and bit-identical grads
        assert_eq!(bwd[2].as_f32().unwrap()[0], 0.0);
        assert_eq!(step[0].as_f32().unwrap(), full[0].as_f32().unwrap()); // loss
        assert_eq!(step[1].as_f32().unwrap(), full[1].as_f32().unwrap()); // correct
        assert_eq!(bwd[0].as_f32().unwrap(), full[2].as_f32().unwrap()); // g_w1
        assert_eq!(bwd[1].as_f32().unwrap(), full[3].as_f32().unwrap()); // g_b1
        for (k, out) in ["g_w2", "g_b2", "g_w3", "g_b3"].iter().enumerate() {
            assert_eq!(
                step[3 + k].as_f32().unwrap(),
                full[4 + k].as_f32().unwrap(),
                "{out}"
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let engine = NativeEngine::new();
        let (full_in, _) = rand_inputs(5);
        let outs = engine.run(VARIANT, "full_grad", &full_in).unwrap();
        // probe the max-|grad| coordinate of each parameter tensor
        for (pi, gi) in [(0usize, 2usize), (1, 3), (2, 4), (3, 5), (4, 6), (5, 7)] {
            let grads = outs[gi].as_f32().unwrap();
            let (idx, &g) = grads
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap();
            if g.abs() < 1e-5 {
                continue; // too flat to measure against f32 loss noise
            }
            let eps = 1e-3f32;
            let probe = |delta: f32| -> f64 {
                let mut inputs = full_in.clone();
                if let Array::F32 { data, .. } = &mut inputs[pi] {
                    data[idx] += delta;
                }
                let o = engine.run(VARIANT, "full_grad", &inputs).unwrap();
                o[0].as_f32().unwrap()[0] as f64
            };
            let fd = (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
            let rel = (fd - g as f64).abs() / (g.abs() as f64).max(1e-6);
            // the loss output is f32, so central differences carry
            // ~1e-4 absolute noise at eps = 1e-3; accept either bound
            assert!(
                rel < 0.05 || (fd - g as f64).abs() < 5e-4,
                "param {pi} idx {idx}: analytic {g} vs fd {fd} (rel {rel})"
            );
        }
    }

    #[test]
    fn lambda_correction_shifts_client_gradient() {
        let engine = NativeEngine::new();
        let (full_in, fwd_in) = rand_inputs(7);
        let z = engine
            .run(VARIANT, "client_fwd", &fwd_in)
            .unwrap()
            .remove(0);
        // perturb z~ away from z so the correction term is non-zero
        let zt = match &z {
            Array::F32 { shape, data } => {
                let mut d = data.clone();
                for v in d.iter_mut() {
                    *v += 0.1;
                }
                Array::f32(shape, d)
            }
            _ => unreachable!(),
        };
        let grad_z = Array::f32(&[BATCH, CUT], vec![0.0; BATCH * CUT]);
        let run = |lambda: f32| {
            let bwd_in = vec![
                full_in[0].clone(),
                full_in[1].clone(),
                full_in[6].clone(),
                zt.clone(),
                grad_z.clone(),
                Array::f32(&[], vec![lambda]),
            ];
            engine.run(VARIANT, "client_bwd", &bwd_in).unwrap()
        };
        let with = run(0.5);
        let without = run(0.0);
        assert!(with[2].as_f32().unwrap()[0] > 0.0, "qerr must be positive");
        // λ = 0 with zero grad_z → zero client grads; λ > 0 → non-zero
        assert!(without[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(with[0].as_f32().unwrap().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn runtime_checks_shapes() {
        let rt = Runtime::native();
        let bad = vec![Array::f32(&[2, 2], vec![0.0; 4])];
        assert!(rt.run(VARIANT, "client_fwd", &bad).is_err());
        assert!(rt.run("nope", "client_fwd", &bad).is_err());
        assert!(rt.run(VARIANT, "nope", &bad).is_err());
    }
}
