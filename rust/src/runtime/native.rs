//! Native reference engine: a parameterized family of pure-rust split MLPs.
//!
//! Implements the same artifact contract the PJRT backend serves —
//! `client_fwd`, `server_step`, `client_bwd`, `full_grad`, `full_eval`
//! with manifest-declared input order/shapes/roles — for the built-in
//! [`NativeModelCfg::registry`] variants, so the full round state
//! machines (SplitFed / FedLite / FedAvg) run from a fresh clone with no
//! Python lowering step and no XLA toolchain. CI's build/test/smoke jobs
//! and the workers-invariance determinism tests execute through this
//! engine.
//!
//! Model shape (every variant): client = dense(input→cut) + ReLU (the
//! cut layer); server = dense(cut→hidden) + ReLU + dense(hidden→classes)
//! + a per-task head ([`HeadKind`]). The gradient correction (paper
//! eq. (5)) lives host-side in `coordinator/correction.rs`; `client_bwd`
//! still accepts a λ input and adds λ·(z − z~) so artifact-side and
//! host-side application compose (the trainers pass λ = 0 here).
//!
//! Registered variants (`<task>_<preset>`):
//!
//! | variant | input | cut | hidden | classes | batch | head |
//! |---|---|---|---|---|---|---|
//! | `femnist_tiny` | 784 | 32 | 32 | 62 | 8 | softmax CE (CI smoke / golden fixtures, bits unchanged) |
//! | `femnist_small` | 784 | 64 | 128 | 62 | 32 | softmax CE |
//! | `femnist_stress` | 784 | 1152 | 256 | 62 | 8 | softmax CE (paper-scale q=1152 PQ geometry) |
//! | `so_tag_tiny` | 1000 | 32 | 32 | 200 | 8 | sigmoid BCE, Recall@5 sums |
//! | `so_tag_small` | 1000 | 64 | 128 | 200 | 16 | sigmoid BCE, Recall@5 sums |
//! | `so_nwp_tiny` | 2004 | 32 | 32 | 2004 | 4·20 rows | PAD-masked token CE |
//! | `so_nwp_small` | 2004 | 64 | 128 | 2004 | 8·20 rows | PAD-masked token CE |
//!
//! FEMNIST consumes images (x `[B, 28, 28, 1]` f32, one class id per
//! row); SO tag consumes L1-normalized bag-of-words (x `[B, vocab]` f32,
//! multi-hot tags `[B, tags]` f32); SO NWP consumes token ids (x and y
//! `[B, T]` s32, PAD = 0) which the engine one-hot expands into the
//! scratch arena — the dense cut layer then doubles as the embedding
//! table, so every task runs the same GEMM kernels.
//!
//! All dense math runs through the tiled deterministic kernels in
//! [`crate::tensor::gemm`] — bit-identical to the naive triple loops by
//! construction (see that module's exactness contract), so the `tiny`
//! golden fixtures reproduce exactly with tiling enabled. Every reduction
//! has a fixed order and `run` takes `&self`, so outputs are
//! bit-identical regardless of how many cohort workers call `run`
//! concurrently.
//!
//! The zero-allocation steady state mirrors the quantizer's (PR 4): an
//! [`EngineScratch`] arena holds every intermediate (zpre/z/h1pre/h1/
//! logits/grad buffers); [`NativeEngine::run_scratch`] and the public
//! `*_into` compute layer reuse it across calls, so after warm-up the
//! compute path performs no heap allocation (`rust/tests/alloc.rs`
//! audits the combined compute+quantize client path). The `Vec<Array>`
//! outputs of the `run` contract still allocate — that boundary is the
//! runtime API, not the kernels.

use std::collections::HashMap;

use crate::data::so_nwp::PAD;
use crate::data::Array;
use crate::models::{ModelSpec, ParamSpec, SideSpec};
use crate::runtime::artifact::{ArtifactMeta, IoSpec, Manifest, Variant};
use crate::tensor::gemm::{self, GemmPolicy};
use crate::util::json::{Object, Value};

/// The historical single-variant key (the `tiny` preset); kept for the
/// golden fixtures and tests that pin it.
pub const VARIANT: &str = "femnist_tiny";

/// Loss head + metric family of a native variant. Every head writes
/// `d(mean loss)/d(logits)` into the scratch's `glogits` and returns
/// `(mean loss, [metric_sum_0, metric_sum_1])`; how many of the two sums
/// the artifact exposes is [`HeadKind::metric_names`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    /// Softmax cross-entropy, one class id per row; metric `correct`.
    SoftmaxCe,
    /// Per-class sigmoid BCE over multi-hot targets; metrics
    /// `hits_at_5` / `positives` (the Recall@5 numerator/denominator).
    SigmoidBce,
    /// Softmax cross-entropy per sequence position with PAD targets
    /// masked out; metrics `correct_tokens` / `valid_tokens`.
    TokenSoftmaxCe,
}

impl HeadKind {
    /// Metric output names, in artifact output order.
    pub fn metric_names(&self) -> &'static [&'static str] {
        match self {
            HeadKind::SoftmaxCe => &["correct"],
            HeadKind::SigmoidBce => &["hits_at_5", "positives"],
            HeadKind::TokenSoftmaxCe => &["correct_tokens", "valid_tokens"],
        }
    }
}

/// Dimensions of one native split-MLP variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NativeModelCfg {
    /// Task name; the manifest key is `<task>_<preset>`.
    pub task: &'static str,
    /// Preset name (`tiny` / `small` / `stress`).
    pub preset: &'static str,
    /// Flattened input dim (pixels for FEMNIST, vocab for the SO tasks).
    pub input: usize,
    /// Cut-layer width d (what the quantizer sees).
    pub cut: usize,
    /// Server hidden width.
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    /// Sequence length (1 for non-sequence tasks); the engine processes
    /// `batch · seq` rows per step.
    pub seq: usize,
    pub head: HeadKind,
}

/// The built-in variant family. `femnist_tiny` must stay bit-identical
/// to the pre-family engine (golden fixtures); new variants append here
/// and are picked up by the manifest, the presets, the generalized
/// tests, and `bench_engine` automatically. The SO dims mirror
/// `SoTagConfig::small()` / `SoNwpConfig::small()` — the configs the
/// data loaders serve for every non-`paper` preset.
const REGISTRY: &[NativeModelCfg] = &[
    NativeModelCfg {
        task: "femnist",
        preset: "tiny",
        input: 28 * 28,
        cut: 32,
        hidden: 32,
        classes: 62,
        batch: 8,
        eval_batch: 32,
        seq: 1,
        head: HeadKind::SoftmaxCe,
    },
    NativeModelCfg {
        task: "femnist",
        preset: "small",
        input: 28 * 28,
        cut: 64,
        hidden: 128,
        classes: 62,
        batch: 32,
        eval_batch: 64,
        seq: 1,
        head: HeadKind::SoftmaxCe,
    },
    NativeModelCfg {
        task: "femnist",
        preset: "stress",
        input: 28 * 28,
        cut: 1152,
        hidden: 256,
        classes: 62,
        batch: 8,
        eval_batch: 16,
        seq: 1,
        head: HeadKind::SoftmaxCe,
    },
    NativeModelCfg {
        task: "so_tag",
        preset: "tiny",
        input: 1000,
        cut: 32,
        hidden: 32,
        classes: 200,
        batch: 8,
        eval_batch: 32,
        seq: 1,
        head: HeadKind::SigmoidBce,
    },
    NativeModelCfg {
        task: "so_tag",
        preset: "small",
        input: 1000,
        cut: 64,
        hidden: 128,
        classes: 200,
        batch: 16,
        eval_batch: 32,
        seq: 1,
        head: HeadKind::SigmoidBce,
    },
    NativeModelCfg {
        task: "so_nwp",
        preset: "tiny",
        input: 2004,
        cut: 32,
        hidden: 32,
        classes: 2004,
        batch: 4,
        eval_batch: 8,
        seq: 20,
        head: HeadKind::TokenSoftmaxCe,
    },
    NativeModelCfg {
        task: "so_nwp",
        preset: "small",
        input: 2004,
        cut: 64,
        hidden: 128,
        classes: 2004,
        batch: 8,
        eval_batch: 16,
        seq: 20,
        head: HeadKind::TokenSoftmaxCe,
    },
];

impl NativeModelCfg {
    /// Every variant the native engine serves.
    pub fn registry() -> &'static [NativeModelCfg] {
        REGISTRY
    }

    /// Manifest key for this variant.
    pub fn variant_key(&self) -> String {
        format!("{}_{}", self.task, self.preset)
    }

    /// Rows per pass for a batch of `b` examples (`b·seq`).
    pub fn rows(&self, b: usize) -> usize {
        b * self.seq
    }

    /// Look a variant up by manifest key (`<task>_<preset>`).
    pub fn by_variant(variant: &str) -> Option<&'static NativeModelCfg> {
        REGISTRY.iter().find(|c| c.variant_key() == variant)
    }

    /// Look a FEMNIST variant up by preset name (`tiny` / `small` /
    /// `stress`) — the historical single-task accessor.
    pub fn by_preset(preset: &str) -> Option<&'static NativeModelCfg> {
        Self::by_task_preset("femnist", preset)
    }

    /// Look a variant up by task + preset.
    pub fn by_task_preset(task: &str, preset: &str) -> Option<&'static NativeModelCfg> {
        REGISTRY.iter().find(|c| c.task == task && c.preset == preset)
    }
}

/// Reusable buffers for the engine's compute path: every intermediate of
/// the forward/backward passes, sized on first use and reused after
/// (capacities only grow; `rust/tests/alloc.rs` asserts the warm path
/// allocates nothing). Lent per cohort slot from the round engine's
/// `RoundAlgorithm::Scratch` pool, so the steady state holds across
/// rounds and attempts.
#[derive(Default)]
pub struct EngineScratch {
    /// Client pre-activation `[m, cut]`.
    pub zpre: Vec<f32>,
    /// Client cut activation `[m, cut]`.
    pub z: Vec<f32>,
    /// Server hidden pre-activation `[m, hidden]`.
    pub h1pre: Vec<f32>,
    /// Server hidden activation `[m, hidden]`.
    pub h1: Vec<f32>,
    /// Logits `[m, classes]`.
    pub logits: Vec<f32>,
    /// d(mean loss)/d(logits) `[m, classes]`.
    pub glogits: Vec<f32>,
    /// Gradient at the cut `[m, cut]` (server's grad_z, client's
    /// corrected gz).
    pub gz: Vec<f32>,
    /// Gradient at the server hidden layer `[m, hidden]`.
    pub dh1: Vec<f32>,
    pub g_w1: Vec<f32>,
    pub g_b1: Vec<f32>,
    pub g_w2: Vec<f32>,
    pub g_b2: Vec<f32>,
    pub g_w3: Vec<f32>,
    pub g_b3: Vec<f32>,
    /// One-hot expansion of token inputs `[m, input]` (sequence tasks
    /// only; empty otherwise).
    pub xoh: Vec<f32>,
}

impl EngineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize every buffer for a pass of `m` rows through `cfg`. Lengths
    /// are exact (kernels assert them); capacities only ever grow.
    pub fn prepare(&mut self, cfg: &NativeModelCfg, m: usize) {
        self.zpre.resize(m * cfg.cut, 0.0);
        self.z.resize(m * cfg.cut, 0.0);
        self.h1pre.resize(m * cfg.hidden, 0.0);
        self.h1.resize(m * cfg.hidden, 0.0);
        self.logits.resize(m * cfg.classes, 0.0);
        self.glogits.resize(m * cfg.classes, 0.0);
        self.gz.resize(m * cfg.cut, 0.0);
        self.dh1.resize(m * cfg.hidden, 0.0);
        self.g_w1.resize(cfg.input * cfg.cut, 0.0);
        self.g_b1.resize(cfg.cut, 0.0);
        self.g_w2.resize(cfg.cut * cfg.hidden, 0.0);
        self.g_b2.resize(cfg.hidden, 0.0);
        self.g_w3.resize(cfg.hidden * cfg.classes, 0.0);
        self.g_b3.resize(cfg.classes, 0.0);
        let oh = if cfg.seq > 1 { m * cfg.input } else { 0 };
        self.xoh.resize(oh, 0.0);
    }

    /// Capacity fingerprint (pointer + capacity per buffer) — the
    /// alloc/scratch-stability tests assert it is stable across
    /// same-shape reuse.
    pub fn capacity_fingerprint(&self) -> Vec<(usize, usize)> {
        [
            &self.zpre, &self.z, &self.h1pre, &self.h1, &self.logits, &self.glogits,
            &self.gz, &self.dh1, &self.g_w1, &self.g_b1, &self.g_w2, &self.g_b2,
            &self.g_w3, &self.g_b3, &self.xoh,
        ]
        .iter()
        .map(|v| (v.as_ptr() as usize, v.capacity()))
        .collect()
    }
}

/// Stateless executor for the built-in variant family.
pub struct NativeEngine {
    policy: GemmPolicy,
}

impl NativeEngine {
    /// Tiled serial kernels — the coordinator default (the round engine
    /// already fans out over clients; nested threads would oversubscribe).
    pub fn new() -> NativeEngine {
        NativeEngine::with_policy(GemmPolicy::tiled())
    }

    /// Engine with an explicit kernel policy (benches compare naive vs
    /// tiled vs tiled+parallel; all three are bit-identical).
    pub fn with_policy(policy: GemmPolicy) -> NativeEngine {
        NativeEngine { policy }
    }

    pub fn policy(&self) -> GemmPolicy {
        self.policy
    }

    /// Synthesize the manifest the artifacts directory would otherwise
    /// provide: one variant per registry entry. Input order here is the
    /// assembly order — it must match the indexing in
    /// [`NativeEngine::run_scratch`].
    pub fn manifest(&self) -> Manifest {
        let mut variants = HashMap::new();
        for cfg in NativeModelCfg::registry() {
            variants.insert(cfg.variant_key(), variant_for(cfg));
        }
        Manifest { variants, jax_version: "native".to_string() }
    }

    /// Execute one artifact with a throwaway scratch. Inputs were already
    /// checked against the manifest by [`crate::runtime::Runtime::run`].
    pub fn run(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
    ) -> anyhow::Result<Vec<Array>> {
        let mut scratch = EngineScratch::default();
        self.run_scratch(variant, name, inputs, &mut scratch)
    }

    /// Execute one artifact against a caller-owned [`EngineScratch`]: the
    /// steady-state entry point the trainers drive (warm scratch ⇒ the
    /// compute performs no heap allocation; only the output `Array`s
    /// allocate).
    pub fn run_scratch(
        &self,
        variant: &str,
        name: &str,
        inputs: &[Array],
        s: &mut EngineScratch,
    ) -> anyhow::Result<Vec<Array>> {
        let cfg = NativeModelCfg::by_variant(variant).ok_or_else(|| {
            anyhow::anyhow!(
                "native engine has no variant '{variant}' (registered: {:?})",
                NativeModelCfg::registry()
                    .iter()
                    .map(|c| c.variant_key())
                    .collect::<Vec<_>>()
            )
        })?;
        let p = self.policy;
        let nmetrics = cfg.head.metric_names().len();
        // helper: loss scalar + per-head metric sums, in output order
        let scalars = |loss: f64, sums: [f64; 2]| {
            let mut outs = Vec::with_capacity(nmetrics + 6);
            outs.push(Array::f32(&[], vec![loss as f32]));
            for sum in sums.iter().take(nmetrics) {
                outs.push(Array::f32(&[], vec![*sum as f32]));
            }
            outs
        };
        match name {
            "client_fwd" => {
                let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
                let m = cfg.rows(cfg.batch);
                s.prepare(cfg, m);
                with_dense_x(cfg, &inputs[2], s, |x, s| {
                    client_fwd_into(cfg, p, w1, b1, x, s)
                })?;
                Ok(vec![Array::f32(&[m, cfg.cut], s.z.clone())])
            }
            "server_step" => {
                let (w2, b2, w3, b3) = (
                    f32s(&inputs[0])?,
                    f32s(&inputs[1])?,
                    f32s(&inputs[2])?,
                    f32s(&inputs[3])?,
                );
                let y = labels(&inputs[4]);
                let zt = f32s(&inputs[5])?;
                let m = cfg.rows(cfg.batch);
                s.prepare(cfg, m);
                let (loss, sums) = server_step_into(cfg, p, w2, b2, w3, b3, y, zt, s)?;
                let mut outs = scalars(loss, sums);
                outs.push(Array::f32(&[m, cfg.cut], s.gz.clone()));
                outs.push(Array::f32(&[cfg.cut, cfg.hidden], s.g_w2.clone()));
                outs.push(Array::f32(&[cfg.hidden], s.g_b2.clone()));
                outs.push(Array::f32(&[cfg.hidden, cfg.classes], s.g_w3.clone()));
                outs.push(Array::f32(&[cfg.classes], s.g_b3.clone()));
                Ok(outs)
            }
            "client_bwd" => {
                let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
                let zt = f32s(&inputs[3])?;
                let grad_z = f32s(&inputs[4])?;
                let lambda = f32s(&inputs[5])?[0];
                s.prepare(cfg, cfg.rows(cfg.batch));
                let qerr = with_dense_x(cfg, &inputs[2], s, |x, s| {
                    client_bwd_into(cfg, p, w1, b1, x, zt, grad_z, lambda, s)
                })?;
                Ok(vec![
                    Array::f32(&[cfg.input, cfg.cut], s.g_w1.clone()),
                    Array::f32(&[cfg.cut], s.g_b1.clone()),
                    Array::f32(&[], vec![qerr as f32]),
                ])
            }
            "full_grad" => {
                let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
                let (w2, b2, w3, b3) = (
                    f32s(&inputs[2])?,
                    f32s(&inputs[3])?,
                    f32s(&inputs[4])?,
                    f32s(&inputs[5])?,
                );
                let y = labels(&inputs[7]);
                s.prepare(cfg, cfg.rows(cfg.batch));
                let (loss, sums) = with_dense_x(cfg, &inputs[6], s, |x, s| {
                    full_grad_into(cfg, p, w1, b1, w2, b2, w3, b3, x, y, s)
                })??;
                let mut outs = scalars(loss, sums);
                outs.push(Array::f32(&[cfg.input, cfg.cut], s.g_w1.clone()));
                outs.push(Array::f32(&[cfg.cut], s.g_b1.clone()));
                outs.push(Array::f32(&[cfg.cut, cfg.hidden], s.g_w2.clone()));
                outs.push(Array::f32(&[cfg.hidden], s.g_b2.clone()));
                outs.push(Array::f32(&[cfg.hidden, cfg.classes], s.g_w3.clone()));
                outs.push(Array::f32(&[cfg.classes], s.g_b3.clone()));
                Ok(outs)
            }
            "full_eval" => {
                let (w1, b1) = (f32s(&inputs[0])?, f32s(&inputs[1])?);
                let (w2, b2, w3, b3) = (
                    f32s(&inputs[2])?,
                    f32s(&inputs[3])?,
                    f32s(&inputs[4])?,
                    f32s(&inputs[5])?,
                );
                let y = labels(&inputs[7]);
                let m = cfg.rows(cfg.eval_batch);
                s.prepare(cfg, m);
                let (loss, sums) = with_dense_x(cfg, &inputs[6], s, |x, s| {
                    full_eval_into(cfg, p, w1, b1, w2, b2, w3, b3, x, y, m, s)
                })??;
                Ok(scalars(loss, sums))
            }
            other => anyhow::bail!("native engine has no artifact '{other}'"),
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new()
    }
}

// -- the compute layer (public: benches and the alloc audit drive it) --------
//
// Each `*_into` fills `EngineScratch` buffers prepared by the caller at
// the right batch size and allocates nothing. `anyhow` is only touched on
// error paths (label validation), so the Ok path stays allocation-free.

/// Client forward: `zpre = x @ w1 + b1`, `z = relu(zpre)` (`m = batch`).
pub fn client_fwd_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    x: &[f32],
    s: &mut EngineScratch,
) {
    let m = s.zpre.len() / cfg.cut;
    gemm::dense_into(x, w1, b1, m, cfg.input, cfg.cut, &mut s.zpre, p);
    relu_into(&s.zpre, &mut s.z);
}

/// Borrowed server-side buffers for [`server_pass`], split out of
/// [`EngineScratch`] so that `full_grad_into` can lend its
/// scratch-resident `z` as the cut input while the rest of the arena is
/// mutably lent.
struct ServerBufs<'a> {
    h1pre: &'a mut [f32],
    h1: &'a mut [f32],
    logits: &'a mut [f32],
    glogits: &'a mut [f32],
    dh1: &'a mut [f32],
    gz: &'a mut [f32],
    g_w2: &'a mut [f32],
    g_b2: &'a mut [f32],
    g_w3: &'a mut [f32],
    g_b3: &'a mut [f32],
}

/// Borrowed label view, dispatched to the variant's [`HeadKind`].
#[derive(Clone, Copy)]
pub enum Labels<'a> {
    /// One class/token id per row (`[m]` s32; token heads mask PAD).
    Classes(&'a [i32]),
    /// Multi-hot targets (`[m, classes]` f32).
    MultiHot(&'a [f32]),
}

/// View an input array as labels (dtype picks the variant; the head
/// dispatch rejects mismatches).
fn labels(a: &Array) -> Labels<'_> {
    match a {
        Array::F32 { data, .. } => Labels::MultiHot(data),
        Array::I32 { data, .. } => Labels::Classes(data),
    }
}

/// Run `f` against a dense `x` view: f32 inputs pass straight through;
/// s32 token inputs are one-hot expanded into the scratch's `xoh` buffer
/// first (moved out for the call so the borrows split; no allocation —
/// `prepare` already sized it).
fn with_dense_x<R>(
    cfg: &NativeModelCfg,
    x: &Array,
    s: &mut EngineScratch,
    f: impl FnOnce(&[f32], &mut EngineScratch) -> R,
) -> anyhow::Result<R> {
    match x {
        Array::F32 { data, .. } => Ok(f(data, s)),
        Array::I32 { data, .. } => {
            let mut xoh = std::mem::take(&mut s.xoh);
            let r = one_hot_into(data, cfg.input, &mut xoh).map(|()| f(&xoh, s));
            s.xoh = xoh;
            r
        }
    }
}

/// One-hot expand token ids into `out` (`[tokens.len(), vocab]`, fully
/// overwritten). Errors on an out-of-range token id.
fn one_hot_into(tokens: &[i32], vocab: usize, out: &mut [f32]) -> anyhow::Result<()> {
    anyhow::ensure!(
        out.len() == tokens.len() * vocab,
        "one-hot buffer sized {} for {} tokens of vocab {vocab}",
        out.len(),
        tokens.len()
    );
    out.fill(0.0);
    for (i, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            t >= 0 && (t as usize) < vocab,
            "token {t} at row {i} out of range for vocab {vocab}"
        );
        out[i * vocab + t as usize] = 1.0;
    }
    Ok(())
}

/// The server forward + loss + backward sequence, shared verbatim by
/// [`server_step_into`] and [`full_grad_into`] — one copy, so the
/// split-vs-monolithic exactness contract has a single source of truth.
#[allow(clippy::too_many_arguments)]
fn server_pass(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    y: Labels<'_>,
    zt: &[f32],
    m: usize,
    b: ServerBufs<'_>,
) -> anyhow::Result<(f64, [f64; 2])> {
    let ServerBufs { h1pre, h1, logits, glogits, dh1, gz, g_w2, g_b2, g_w3, g_b3 } = b;
    // forward
    gemm::dense_into(zt, w2, b2, m, cfg.cut, cfg.hidden, h1pre, p);
    relu_into(h1pre, h1);
    gemm::dense_into(h1, w3, b3, m, cfg.hidden, cfg.classes, logits, p);
    let (loss, sums) = head_loss_into(cfg, logits, y, m, glogits)?;
    // backward
    gemm::matmul_at_b_into(h1, glogits, m, cfg.hidden, cfg.classes, g_w3, p);
    gemm::colsum_into(glogits, m, cfg.classes, g_b3);
    gemm::matmul_a_bt_into(glogits, w3, m, cfg.classes, cfg.hidden, dh1, p);
    relu_backward(dh1, h1pre);
    gemm::matmul_at_b_into(zt, dh1, m, cfg.cut, cfg.hidden, g_w2, p);
    gemm::colsum_into(dh1, m, cfg.hidden, g_b2);
    gemm::matmul_a_bt_into(dh1, w2, m, cfg.hidden, cfg.cut, gz, p);
    Ok((loss, sums))
}

/// Server forward + loss + backward off the (possibly quantized) cut
/// activations `zt`. Fills `gz` (grad at the cut) and the server grads;
/// returns `(mean loss, metric sums)`. Errors on an out-of-range label.
#[allow(clippy::too_many_arguments)]
pub fn server_step_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    y: Labels<'_>,
    zt: &[f32],
    s: &mut EngineScratch,
) -> anyhow::Result<(f64, [f64; 2])> {
    let m = s.h1pre.len() / cfg.hidden;
    let bufs = ServerBufs {
        h1pre: &mut s.h1pre,
        h1: &mut s.h1,
        logits: &mut s.logits,
        glogits: &mut s.glogits,
        dh1: &mut s.dh1,
        gz: &mut s.gz,
        g_w2: &mut s.g_w2,
        g_b2: &mut s.g_b2,
        g_w3: &mut s.g_w3,
        g_b3: &mut s.g_b3,
    };
    server_pass(cfg, p, w2, b2, w3, b3, y, zt, m, bufs)
}

/// Client backward with the gradient correction (eq. (5)): recompute the
/// forward, add `λ·(z − z~)` to the returned cut gradient, backprop to
/// the client weights. Fills `g_w1`/`g_b1`; returns the squared
/// correction error `‖z − z~‖²`.
#[allow(clippy::too_many_arguments)]
pub fn client_bwd_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    x: &[f32],
    zt: &[f32],
    grad_z: &[f32],
    lambda: f32,
    s: &mut EngineScratch,
) -> f64 {
    let m = s.zpre.len() / cfg.cut;
    client_fwd_into(cfg, p, w1, b1, x, s);
    // gradient correction (eq. (5)): d/dz [λ/2 ‖z − z~‖²] = λ (z − z~)
    let mut qerr = 0.0f64;
    for i in 0..m * cfg.cut {
        let diff = s.z[i] - zt[i];
        qerr += (diff as f64) * (diff as f64);
        s.gz[i] = grad_z[i] + lambda * diff;
    }
    relu_backward(&mut s.gz, &s.zpre);
    gemm::matmul_at_b_into(x, &s.gz, m, cfg.input, cfg.cut, &mut s.g_w1, p);
    gemm::colsum_into(&s.gz, m, cfg.cut, &mut s.g_b1);
    qerr
}

/// Monolithic forward+backward: identical composition to the split path
/// with `z~ = z` and `λ = 0`, so split-vs-monolithic agreement is exact
/// by construction. Fills every gradient buffer; returns (loss, sums).
#[allow(clippy::too_many_arguments)]
pub fn full_grad_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    x: &[f32],
    y: Labels<'_>,
    s: &mut EngineScratch,
) -> anyhow::Result<(f64, [f64; 2])> {
    let m = s.zpre.len() / cfg.cut;
    client_fwd_into(cfg, p, w1, b1, x, s);
    // destructure the arena to split the borrows: the scratch-resident z
    // is lent to the server pass as zt while gz/h1*/logits are mutably
    // lent, exactly the server_step_into sequence (one copy of the math)
    let EngineScratch {
        zpre, z, h1pre, h1, logits, glogits, gz, dh1,
        g_w1, g_b1, g_w2, g_b2, g_w3, g_b3, xoh: _,
    } = s;
    let bufs = ServerBufs {
        h1pre,
        h1,
        logits,
        glogits,
        dh1,
        gz: &mut gz[..],
        g_w2,
        g_b2,
        g_w3,
        g_b3,
    };
    let (loss, sums) = server_pass(cfg, p, w2, b2, w3, b3, y, z, m, bufs)?;
    relu_backward(gz, zpre);
    gemm::matmul_at_b_into(x, gz, m, cfg.input, cfg.cut, g_w1, p);
    gemm::colsum_into(gz, m, cfg.cut, g_b1);
    Ok((loss, sums))
}

/// Forward-only eval over `m` rows; returns (loss, sums). The loss
/// gradient is still computed into the scratch (same arithmetic as the
/// historical engine) but unused.
#[allow(clippy::too_many_arguments)]
pub fn full_eval_into(
    cfg: &NativeModelCfg,
    p: GemmPolicy,
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    w3: &[f32],
    b3: &[f32],
    x: &[f32],
    y: Labels<'_>,
    m: usize,
    s: &mut EngineScratch,
) -> anyhow::Result<(f64, [f64; 2])> {
    gemm::dense_into(x, w1, b1, m, cfg.input, cfg.cut, &mut s.zpre, p);
    relu_into(&s.zpre, &mut s.z);
    gemm::dense_into(&s.z, w2, b2, m, cfg.cut, cfg.hidden, &mut s.h1pre, p);
    relu_into(&s.h1pre, &mut s.h1);
    gemm::dense_into(&s.h1, w3, b3, m, cfg.hidden, cfg.classes, &mut s.logits, p);
    head_loss_into(cfg, &s.logits, y, m, &mut s.glogits)
}

// -- manifest construction ---------------------------------------------------

fn variant_for(cfg: &NativeModelCfg) -> Variant {
    let x = |b: usize| match cfg.task {
        "femnist" => io("x", &[b, 28, 28, 1], "f32", "data"),
        "so_nwp" => io("x", &[b, cfg.seq], "s32", "data"),
        _ => io("x", &[b, cfg.input], "f32", "data"),
    };
    let y = |b: usize| match cfg.head {
        HeadKind::SoftmaxCe => io("y", &[b], "s32", "data"),
        HeadKind::SigmoidBce => io("y", &[b, cfg.classes], "f32", "data"),
        HeadKind::TokenSoftmaxCe => io("y", &[b, cfg.seq], "s32", "data"),
    };
    let client_params = || {
        vec![
            io("w1", &[cfg.input, cfg.cut], "f32", "param_client"),
            io("b1", &[cfg.cut], "f32", "param_client"),
        ]
    };
    let server_params = || {
        vec![
            io("w2", &[cfg.cut, cfg.hidden], "f32", "param_server"),
            io("b2", &[cfg.hidden], "f32", "param_server"),
            io("w3", &[cfg.hidden, cfg.classes], "f32", "param_server"),
            io("b3", &[cfg.classes], "f32", "param_server"),
        ]
    };

    let metric_names = cfg.head.metric_names();
    let with_metrics = |tail: &[&str]| -> Vec<String> {
        std::iter::once("loss")
            .chain(metric_names.iter().copied())
            .chain(tail.iter().copied())
            .map(str::to_string)
            .collect()
    };
    let rows = cfg.rows(cfg.batch);

    let mut artifacts = HashMap::new();
    let mut add = |meta: ArtifactMeta| {
        artifacts.insert(meta.name.clone(), meta);
    };
    let mut inputs = client_params();
    inputs.push(x(cfg.batch));
    add(art("client_fwd", inputs, vec!["z".to_string()]));

    let mut inputs = server_params();
    inputs.push(y(cfg.batch));
    inputs.push(io("z_tilde", &[rows, cfg.cut], "f32", "cut"));
    add(art(
        "server_step",
        inputs,
        with_metrics(&["grad_z", "g_w2", "g_b2", "g_w3", "g_b3"]),
    ));

    let mut inputs = client_params();
    inputs.push(x(cfg.batch));
    inputs.push(io("z_tilde", &[rows, cfg.cut], "f32", "cut"));
    inputs.push(io("grad_z", &[rows, cfg.cut], "f32", "grad_cut"));
    inputs.push(io("lambda", &[], "f32", "hyper"));
    add(art(
        "client_bwd",
        inputs,
        vec!["g_w1".to_string(), "g_b1".to_string(), "qerr".to_string()],
    ));

    let mut inputs = client_params();
    inputs.extend(server_params());
    inputs.push(x(cfg.batch));
    inputs.push(y(cfg.batch));
    add(art(
        "full_grad",
        inputs,
        with_metrics(&["g_w1", "g_b1", "g_w2", "g_b2", "g_w3", "g_b3"]),
    ));

    let mut inputs = client_params();
    inputs.extend(server_params());
    inputs.push(x(cfg.eval_batch));
    inputs.push(y(cfg.eval_batch));
    add(art("full_eval", inputs, with_metrics(&[])));

    let mut config = Object::new();
    config.insert("batch", Value::from_usize(cfg.batch));
    config.insert("eval_batch", Value::from_usize(cfg.eval_batch));
    let spec = ModelSpec {
        task: cfg.task.to_string(),
        preset: cfg.preset.to_string(),
        cut_dim: cfg.cut,
        act_batch: rows,
        batch: cfg.batch,
        eval_batch: cfg.eval_batch,
        client: SideSpec {
            params: vec![
                param("w1", &[cfg.input, cfg.cut], "glorot_uniform", cfg.input, cfg.cut),
                param("b1", &[cfg.cut], "zeros", cfg.cut, cfg.cut),
            ],
        },
        server: SideSpec {
            params: vec![
                param("w2", &[cfg.cut, cfg.hidden], "glorot_uniform", cfg.cut, cfg.hidden),
                param("b2", &[cfg.hidden], "zeros", cfg.hidden, cfg.hidden),
                param(
                    "w3",
                    &[cfg.hidden, cfg.classes],
                    "glorot_uniform",
                    cfg.hidden,
                    cfg.classes,
                ),
                param("b3", &[cfg.classes], "zeros", cfg.hidden, cfg.classes),
            ],
        },
        metrics: metric_names.iter().map(|m| m.to_string()).collect(),
        client_args: vec!["x".to_string()],
        server_args: vec!["y".to_string()],
        config: Value::Obj(config),
    };
    Variant { spec, artifacts }
}

fn io(name: &str, shape: &[usize], dtype: &str, role: &str) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
        role: role.to_string(),
    }
}

fn art(name: &str, inputs: Vec<IoSpec>, outputs: Vec<String>) -> ArtifactMeta {
    ArtifactMeta {
        name: name.to_string(),
        path: format!("native/{name}"),
        inputs,
        outputs,
        meta: Value::Null,
    }
}

fn param(name: &str, shape: &[usize], init: &str, fan_in: usize, fan_out: usize) -> ParamSpec {
    ParamSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        init: init.to_string(),
        scale: 1.0,
        fan_in,
        fan_out,
    }
}

// -- elementwise + loss (fixed reduction order => deterministic) -------------

fn f32s(a: &Array) -> anyhow::Result<&[f32]> {
    a.as_f32().ok_or_else(|| anyhow::anyhow!("expected f32 input"))
}

fn i32s(a: &Array) -> anyhow::Result<&[i32]> {
    a.as_i32().ok_or_else(|| anyhow::anyhow!("expected s32 input"))
}

fn relu_into(z: &[f32], out: &mut [f32]) {
    debug_assert_eq!(z.len(), out.len());
    for (o, &v) in out.iter_mut().zip(z) {
        *o = if v > 0.0 { v } else { 0.0 };
    }
}

/// Zero the gradient wherever the pre-activation was non-positive.
fn relu_backward(grad: &mut [f32], pre: &[f32]) {
    for (g, &p) in grad.iter_mut().zip(pre) {
        if p <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Mean softmax cross-entropy over the batch, gradient written into
/// `grad` (`[m, c]`, fully overwritten). Returns (mean loss,
/// correct-prediction count). Ties in the argmax resolve to the lowest
/// class index (fixed, deterministic). Labels are validated against `c`
/// up front: an out-of-range label is a data bug and surfaces as a
/// proper error, not an index-out-of-bounds panic mid-round.
fn softmax_ce_into(
    logits: &[f32],
    y: &[i32],
    m: usize,
    c: usize,
    grad: &mut [f32],
) -> anyhow::Result<(f64, f64)> {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(grad.len(), m * c);
    anyhow::ensure!(y.len() == m, "got {} labels for a batch of {m}", y.len());
    for (i, &yv) in y.iter().enumerate() {
        anyhow::ensure!(
            yv >= 0 && (yv as usize) < c,
            "label {yv} at row {i} out of range for {c} classes"
        );
    }
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let mut maxv = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                argmax = j;
            }
        }
        let g = &mut grad[i * c..(i + 1) * c];
        let mut sum = 0.0f32;
        for (gv, &v) in g.iter_mut().zip(row) {
            let e = (v - maxv).exp();
            *gv = e;
            sum += e;
        }
        let yi = y[i] as usize;
        loss -= (row[yi] - maxv) as f64 - (sum as f64).ln();
        if argmax == yi {
            correct += 1.0;
        }
        let inv = 1.0 / (sum * m as f32);
        for gv in g.iter_mut() {
            *gv *= inv;
        }
        g[yi] -= 1.0 / m as f32;
    }
    Ok((loss / m as f64, correct))
}

/// Dispatch the loss + metric computation to the variant's head. The
/// two-slot sums array carries up to two metric sums in
/// [`HeadKind::metric_names`] order (unused slots stay 0).
fn head_loss_into(
    cfg: &NativeModelCfg,
    logits: &[f32],
    y: Labels<'_>,
    m: usize,
    grad: &mut [f32],
) -> anyhow::Result<(f64, [f64; 2])> {
    match (cfg.head, y) {
        (HeadKind::SoftmaxCe, Labels::Classes(y)) => {
            let (loss, correct) = softmax_ce_into(logits, y, m, cfg.classes, grad)?;
            Ok((loss, [correct, 0.0]))
        }
        (HeadKind::SigmoidBce, Labels::MultiHot(y)) => {
            sigmoid_bce_into(logits, y, m, cfg.classes, grad)
        }
        (HeadKind::TokenSoftmaxCe, Labels::Classes(y)) => {
            token_softmax_ce_into(logits, y, m, cfg.classes, grad)
        }
        _ => anyhow::bail!(
            "label dtype does not match the {:?} head of '{}'",
            cfg.head,
            cfg.variant_key()
        ),
    }
}

/// Per-class sigmoid binary cross-entropy over multi-hot targets,
/// summed over classes and averaged over the `m` rows; gradient
/// `(σ(l) − y)/m` written into `grad`. Returns
/// `(mean loss, [hits_at_5, positives])` — the Recall@5 sums: how many
/// true tags land in the row's top-5 logits (deterministic: strict `>`
/// comparison, so ties keep the lowest class index) over how many true
/// tags there are.
fn sigmoid_bce_into(
    logits: &[f32],
    y: &[f32],
    m: usize,
    c: usize,
    grad: &mut [f32],
) -> anyhow::Result<(f64, [f64; 2])> {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(grad.len(), m * c);
    anyhow::ensure!(
        y.len() == m * c,
        "got {} targets for a [{m}, {c}] multi-hot batch",
        y.len()
    );
    let top = c.min(5);
    let mut loss = 0.0f64;
    let mut hits = 0.0f64;
    let mut positives = 0.0f64;
    let inv_m = 1.0 / m as f32;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let yr = &y[i * c..(i + 1) * c];
        let g = &mut grad[i * c..(i + 1) * c];
        // stable BCE-with-logits: max(l,0) − l·t + ln(1 + e^{−|l|})
        for j in 0..c {
            let l = row[j];
            let t = yr[j];
            loss += (l.max(0.0) - l * t + (-l.abs()).exp().ln_1p()) as f64;
            let sig = 1.0 / (1.0 + (-l).exp());
            g[j] = (sig - t) * inv_m;
        }
        // deterministic top-5: descending values, lowest index on ties
        let mut top_idx = [usize::MAX; 5];
        let mut top_val = [f32::NEG_INFINITY; 5];
        for (j, &v) in row.iter().enumerate() {
            let mut k = top;
            while k > 0 && v > top_val[k - 1] {
                k -= 1;
            }
            if k < top {
                for s in (k + 1..top).rev() {
                    top_val[s] = top_val[s - 1];
                    top_idx[s] = top_idx[s - 1];
                }
                top_val[k] = v;
                top_idx[k] = j;
            }
        }
        for &j in top_idx.iter().take(top) {
            if yr[j] > 0.0 {
                hits += 1.0;
            }
        }
        for &t in yr {
            if t > 0.0 {
                positives += 1.0;
            }
        }
    }
    Ok((loss / m as f64, [hits, positives]))
}

/// Softmax cross-entropy per sequence position with PAD targets masked
/// out: masked rows contribute no loss and a zero gradient row, and the
/// mean normalizes by the count of valid (non-PAD) targets. Returns
/// `(mean loss, [correct_tokens, valid_tokens])`.
fn token_softmax_ce_into(
    logits: &[f32],
    y: &[i32],
    m: usize,
    c: usize,
    grad: &mut [f32],
) -> anyhow::Result<(f64, [f64; 2])> {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(grad.len(), m * c);
    anyhow::ensure!(y.len() == m, "got {} targets for {m} token rows", y.len());
    for (i, &yv) in y.iter().enumerate() {
        anyhow::ensure!(
            yv >= 0 && (yv as usize) < c,
            "label {yv} at row {i} out of range for {c} classes"
        );
    }
    let valid = y.iter().filter(|&&yv| yv != PAD).count();
    if valid == 0 {
        grad.fill(0.0);
        return Ok((0.0, [0.0, 0.0]));
    }
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    for i in 0..m {
        let g = &mut grad[i * c..(i + 1) * c];
        if y[i] == PAD {
            g.fill(0.0);
            continue;
        }
        let row = &logits[i * c..(i + 1) * c];
        let mut maxv = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                argmax = j;
            }
        }
        let mut sum = 0.0f32;
        for (gv, &v) in g.iter_mut().zip(row) {
            let e = (v - maxv).exp();
            *gv = e;
            sum += e;
        }
        let yi = y[i] as usize;
        loss -= (row[yi] - maxv) as f64 - (sum as f64).ln();
        if argmax == yi {
            correct += 1.0;
        }
        let inv = 1.0 / (sum * valid as f32);
        for gv in g.iter_mut() {
            *gv *= inv;
        }
        g[yi] -= 1.0 / valid as f32;
    }
    Ok((loss / valid as f64, [correct, valid as f64]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn rand_inputs(cfg: &NativeModelCfg, seed: u64) -> (Vec<Array>, Vec<Array>) {
        // (full_grad inputs, client_fwd inputs) over shared params/batch
        let rt = Runtime::native();
        let spec = rt.manifest.variant(&cfg.variant_key()).unwrap().spec.clone();
        let rng = Rng::new(seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let mut r = rng.fork(3);
        let b = cfg.batch;
        let x = match cfg.task {
            "femnist" => Array::f32(&[b, 28, 28, 1], r.uniform_vec(b * cfg.input, 0.0, 1.0)),
            "so_nwp" => Array::i32(
                &[b, cfg.seq],
                (0..b * cfg.seq).map(|_| r.below(cfg.input) as i32).collect(),
            ),
            _ => Array::f32(&[b, cfg.input], r.uniform_vec(b * cfg.input, 0.0, 1.0)),
        };
        let y = match cfg.head {
            HeadKind::SoftmaxCe => {
                Array::i32(&[b], (0..b).map(|_| r.below(cfg.classes) as i32).collect())
            }
            HeadKind::SigmoidBce => {
                let mut t = vec![0.0f32; b * cfg.classes];
                for row in 0..b {
                    for _ in 0..3 {
                        t[row * cfg.classes + r.below(cfg.classes)] = 1.0;
                    }
                }
                Array::f32(&[b, cfg.classes], t)
            }
            HeadKind::TokenSoftmaxCe => Array::i32(
                &[b, cfg.seq],
                (0..b * cfg.seq).map(|_| r.below(cfg.classes) as i32).collect(),
            ),
        };
        let p = |t: &crate::tensor::Tensor| Array::f32(t.shape(), t.data().to_vec());
        let mut full: Vec<Array> = wc.tensors.iter().map(&p).collect();
        full.extend(ws.tensors.iter().map(&p));
        full.push(x.clone());
        full.push(y);
        let mut fwd: Vec<Array> = wc.tensors.iter().map(&p).collect();
        fwd.push(x);
        (full, fwd)
    }

    #[test]
    fn manifest_is_complete_and_consistent_for_every_variant() {
        let rt = Runtime::native();
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let v = rt.manifest.variant(&key).unwrap();
            for a in ["client_fwd", "server_step", "client_bwd", "full_grad", "full_eval"] {
                assert!(v.artifacts.contains_key(a), "{key}/{a} missing");
            }
            assert_eq!(v.spec.cut_dim, cfg.cut, "{key}");
            assert_eq!(v.spec.client.numel(), cfg.input * cfg.cut + cfg.cut, "{key}");
            assert_eq!(
                v.spec.server.numel(),
                cfg.cut * cfg.hidden + cfg.hidden + cfg.hidden * cfg.classes + cfg.classes,
                "{key}"
            );
            // param_client/param_server input order matches the SideSpec
            let fwd = v.artifacts.get("client_fwd").unwrap();
            assert_eq!(fwd.inputs[0].name, v.spec.client.params[0].name);
            assert_eq!(fwd.inputs[0].shape, v.spec.client.params[0].shape);
        }
        // the registry still serves the historical key
        assert!(NativeModelCfg::by_variant(VARIANT).is_some());
        assert_eq!(NativeModelCfg::by_preset("tiny").unwrap().cut, 32);
    }

    #[test]
    fn split_composition_equals_full_grad_exactly_on_every_variant() {
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let nm = cfg.head.metric_names().len();
            let engine = NativeEngine::new();
            let (full_in, fwd_in) = rand_inputs(cfg, 11);
            let full = engine.run(&key, "full_grad", &full_in).unwrap();

            let z = engine.run(&key, "client_fwd", &fwd_in).unwrap().remove(0);
            let step_in = vec![
                full_in[2].clone(), // w2
                full_in[3].clone(), // b2
                full_in[4].clone(), // w3
                full_in[5].clone(), // b3
                full_in[7].clone(), // y
                z.clone(),          // z_tilde = z
            ];
            let step = engine.run(&key, "server_step", &step_in).unwrap();
            let bwd_in = vec![
                full_in[0].clone(),         // w1
                full_in[1].clone(),         // b1
                full_in[6].clone(),         // x
                z,                          // z_tilde = z
                step[1 + nm].clone(),       // grad_z
                Array::f32(&[], vec![0.0]), // lambda = 0
            ];
            let bwd = engine.run(&key, "client_bwd", &bwd_in).unwrap();

            // z~ == z, λ == 0 → zero correction error and bit-identical grads
            assert_eq!(bwd[2].as_f32().unwrap()[0], 0.0, "{key} qerr");
            // loss + every metric sum agree
            for k in 0..=nm {
                assert_eq!(
                    step[k].as_f32().unwrap(),
                    full[k].as_f32().unwrap(),
                    "{key} scalar {k}"
                );
            }
            assert_eq!(bwd[0].as_f32().unwrap(), full[1 + nm].as_f32().unwrap(), "{key} g_w1");
            assert_eq!(bwd[1].as_f32().unwrap(), full[2 + nm].as_f32().unwrap(), "{key} g_b1");
            for (k, out) in ["g_w2", "g_b2", "g_w3", "g_b3"].iter().enumerate() {
                assert_eq!(
                    step[2 + nm + k].as_f32().unwrap(),
                    full[3 + nm + k].as_f32().unwrap(),
                    "{key} {out}"
                );
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences_on_every_variant() {
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let nm = cfg.head.metric_names().len();
            let engine = NativeEngine::new();
            let (full_in, _) = rand_inputs(cfg, 5);
            let outs = engine.run(&key, "full_grad", &full_in).unwrap();
            // probe the max-|grad| coordinate of each parameter tensor
            for pi in 0..6usize {
                let gi = 1 + nm + pi;
                let grads = outs[gi].as_f32().unwrap();
                let (idx, &g) = grads
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if g.abs() < 1e-5 {
                    continue; // too flat to measure against f32 loss noise
                }
                let eps = 1e-3f32;
                let probe = |delta: f32| -> f64 {
                    let mut inputs = full_in.clone();
                    if let Array::F32 { data, .. } = &mut inputs[pi] {
                        data[idx] += delta;
                    }
                    let o = engine.run(&key, "full_grad", &inputs).unwrap();
                    o[0].as_f32().unwrap()[0] as f64
                };
                let fd = (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
                let rel = (fd - g as f64).abs() / (g.abs() as f64).max(1e-6);
                // the loss output is f32, so central differences carry
                // ~1e-4 absolute noise at eps = 1e-3; accept either bound
                assert!(
                    rel < 0.05 || (fd - g as f64).abs() < 5e-4,
                    "{key} param {pi} idx {idx}: analytic {g} vs fd {fd} (rel {rel})"
                );
            }
        }
    }

    /// All kernel policies produce bit-identical artifact outputs on
    /// every variant, including the dsub-8, 1152-wide `stress` geometry
    /// (the engine-level view of the gemm exactness contract).
    #[test]
    fn kernel_policies_are_bit_identical_per_artifact() {
        for cfg in NativeModelCfg::registry() {
            let key = cfg.variant_key();
            let (full_in, fwd_in) = rand_inputs(cfg, 23);
            let engines = [
                NativeEngine::with_policy(GemmPolicy::naive()),
                NativeEngine::with_policy(GemmPolicy::tiled()),
                NativeEngine::with_policy(GemmPolicy::parallel(3)),
            ];
            let runs: Vec<_> = engines
                .iter()
                .map(|e| {
                    let z = e.run(&key, "client_fwd", &fwd_in).unwrap();
                    let full = e.run(&key, "full_grad", &full_in).unwrap();
                    (z, full)
                })
                .collect();
            for other in &runs[1..] {
                assert_eq!(
                    runs[0].0[0].as_f32().unwrap(),
                    other.0[0].as_f32().unwrap(),
                    "{key} z"
                );
                for (a, b) in runs[0].1.iter().zip(&other.1) {
                    assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(), "{key} full_grad");
                }
            }
        }
    }

    #[test]
    fn lambda_correction_shifts_client_gradient() {
        let cfg = NativeModelCfg::by_preset("tiny").unwrap();
        let engine = NativeEngine::new();
        let (full_in, fwd_in) = rand_inputs(cfg, 7);
        let z = engine.run(VARIANT, "client_fwd", &fwd_in).unwrap().remove(0);
        // perturb z~ away from z so the correction term is non-zero
        let zt = match &z {
            Array::F32 { shape, data } => {
                let mut d = data.clone();
                for v in d.iter_mut() {
                    *v += 0.1;
                }
                Array::f32(shape, d)
            }
            _ => unreachable!(),
        };
        let n = cfg.batch * cfg.cut;
        let grad_z = Array::f32(&[cfg.batch, cfg.cut], vec![0.0; n]);
        let run = |lambda: f32| {
            let bwd_in = vec![
                full_in[0].clone(),
                full_in[1].clone(),
                full_in[6].clone(),
                zt.clone(),
                grad_z.clone(),
                Array::f32(&[], vec![lambda]),
            ];
            engine.run(VARIANT, "client_bwd", &bwd_in).unwrap()
        };
        let with = run(0.5);
        let without = run(0.0);
        assert!(with[2].as_f32().unwrap()[0] > 0.0, "qerr must be positive");
        // λ = 0 with zero grad_z → zero client grads; λ > 0 → non-zero
        assert!(without[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(with[0].as_f32().unwrap().iter().any(|&v| v != 0.0));
    }

    /// Satellite: an out-of-range label is a proper error on every
    /// label-consuming artifact, not an index-out-of-bounds panic.
    #[test]
    fn out_of_range_labels_error_instead_of_panicking() {
        let cfg = NativeModelCfg::by_preset("tiny").unwrap();
        let engine = NativeEngine::new();
        let (mut full_in, fwd_in) = rand_inputs(cfg, 13);
        for bad in [cfg.classes as i32, -1, i32::MAX] {
            if let Array::I32 { data, .. } = &mut full_in[7] {
                data[2] = bad;
            }
            let err = engine.run(VARIANT, "full_grad", &full_in).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{bad}: {err}");

            // server_step sees the same labels through its own input slot
            let z = engine.run(VARIANT, "client_fwd", &fwd_in).unwrap().remove(0);
            let step_in = vec![
                full_in[2].clone(),
                full_in[3].clone(),
                full_in[4].clone(),
                full_in[5].clone(),
                full_in[7].clone(), // y (bad)
                z,
            ];
            let err = engine.run(VARIANT, "server_step", &step_in).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{bad}: {err}");
        }
        // full_eval validates too (eval batches come from the same data
        // plumbing)
        let eval_m = cfg.eval_batch;
        let mut eval_in = full_in.clone();
        eval_in[6] = Array::f32(&[eval_m, 28, 28, 1], vec![0.1; eval_m * cfg.input]);
        eval_in[7] = Array::i32(&[eval_m], vec![cfg.classes as i32; eval_m]);
        let err = engine.run(VARIANT, "full_eval", &eval_in).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    /// Warm scratch reuse is bit-identical to fresh scratches and keeps
    /// its buffer capacities (the steady-state contract run_scratch
    /// provides the trainers).
    #[test]
    fn scratch_reuse_is_bit_identical_and_capacity_stable() {
        let cfg = NativeModelCfg::by_preset("small").unwrap();
        let key = cfg.variant_key();
        let engine = NativeEngine::new();
        let (full_in, _) = rand_inputs(cfg, 31);
        let fresh = engine.run(&key, "full_grad", &full_in).unwrap();
        let mut scratch = EngineScratch::new();
        // warm-up sizes the buffers (full_eval is the largest batch)
        let _ = engine.run_scratch(&key, "full_grad", &full_in, &mut scratch).unwrap();
        let fp = scratch.capacity_fingerprint();
        for _ in 0..2 {
            let warm = engine
                .run_scratch(&key, "full_grad", &full_in, &mut scratch)
                .unwrap();
            for (a, b) in fresh.iter().zip(&warm) {
                assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
            }
            assert_eq!(scratch.capacity_fingerprint(), fp, "scratch reallocated");
        }
    }

    #[test]
    fn runtime_checks_shapes() {
        let rt = Runtime::native();
        let bad = vec![Array::f32(&[2, 2], vec![0.0; 4])];
        assert!(rt.run(VARIANT, "client_fwd", &bad).is_err());
        assert!(rt.run("nope", "client_fwd", &bad).is_err());
        assert!(rt.run(VARIANT, "nope", &bad).is_err());
    }

    /// The SO registry dims are pinned to the data-loader configs the
    /// trainers will actually serve (`small()` for every non-`paper`
    /// preset) — a drift here would fail shape checks mid-round.
    #[test]
    fn so_variant_dims_match_data_loader_configs() {
        use crate::data::{so_nwp::SoNwpConfig, so_tag::SoTagConfig};
        let tag = SoTagConfig::small();
        for preset in ["tiny", "small"] {
            let c = NativeModelCfg::by_task_preset("so_tag", preset).unwrap();
            assert_eq!(c.input, tag.vocab, "so_tag_{preset} input");
            assert_eq!(c.classes, tag.tags, "so_tag_{preset} classes");
            assert_eq!(c.seq, 1);
            assert_eq!(c.head, HeadKind::SigmoidBce);
        }
        let nwp = SoNwpConfig::small();
        for preset in ["tiny", "small"] {
            let c = NativeModelCfg::by_task_preset("so_nwp", preset).unwrap();
            assert_eq!(c.input, nwp.vocab, "so_nwp_{preset} input");
            assert_eq!(c.classes, nwp.vocab, "so_nwp_{preset} classes");
            assert_eq!(c.seq, nwp.seq, "so_nwp_{preset} seq");
            assert_eq!(c.head, HeadKind::TokenSoftmaxCe);
        }
        // femnist keyed lookups are unchanged by the multi-task registry
        assert_eq!(NativeModelCfg::by_preset("small").unwrap().task, "femnist");
    }

    #[test]
    fn recall_at_5_counts_true_tags_in_top5() {
        // 1 row, 8 classes; top-5 by logit are indices 0..5 descending;
        // true tags at 1 (inside the top-5) and 7 (outside)
        let logits = vec![5.0, 4.0, 3.0, 2.0, 1.0, 0.0, -1.0, -2.0];
        let mut y = vec![0.0f32; 8];
        y[1] = 1.0;
        y[7] = 1.0;
        let mut grad = vec![0.0f32; 8];
        let (loss, [hits, pos]) = sigmoid_bce_into(&logits, &y, 1, 8, &mut grad).unwrap();
        assert_eq!(hits, 1.0);
        assert_eq!(pos, 2.0);
        assert!(loss > 0.0);
        // gradient is σ(l) − y: positive where y = 0, negative where the
        // logit underestimates a true tag
        assert!(grad[0] > 0.0 && grad[1] < 0.0 && grad[7] < 0.0);
    }

    #[test]
    fn top5_ties_resolve_to_lowest_index() {
        // all-equal logits: the deterministic top-5 must be 0..5
        let logits = vec![1.0f32; 10];
        let mut y = vec![0.0f32; 10];
        y[4] = 1.0; // inside 0..5
        y[9] = 1.0; // outside
        let mut grad = vec![0.0f32; 10];
        let (_, [hits, pos]) = sigmoid_bce_into(&logits, &y, 1, 10, &mut grad).unwrap();
        assert_eq!(hits, 1.0);
        assert_eq!(pos, 2.0);
    }

    #[test]
    fn token_head_masks_padding_rows() {
        // 4 rows, 3 classes; rows 1 and 3 are PAD targets
        let logits = vec![
            1.0, 2.0, 0.5, //
            9.0, 9.0, 9.0, //
            0.1, 0.2, 3.0, //
            9.0, 9.0, 9.0, //
        ];
        let y: Vec<i32> = vec![1, PAD, 2, PAD];
        let mut grad = vec![7.0f32; 12];
        let (loss, [correct, valid]) =
            token_softmax_ce_into(&logits, &y, 4, 3, &mut grad).unwrap();
        assert_eq!(valid, 2.0);
        assert_eq!(correct, 2.0);
        assert!(loss > 0.0);
        assert!(grad[3..6].iter().all(|&g| g == 0.0), "PAD row grad not zeroed");
        assert!(grad[9..12].iter().all(|&g| g == 0.0), "PAD row grad not zeroed");
        // a valid softmax-CE gradient row sums to ~0
        let s: f32 = grad[0..3].iter().sum();
        assert!(s.abs() < 1e-6, "row grad sums to {s}");
    }

    #[test]
    fn out_of_range_tokens_error_instead_of_panicking() {
        let cfg = NativeModelCfg::by_task_preset("so_nwp", "tiny").unwrap();
        let key = cfg.variant_key();
        let engine = NativeEngine::new();
        let (mut full_in, _) = rand_inputs(cfg, 3);
        if let Array::I32 { data, .. } = &mut full_in[6] {
            data[0] = cfg.input as i32; // x token beyond the vocab
        }
        let err = engine.run(&key, "full_grad", &full_in).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }
}
