//! Metric aggregation and run summaries.
//!
//! Each task reports different raw metric sums from its AOT artifacts
//! (FEMNIST: correct-count; SO Tag: hits@5 + positives; SO NWP:
//! correct-tokens + valid-tokens); [`TaskMetric`] turns those sums into
//! the paper's headline numbers. [`RoundRecord`]/[`RunLog`] accumulate the
//! per-round series that the figures plot.

use crate::coordinator::faults::DropCounts;
use crate::util::json::{Object, Value};

/// Converts raw metric sums into the per-task headline metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskMetric {
    /// correct / examples.
    Accuracy,
    /// hits@5 / positives (StackOverflow tag prediction).
    RecallAt5,
    /// correct tokens / valid tokens.
    TokenAccuracy,
}

impl TaskMetric {
    pub fn for_task(task: &str) -> TaskMetric {
        match task {
            "so_tag" => TaskMetric::RecallAt5,
            "so_nwp" => TaskMetric::TokenAccuracy,
            _ => TaskMetric::Accuracy,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskMetric::Accuracy => "accuracy",
            TaskMetric::RecallAt5 => "recall_at_5",
            TaskMetric::TokenAccuracy => "token_accuracy",
        }
    }

    /// `sums` are the artifact's raw metric outputs in manifest order;
    /// `examples` is the number of examples evaluated (used when the
    /// denominator isn't part of the sums).
    pub fn value(&self, sums: &[f64], examples: f64) -> f64 {
        match self {
            TaskMetric::Accuracy => sums.first().copied().unwrap_or(0.0) / examples.max(1.0),
            TaskMetric::RecallAt5 | TaskMetric::TokenAccuracy => {
                let num = sums.first().copied().unwrap_or(0.0);
                let den = sums.get(1).copied().unwrap_or(0.0);
                if den > 0.0 {
                    num / den
                } else {
                    0.0
                }
            }
        }
    }
}

/// Everything recorded about one round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub train_metric: f64,
    pub eval_loss: Option<f64>,
    pub eval_metric: Option<f64>,
    /// Mean relative quantization error across selected clients.
    pub quant_error: f64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub cumulative_uplink: u64,
    pub wall_seconds: f64,
    pub sim_comm_seconds: f64,
    /// Clients sampled into the committed attempt's cohort.
    pub cohort_sampled: usize,
    /// Clients whose contribution reached the aggregate.
    pub cohort_survived: usize,
    /// Per-phase drop tally for the committed attempt.
    pub dropped: DropCounts,
    /// Sampling attempts this round took (1 = committed first try; see
    /// `coordinator::engine::RoundDriver`).
    pub attempts: u32,
    /// Weighted mean of the FedLite surrogate objective eq. (6),
    /// `⟨g, z⟩ + (λ/2)‖z − z̃‖²`, across surviving split clients.
    /// 0 for fedavg (no cut, nothing to correct) and unquantized runs.
    pub surrogate_loss: f64,
    /// Clients in the committed attempt's cohort whose fault plan carried
    /// a byzantine kind (ground truth from the attack schedule, not a
    /// detector output).
    pub byzantine_sampled: usize,
    /// Uploads the codeword-validation defense rejected this round
    /// (mirrors `dropped.rejected_codeword`, surfaced as its own column
    /// so the defense is grep-able without parsing the phase summary).
    pub rejected_codewords: usize,
    /// Survivor updates whose L2 norm exceeded `--clip-norm` and were
    /// scaled down before aggregation.
    pub clipped_updates: usize,
    /// Socket backend only: `StepAssign`s re-sent to a different member
    /// after a transport loss, straggler timeout, or peer failure.
    /// Transport telemetry, not computation — reassigned slots re-execute
    /// the same `(round, attempt, client)` work and every other column is
    /// unchanged. Always 0 in-process.
    pub reassigned_steps: usize,
    /// Socket backend only: members quarantined or reaped this round
    /// (straggler past the per-slot deadline, dead connection, protocol
    /// violation). Always 0 in-process.
    pub quarantined_members: usize,
}

impl RoundRecord {
    /// Column schema of the per-round CSV. One source of truth, shared by
    /// every trainer through the round engine's log writers and asserted
    /// against in CI (the cross-trainer schema diff): split and fedavg
    /// logs must carry identical columns and cohort bookkeeping or the
    /// paper's communication comparison is apples-to-oranges.
    pub const CSV_COLUMNS: [&'static str; 21] = [
        "round", "train_loss", "train_metric", "eval_loss", "eval_metric",
        "quant_error", "uplink_bytes", "downlink_bytes", "cumulative_uplink",
        "wall_seconds", "sim_comm_seconds", "cohort_sampled", "cohort_survived",
        "dropped_at_phase", "round_attempts", "surrogate_loss",
        "byzantine_sampled", "rejected_codewords", "clipped_updates",
        "reassigned_steps", "quarantined_members",
    ];

    /// Render this record as one CSV row in [`RoundRecord::CSV_COLUMNS`]
    /// order. The formatting is part of the golden bit-identity contract
    /// (`rust/tests/determinism.rs`): do not change widths or precision
    /// without re-blessing the fixtures.
    pub fn csv_row(&self) -> Vec<String> {
        vec![
            self.round.to_string(),
            format!("{:.6}", self.train_loss),
            format!("{:.6}", self.train_metric),
            self.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            self.eval_metric.map(|v| format!("{v:.6}")).unwrap_or_default(),
            format!("{:.6}", self.quant_error),
            self.uplink_bytes.to_string(),
            self.downlink_bytes.to_string(),
            self.cumulative_uplink.to_string(),
            format!("{:.4}", self.wall_seconds),
            format!("{:.4}", self.sim_comm_seconds),
            self.cohort_sampled.to_string(),
            self.cohort_survived.to_string(),
            self.dropped.summary(),
            self.attempts.to_string(),
            format!("{:.6}", self.surrogate_loss),
            self.byzantine_sampled.to_string(),
            self.rejected_codewords.to_string(),
            self.clipped_updates.to_string(),
            self.reassigned_steps.to_string(),
            self.quarantined_members.to_string(),
        ]
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("round", Value::from_usize(self.round));
        o.insert("train_loss", Value::Num(self.train_loss));
        o.insert("train_metric", Value::Num(self.train_metric));
        if let Some(l) = self.eval_loss {
            o.insert("eval_loss", Value::Num(l));
        }
        if let Some(m) = self.eval_metric {
            o.insert("eval_metric", Value::Num(m));
        }
        o.insert("quant_error", Value::Num(self.quant_error));
        o.insert("uplink_bytes", Value::Num(self.uplink_bytes as f64));
        o.insert("downlink_bytes", Value::Num(self.downlink_bytes as f64));
        o.insert("cumulative_uplink", Value::Num(self.cumulative_uplink as f64));
        o.insert("wall_seconds", Value::Num(self.wall_seconds));
        o.insert("sim_comm_seconds", Value::Num(self.sim_comm_seconds));
        o.insert("cohort_sampled", Value::from_usize(self.cohort_sampled));
        o.insert("cohort_survived", Value::from_usize(self.cohort_survived));
        o.insert("dropped_at_phase", Value::Str(self.dropped.summary()));
        o.insert("round_attempts", Value::from_usize(self.attempts as usize));
        o.insert("surrogate_loss", Value::Num(self.surrogate_loss));
        o.insert("byzantine_sampled", Value::from_usize(self.byzantine_sampled));
        o.insert("rejected_codewords", Value::from_usize(self.rejected_codewords));
        o.insert("clipped_updates", Value::from_usize(self.clipped_updates));
        o.insert("reassigned_steps", Value::from_usize(self.reassigned_steps));
        o.insert(
            "quarantined_members",
            Value::from_usize(self.quarantined_members),
        );
        Value::Obj(o)
    }
}

/// The full per-run series plus summary statistics.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub rounds: Vec<RoundRecord>,
}

impl RunLog {
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    /// Best evaluation metric seen (higher is better).
    pub fn best_eval_metric(&self) -> Option<f64> {
        self.rounds
            .iter()
            .filter_map(|r| r.eval_metric)
            .fold(None, |m, v| Some(m.map_or(v, |m: f64| m.max(v))))
    }

    /// Final-k average of eval metric (robust to last-round noise).
    pub fn final_eval_metric(&self, k: usize) -> Option<f64> {
        let vals: Vec<f64> = self.rounds.iter().filter_map(|r| r.eval_metric).collect();
        if vals.is_empty() {
            return None;
        }
        let k = k.min(vals.len()).max(1);
        Some(vals[vals.len() - k..].iter().sum::<f64>() / k as f64)
    }

    pub fn total_uplink(&self) -> u64 {
        self.rounds.last().map(|r| r.cumulative_uplink).unwrap_or(0)
    }

    /// Mean train loss over the final k rounds.
    pub fn final_train_loss(&self, k: usize) -> f64 {
        if self.rounds.is_empty() {
            return f64::NAN;
        }
        let k = k.min(self.rounds.len()).max(1);
        self.rounds[self.rounds.len() - k..]
            .iter()
            .map(|r| r.train_loss)
            .sum::<f64>()
            / k as f64
    }
}

/// Online mean/min/max accumulator used by per-round stats.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stat {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Stat {
    pub fn add(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_metric_mapping() {
        assert_eq!(TaskMetric::for_task("femnist"), TaskMetric::Accuracy);
        assert_eq!(TaskMetric::for_task("so_tag"), TaskMetric::RecallAt5);
        assert_eq!(TaskMetric::for_task("so_nwp"), TaskMetric::TokenAccuracy);
    }

    #[test]
    fn metric_values() {
        assert_eq!(TaskMetric::Accuracy.value(&[30.0], 100.0), 0.3);
        assert_eq!(TaskMetric::RecallAt5.value(&[12.0, 48.0], 100.0), 0.25);
        assert_eq!(TaskMetric::TokenAccuracy.value(&[0.0, 0.0], 10.0), 0.0);
    }

    #[test]
    fn run_log_summaries() {
        let mut log = RunLog::default();
        for i in 0..10 {
            log.push(RoundRecord {
                round: i,
                train_loss: 10.0 - i as f64,
                eval_metric: Some(0.1 * i as f64),
                cumulative_uplink: (i as u64 + 1) * 100,
                ..Default::default()
            });
        }
        assert_eq!(log.best_eval_metric(), Some(0.9));
        assert!((log.final_eval_metric(3).unwrap() - 0.8).abs() < 1e-12);
        assert_eq!(log.total_uplink(), 1000);
        assert!((log.final_train_loss(2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn stat_accumulates() {
        let mut s = Stat::default();
        for v in [1.0, 3.0, 2.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn round_record_json() {
        let r = RoundRecord { round: 3, train_loss: 1.5, ..Default::default() };
        let j = r.to_json();
        assert_eq!(j.get("round").as_usize(), Some(3));
        assert_eq!(j.get("train_loss").as_f64(), Some(1.5));
        assert_eq!(j.get("eval_loss").as_f64(), None);
    }

    #[test]
    fn csv_row_matches_schema() {
        let r = RoundRecord {
            round: 2,
            train_loss: 1.25,
            eval_loss: Some(0.5),
            uplink_bytes: 42,
            attempts: 3,
            surrogate_loss: 0.125,
            byzantine_sampled: 2,
            rejected_codewords: 1,
            clipped_updates: 4,
            reassigned_steps: 5,
            quarantined_members: 1,
            ..Default::default()
        };
        let row = r.csv_row();
        assert_eq!(row.len(), RoundRecord::CSV_COLUMNS.len());
        assert_eq!(row[0], "2");
        assert_eq!(row[1], "1.250000");
        assert_eq!(row[3], "0.500000");
        assert_eq!(row[4], "", "absent eval metric renders empty");
        assert_eq!(row[6], "42");
        assert_eq!(row[14], "3");
        assert_eq!(row[15], "0.125000");
        assert_eq!(row[16], "2");
        assert_eq!(row[17], "1");
        assert_eq!(row[18], "4");
        assert_eq!(row[19], "5");
        assert_eq!(row[20], "1");
        // the schema itself is load-bearing for the CI cross-trainer diff
        assert_eq!(RoundRecord::CSV_COLUMNS[9], "wall_seconds");
        assert_eq!(RoundRecord::CSV_COLUMNS[13], "dropped_at_phase");
        // schema growth is append-only so fixtures blessed on older,
        // shorter schemas can be compared by header projection
        assert_eq!(RoundRecord::CSV_COLUMNS[15], "surrogate_loss");
        assert_eq!(RoundRecord::CSV_COLUMNS[18], "clipped_updates");
        assert_eq!(RoundRecord::CSV_COLUMNS[19], "reassigned_steps");
        assert_eq!(RoundRecord::CSV_COLUMNS[20], "quarantined_members");
    }

    #[test]
    fn round_record_json_cohort_fields() {
        use crate::coordinator::faults::DropPhase;
        let mut dropped = DropCounts::default();
        dropped.add(DropPhase::AfterUpload);
        let r = RoundRecord {
            round: 1,
            cohort_sampled: 4,
            cohort_survived: 3,
            dropped,
            attempts: 2,
            ..Default::default()
        };
        let j = r.to_json();
        assert_eq!(j.get("cohort_sampled").as_usize(), Some(4));
        assert_eq!(j.get("cohort_survived").as_usize(), Some(3));
        assert_eq!(j.get("dropped_at_phase").as_str(), Some("after_upload:1"));
        assert_eq!(j.get("round_attempts").as_usize(), Some(2));
    }
}
