//! Worker-thread utilities (no `tokio`/`rayon` in the offline build).
//!
//! Provides a fixed-size [`ThreadPool`] with `execute` (fire-and-forget)
//! and `parallel_map` (ordered results over owned, `'static` items),
//! [`scoped_parallel_map`] (ordered results over *borrowed* state — the
//! coordinator's per-round cohort fan-out runs through this), and a
//! scoped chunked for-each used by the data generators and the quantizer
//! sweeps. `ThreadPool::default_size()` is the resolution of the
//! `--workers 0` (auto) setting.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple shared-queue thread pool.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("fedlite-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers }
    }

    /// Pool sized to the machine (logical cores, capped).
    pub fn default_size() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Apply `f` to each item (items moved in), returning results in input
    /// order. Blocks until all complete. Panics in jobs poison the result
    /// slot and are re-raised here.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f(i, item)
                }));
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("worker died");
            match r {
                Ok(v) => slots[i] = Some(v),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Ordered parallel map over `items` using up to `workers` scoped threads.
///
/// Unlike [`ThreadPool::parallel_map`], the closure may borrow
/// non-`'static` state (model parameters, the metered network, the
/// dataset), which is exactly what the per-round client fan-out needs.
/// Items are claimed from a shared atomic counter, results land in their
/// input slot, so the output order — and therefore any order-sensitive
/// reduction performed over it — is independent of thread scheduling.
/// `workers <= 1` (or fewer than two items) runs inline on the caller's
/// thread: the serial path spawns nothing and is the exact pre-parallel
/// behavior.
///
/// A panic inside `f` is propagated to the caller after all workers
/// finish (via `std::thread::scope`). There is deliberately no
/// error short-circuit: when `R` is a `Result`, every item still runs
/// and the caller sees the first `Err` during its ordered reduction —
/// at most one round of extra work on a path that is about to abort.
///
/// Trade-off: this spawns fresh scoped threads per call rather than
/// routing borrowed closures through the persistent [`ThreadPool`]
/// (whose job queue requires `'static`). At cohort scale the spawn cost
/// (~tens of µs/thread, once per round) is noise next to a client step;
/// if profiling ever says otherwise, the fix is a scoped-submit facade
/// over the pool, not more call sites of this function.
pub fn scoped_parallel_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if workers <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    type Slot<T, R> = Mutex<(Option<T>, Option<R>)>;
    let slots: Vec<Slot<T, R>> = items
        .into_iter()
        .map(|x| Mutex::new((Some(x), None)))
        .collect();
    thread::scope(|s| {
        for _ in 0..workers.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().0.take().expect("item claimed once");
                let out = f(i, item);
                slots[i].lock().unwrap().1 = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().1.expect("worker filled slot"))
        .collect()
}

/// Chunked parallel for-each over a mutable slice using scoped threads:
/// splits `data` into `chunks` contiguous pieces and runs `f(chunk_index,
/// start_offset, chunk)` concurrently. Used by data generators that fill
/// large buffers.
pub fn scoped_chunks<T: Send, F>(data: &mut [T], chunks: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunks = chunks.clamp(1, n);
    let base = n / chunks;
    let rem = n % chunks;
    thread::scope(|s| {
        let mut rest = data;
        let mut offset = 0;
        for i in 0..chunks {
            let len = base + usize::from(i < rem);
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let f = &f;
            let start = offset;
            s.spawn(move || f(i, start, head));
            offset += len;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.parallel_map((0..50).collect(), |_, x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.parallel_map(Vec::<i32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panics_propagate() {
        let pool = ThreadPool::new(2);
        let _ = pool.parallel_map(vec![1, 2, 3], |_, x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn scoped_map_preserves_order_over_borrowed_state() {
        // non-'static borrow: the closure reads a local Vec by reference
        let table: Vec<u64> = (0..200).map(|i| i * 3).collect();
        let items: Vec<usize> = (0..200).collect();
        let serial: Vec<u64> = items.iter().map(|&x| table[x] + x as u64).collect();
        let out = scoped_parallel_map(4, items, |i, x| {
            assert_eq!(i, x);
            table[x] + x as u64
        });
        assert_eq!(out, serial);
    }

    #[test]
    fn scoped_map_workers_one_runs_inline() {
        let tid = thread::current().id();
        let out = scoped_parallel_map(1, vec![1, 2, 3], |_, x: i32| {
            assert_eq!(thread::current().id(), tid);
            x * 10
        });
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn scoped_map_empty_and_single() {
        let out: Vec<i32> = scoped_parallel_map(8, Vec::new(), |_, x| x);
        assert!(out.is_empty());
        let out = scoped_parallel_map(8, vec![7], |i, x: i32| x + i as i32);
        assert_eq!(out, vec![7]);
    }

    #[test]
    #[should_panic(expected = "scoped boom")]
    fn scoped_map_panics_propagate() {
        let _ = scoped_parallel_map(3, (0..10).collect::<Vec<i32>>(), |_, x| {
            if x == 5 {
                panic!("scoped boom");
            }
            x
        });
    }

    #[test]
    fn scoped_chunks_covers_slice() {
        let mut v = vec![0usize; 103];
        scoped_chunks(&mut v, 7, |_, start, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = start + k;
            }
        });
        assert_eq!(v, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_chunks_more_chunks_than_items() {
        let mut v = vec![0u8; 3];
        scoped_chunks(&mut v, 10, |_, _, chunk| {
            for x in chunk.iter_mut() {
                *x = 1;
            }
        });
        assert_eq!(v, vec![1, 1, 1]);
    }
}
