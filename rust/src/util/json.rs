//! Minimal JSON parser and writer (no `serde` in the offline build).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes and
//! `\uXXXX`, numbers, booleans, null). Object key order is preserved so
//! manifests round-trip readably. Used for `artifacts/manifest.json`,
//! run configs, checkpoints, and metric logs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Keys kept in insertion order via parallel vec (BTreeMap for lookup).
    Obj(Object),
}

/// JSON object preserving insertion order with O(log n) lookup.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Object {
    order: Vec<String>,
    map: BTreeMap<String, Value>,
}

impl Object {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, val: Value) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, val);
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&Object> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `value["a"]["b"]`-style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    /// Shape-style vec of usize from a JSON array.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- construction helpers ------------------------------------------------

    pub fn from_usize(n: usize) -> Value {
        Value::Num(n as f64)
    }

    pub fn arr_of_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::from_usize(x)).collect())
    }

    pub fn arr_of_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    // -- serialization --------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        newline(out, lvl + 1);
                        v.write(out, Some(lvl + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(lvl) = indent {
                    if !a.is_empty() {
                        newline(out, lvl);
                    }
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        newline(out, lvl + 1);
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(lvl + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(lvl) = indent {
                    if !o.is_empty() {
                        newline(out, lvl);
                    }
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    /// Compact (single-line) serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn newline(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str(" ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut obj = Object::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                _ => {
                    // copy one UTF-8 char
                    let start = self.i;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").get("c").as_bool(), Some(true));
        assert_eq!(v.get("b").get("d"), &Value::Null);
        assert_eq!(v.get("e").as_str(), Some("x\ny"));
        // reparse of serialization equals original value
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse(r#"{"a":1} extra"#).is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers_precise() {
        let v = parse("[0, -0.5, 1e-3, 123456789]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_usize(), Some(0));
        assert_eq!(a[1].as_f64(), Some(-0.5));
        assert_eq!(a[2].as_f64(), Some(0.001));
        assert_eq!(a[3].as_usize(), Some(123456789));
        assert_eq!(a[1].as_usize(), None); // not a usize
    }

    #[test]
    fn missing_paths_are_null() {
        let v = parse(r#"{"a": {"b": 1}}"#).unwrap();
        assert_eq!(v.get("a").get("nope").get("deeper"), &Value::Null);
        assert_eq!(v.idx(3), &Value::Null);
    }

    #[test]
    fn usize_vec() {
        let v = parse("[3, 3, 1, 32]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![3, 3, 1, 32]));
        assert_eq!(parse("[1, 2.5]").unwrap().as_usize_vec(), None);
    }
}
