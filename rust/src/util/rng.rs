//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded through SplitMix64, plus the samplers the simulation
//! needs: uniform, normal (Box–Muller), Bernoulli, gamma (Marsaglia–Tsang),
//! Dirichlet, Zipf, categorical, and Fisher–Yates shuffling. Every
//! component of the system derives its stream from a root seed via
//! [`Rng::fork`], so runs are reproducible regardless of thread scheduling.

/// xoshiro256++ PRNG (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single `u64`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream for a named sub-component.
    ///
    /// Uses a hash of `(next_u64 of a clone, tag)` so that forks are stable
    /// with respect to the parent's state at fork time and distinct per tag.
    pub fn fork(&self, tag: u64) -> Rng {
        let mut base = self.s[0] ^ self.s[2];
        let mut sm = base ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        base = splitmix64(&mut sm);
        Rng::new(base ^ splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. Panics on `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free for practical purposes (bias < 2^-64*n)
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang; valid for any k > 0.
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0);
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over `n` categories.
    pub fn dirichlet_sym(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            // pathological underflow: fall back to a random one-hot
            let mut out = vec![0.0; n];
            out[self.below(n)] = 1.0;
            return out;
        }
        v.iter_mut().for_each(|x| *x /= s);
        v
    }

    /// Sample an index from an (unnormalized) non-negative weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (s > 0).
    ///
    /// Precomputing the CDF is the caller's job for hot loops; this is the
    /// simple O(n)-free inverse-CDF approximation adequate for data gen.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // rejection sampling from the continuous bounding envelope
        debug_assert!(n >= 1);
        let n_f = n as f64;
        loop {
            let u = self.uniform();
            // inverse of the integral of x^-s over [1, n+1]
            let x = if (s - 1.0).abs() < 1e-9 {
                ((n_f + 1.0).ln() * u).exp()
            } else {
                let a = 1.0 - s;
                ((u * ((n_f + 1.0).powf(a) - 1.0)) + 1.0).powf(1.0 / a)
            };
            let k = x.floor();
            if k >= 1.0 && k <= n_f {
                // accept with prob proportional to k^-s / envelope
                let accept = (k.powf(-s)) / (x.powf(-s)).max(f64::MIN_POSITIVE);
                if self.uniform() < accept.min(1.0) {
                    return k as usize - 1;
                }
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.choose_k_into(n, k, &mut idx);
        idx
    }

    /// Populations at or below this size use the legacy partial
    /// Fisher–Yates path in [`Rng::choose_k_into`] (O(n) scratch, the
    /// stream every base-blessed golden fixture was produced with);
    /// larger populations switch to Floyd's O(k) algorithm. The cutover
    /// sits far above every committed preset/scenario population (the
    /// presets default to 100 clients; the golden scenarios use 8), so
    /// existing emitted bits are untouched while million-client configs
    /// never materialize `0..n`.
    pub const CHOOSE_K_DENSE_MAX: usize = 1 << 16;

    /// Allocation-free [`Rng::choose_k`]: leaves the `k` chosen indices in
    /// `scratch[..k]`, reusing its capacity.
    ///
    /// Stream contract: for `n <= CHOOSE_K_DENSE_MAX` this consumes
    /// exactly the legacy stream (`k` draws of `below`) via partial
    /// Fisher–Yates over a materialized `0..n` — bit-compatible with
    /// every fixture blessed before the Floyd's path existed. For larger
    /// `n` it runs Floyd's algorithm instead: still exactly `k` draws of
    /// `below`, but a *different* stream (and O(k) time/space, never
    /// touching the full range). Both paths yield uniform k-subsets.
    pub fn choose_k_into(&mut self, n: usize, k: usize, scratch: &mut Vec<usize>) {
        assert!(k <= n, "choose_k({k}) from {n}");
        scratch.clear();
        if n <= Self::CHOOSE_K_DENSE_MAX {
            scratch.extend(0..n);
            for i in 0..k {
                let j = i + self.below(n - i);
                scratch.swap(i, j);
            }
            scratch.truncate(k);
        } else {
            // Floyd's uniform k-subset sampling: O(k) with a warm scratch.
            // The linear `contains` scan is fine at cohort scale (k is the
            // per-round cohort, not the population).
            for j in (n - k)..n {
                let t = self.below(j + 1);
                if scratch.contains(&t) {
                    scratch.push(j);
                } else {
                    scratch.push(t);
                }
            }
        }
    }

    /// Fill a slice with scaled Bernoulli dropout mask values
    /// (`1/(1-p)` with probability `1-p`, else `0`).
    pub fn dropout_mask(&mut self, p: f64, out: &mut [f32]) {
        let scale = if p < 1.0 { 1.0 / (1.0 - p) } else { 0.0 };
        for v in out.iter_mut() {
            *v = if self.uniform() >= p { scale as f32 } else { 0.0 };
        }
    }

    /// Vector of standard normals as f32 (parameter init, synthetic data).
    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_ms(mean as f64, std as f64) as f32).collect()
    }

    /// Vector of uniforms in `[lo, hi)` as f32.
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_in(lo as f64, hi as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent_and_stable() {
        let root = Rng::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        let mut f1b = root.fork(1);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet_sym(alpha, 20);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(5);
        for &k in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let m: f64 = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((m - k).abs() / k < 0.1, "k={k} mean={m}");
        }
    }

    #[test]
    fn zipf_rank_zero_most_frequent() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[0] > counts[9]);
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(8);
        for _ in 0..100 {
            let mut ks = r.choose_k(20, 10);
            ks.sort_unstable();
            ks.dedup();
            assert_eq!(ks.len(), 10);
        }
    }

    #[test]
    fn choose_k_dense_stream_is_the_legacy_partial_fisher_yates() {
        // the exact draw sequence golden fixtures depend on: materialize
        // 0..n, then k swaps driven by below(n - i)
        let n = 12;
        let k = 5;
        let mut r = Rng::new(77);
        let got = r.choose_k(n, k);
        let mut expect: Vec<usize> = (0..n).collect();
        let mut r2 = Rng::new(77);
        for i in 0..k {
            let j = i + r2.below(n - i);
            expect.swap(i, j);
        }
        expect.truncate(k);
        assert_eq!(got, expect);
    }

    #[test]
    fn choose_k_floyds_path_distinct_in_range_and_o_cohort() {
        // above the dense cutover: Floyd's path, still k distinct indices
        // drawn uniformly from [0, n) without touching the full range
        let n = Rng::CHOOSE_K_DENSE_MAX + 1_000_000;
        let k = 64;
        let mut r = Rng::new(13);
        let mut scratch = Vec::new();
        for round in 0..20 {
            r.choose_k_into(n, k, &mut scratch);
            assert_eq!(scratch.len(), k, "round {round}");
            assert!(scratch.capacity() < 4 * k, "Floyd's path grew O(n) scratch");
            assert!(scratch.iter().all(|&c| c < n));
            let mut sorted = scratch.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in round {round}");
        }
    }

    #[test]
    fn choose_k_floyds_path_covers_the_range() {
        // ids from every region of a large population should appear: the
        // sampler is not confined to the tail window Floyd's iterates over
        let n = Rng::CHOOSE_K_DENSE_MAX * 16;
        let mut r = Rng::new(14);
        let mut scratch = Vec::new();
        let mut low = 0usize; // ids in the first half of the range
        let mut draws = 0usize;
        for _ in 0..200 {
            r.choose_k_into(n, 32, &mut scratch);
            low += scratch.iter().filter(|&&c| c < n / 2).count();
            draws += scratch.len();
        }
        let frac = low as f64 / draws as f64;
        assert!((frac - 0.5).abs() < 0.05, "first-half fraction {frac}");
    }

    #[test]
    fn dropout_mask_scaling() {
        let mut r = Rng::new(9);
        let mut m = vec![0.0f32; 100_000];
        r.dropout_mask(0.25, &mut m);
        let mean: f64 = m.iter().map(|&x| x as f64).sum::<f64>() / m.len() as f64;
        assert!((mean - 1.0).abs() < 0.02, "E[mask] should be ~1, got {mean}");
        assert!(m.iter().all(|&x| x == 0.0 || (x - 4.0 / 3.0).abs() < 1e-6));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
