//! Self-contained substrates: PRNG, JSON, CLI parsing, thread pool, logging.
//!
//! The offline build environment ships no `rand`/`serde`/`clap`/`tokio`, so
//! the coordinator carries its own implementations. Each is deliberately
//! small, deterministic, and unit-tested — they are load-bearing for
//! reproducibility (every experiment seed flows through [`rng`]).

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod bench;
pub mod rng;
