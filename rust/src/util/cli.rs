//! Declarative command-line parsing (no `clap` in the offline build).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! repeated flags, and auto-generated `--help`. Intentionally small: the
//! `fedlite` binary's surface is a handful of experiment/train subcommands.

use std::collections::BTreeMap;

/// Specification of one flag.
#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
    pub repeated: bool,
}

impl Flag {
    pub fn opt(name: &'static str, default: &'static str, help: &'static str) -> Flag {
        Flag { name, help, default: Some(default), is_switch: false, repeated: false }
    }

    pub fn req(name: &'static str, help: &'static str) -> Flag {
        Flag { name, help, default: None, is_switch: false, repeated: false }
    }

    pub fn switch(name: &'static str, help: &'static str) -> Flag {
        Flag { name, help, default: None, is_switch: true, repeated: false }
    }

    pub fn multi(name: &'static str, help: &'static str) -> Flag {
        Flag { name, help, default: None, is_switch: false, repeated: true }
    }
}

/// Parsed flag values for one invocation.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    pub fn usize(&self, name: &str) -> anyhow::Result<usize> {
        parse_num(self.get(name), name)
    }

    pub fn u64(&self, name: &str) -> anyhow::Result<u64> {
        parse_num(self.get(name), name)
    }

    pub fn f64(&self, name: &str) -> anyhow::Result<f64> {
        parse_num(self.get(name), name)
    }

    /// A probability-valued flag: parsed as f64 and validated into [0, 1].
    pub fn prob(&self, name: &str) -> anyhow::Result<f64> {
        let v: f64 = parse_num(self.get(name), name)?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&v),
            "--{name} must be a probability in [0, 1], got {v}"
        );
        Ok(v)
    }

    pub fn str(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))
    }
}

fn parse_num<T: std::str::FromStr>(v: Option<&str>, name: &str) -> anyhow::Result<T>
where
    T::Err: std::fmt::Display,
{
    let s = v.ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))?;
    s.parse::<T>()
        .map_err(|e| anyhow::anyhow!("bad value '{s}' for --{name}: {e}"))
}

/// A subcommand with its flags.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

/// Top-level CLI: a set of subcommands.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

/// Result of parsing: which subcommand + its args.
#[derive(Debug)]
pub struct Invocation {
    pub command: &'static str,
    pub args: Args,
}

impl Cli {
    /// Parse `argv[1..]`. Returns Err with a usage/help message when the
    /// input is invalid or `--help` was requested (caller prints + exits).
    pub fn parse(&self, argv: &[String]) -> Result<Invocation, String> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(self.usage());
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name.as_str())
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;
        let mut args = Args::default();
        // seed defaults
        for f in &cmd.flags {
            if let Some(d) = f.default {
                args.values.insert(f.name.to_string(), vec![d.to_string()]);
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.command_usage(cmd));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let flag = cmd
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        format!("unknown flag --{name} for '{}'\n\n{}", cmd.name,
                                self.command_usage(cmd))
                    })?;
                if flag.is_switch {
                    if inline_val.is_some() {
                        return Err(format!("switch --{name} takes no value"));
                    }
                    args.switches.insert(name.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("flag --{name} needs a value"))?
                        }
                    };
                    let slot = args.values.entry(name.to_string()).or_default();
                    if flag.repeated {
                        // defaults never apply to repeated flags
                        slot.push(val);
                    } else {
                        *slot = vec![val];
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(Invocation { command: cmd.name, args })
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [flags]\n\nCOMMANDS:\n",
                            self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str(&format!("\nRun '{} <command> --help' for flags.", self.bin));
        s
    }

    fn command_usage(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nFLAGS:\n", self.bin, cmd.name, cmd.about);
        for f in &cmd.flags {
            let kind = if f.is_switch {
                String::new()
            } else if let Some(d) = f.default {
                format!(" <value> (default: {d})")
            } else if f.repeated {
                " <value> (repeatable)".to_string()
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "fedlite",
            about: "test",
            commands: vec![Command {
                name: "train",
                about: "train a model",
                flags: vec![
                    Flag::opt("rounds", "100", "number of rounds"),
                    Flag::req("task", "task name"),
                    Flag::switch("verbose", "chatty"),
                    Flag::multi("sweep", "values to sweep"),
                    Flag::opt("p", "0", "a probability"),
                ],
            }],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let inv = cli().parse(&sv(&["train", "--task", "femnist", "--verbose"])).unwrap();
        assert_eq!(inv.command, "train");
        assert_eq!(inv.args.usize("rounds").unwrap(), 100);
        assert_eq!(inv.args.str("task").unwrap(), "femnist");
        assert!(inv.args.has("verbose"));
        assert!(!inv.args.has("other"));
    }

    #[test]
    fn equals_syntax_and_override() {
        let inv = cli().parse(&sv(&["train", "--task=x", "--rounds=7"])).unwrap();
        assert_eq!(inv.args.usize("rounds").unwrap(), 7);
        assert_eq!(inv.args.str("task").unwrap(), "x");
    }

    #[test]
    fn repeated_flags_accumulate() {
        let inv = cli()
            .parse(&sv(&["train", "--task", "t", "--sweep", "1", "--sweep", "2"]))
            .unwrap();
        assert_eq!(inv.args.get_all("sweep"), &["1".to_string(), "2".to_string()]);
    }

    #[test]
    fn prob_flag_validates_range() {
        let inv = cli().parse(&sv(&["train", "--p", "0.3"])).unwrap();
        assert!((inv.args.prob("p").unwrap() - 0.3).abs() < 1e-12);
        let inv = cli().parse(&sv(&["train", "--p", "1.5"])).unwrap();
        assert!(inv.args.prob("p").is_err());
        let inv = cli().parse(&sv(&["train", "--p", "-0.1"])).unwrap();
        assert!(inv.args.prob("p").is_err());
        // boundary values are probabilities too
        let inv = cli().parse(&sv(&["train", "--p", "1"])).unwrap();
        assert_eq!(inv.args.prob("p").unwrap(), 1.0);
    }

    #[test]
    fn missing_required_flag_errors_at_access() {
        let inv = cli().parse(&sv(&["train"])).unwrap();
        assert!(inv.args.str("task").is_err());
    }

    #[test]
    fn unknown_command_and_flag() {
        assert!(cli().parse(&sv(&["nope"])).is_err());
        assert!(cli().parse(&sv(&["train", "--bogus", "1"])).is_err());
    }

    #[test]
    fn help_is_err_with_usage() {
        let msg = cli().parse(&sv(&["--help"])).unwrap_err();
        assert!(msg.contains("COMMANDS"));
        let msg = cli().parse(&sv(&["train", "--help"])).unwrap_err();
        assert!(msg.contains("--rounds"));
    }
}
