//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::case`]:
//! warmup, N timed iterations, mean/min/max/p50 reporting, and CSV + JSON
//! persistence under `results/bench/` so §Perf before/after numbers are
//! reproducible files, not terminal scrollback. [`Bench::finish_to`]
//! additionally writes a repo-root trajectory file (`BENCH_<suite>.json`)
//! that CI regenerates and diffs across PRs.
//!
//! # JSON schema (`fedlite-bench-v1`)
//!
//! ```json
//! {
//!   "schema": "fedlite-bench-v1",
//!   "suite": "quantizer",
//!   "rows": [
//!     {"case": "quantize q=288 R=1 L=32 iters=8", "iters": 5,
//!      "ns_per_iter": 1234567.0, "mean_s": 1.234567e-3,
//!      "p50_s": 1.2e-3, "min_s": 1.1e-3, "max_s": 1.4e-3,
//!      "mb_per_s": 598.2}
//!   ]
//! }
//! ```
//!
//! `ns_per_iter` is the mean over timed iterations; `mb_per_s` is 0 when
//! the case declared no per-iteration work amount.

use std::time::Instant;

use crate::util::json::{Object, Value};
use crate::util::logging::CsvWriter;

/// One benchmark suite (one `cargo bench` target).
pub struct Bench {
    name: String,
    rows: Vec<(String, Stats, f64)>,
}

/// Timing statistics over iterations, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
}

/// Resolve an iteration-count knob against the `FEDLITE_BENCH_REPS` env
/// var (CI runs the suites with reduced reps; 0/garbage means "default").
pub fn reps_or(default: usize) -> usize {
    std::env::var("FEDLITE_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Whether `FEDLITE_BENCH_SMALL` asks for the reduced problem shape.
pub fn small_shape() -> bool {
    std::env::var("FEDLITE_BENCH_SMALL").map(|v| v == "1").unwrap_or(false)
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("== bench: {name} ==");
        Bench { name: name.to_string(), rows: Vec::new() }
    }

    /// Time `f` for `iters` iterations after `warmup` runs. `work` is an
    /// optional per-iteration work amount (bytes, elements) used to derive
    /// a throughput column.
    pub fn case<F: FnMut()>(&mut self, label: &str, warmup: usize, iters: usize, work: f64, mut f: F) -> Stats {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            iters,
            mean: samples.iter().sum::<f64>() / iters as f64,
            min: samples[0],
            max: samples[iters - 1],
            p50: samples[iters / 2],
        };
        let thr = if work > 0.0 { work / stats.mean } else { 0.0 };
        println!(
            "{label:<44} mean={:>9} p50={:>9} min={:>9} {}",
            fmt_s(stats.mean),
            fmt_s(stats.p50),
            fmt_s(stats.min),
            if work > 0.0 { format!("thr={:.1} MB/s", thr / 1e6) } else { String::new() }
        );
        self.rows.push((label.to_string(), stats, thr));
        stats
    }

    /// Machine-readable view of the suite (schema `fedlite-bench-v1`).
    pub fn to_json(&self) -> Value {
        let mut root = Object::new();
        root.insert("schema", Value::Str("fedlite-bench-v1".into()));
        root.insert("suite", Value::Str(self.name.clone()));
        let rows = self
            .rows
            .iter()
            .map(|(label, s, thr)| {
                let mut row = Object::new();
                row.insert("case", Value::Str(label.clone()));
                row.insert("iters", Value::from_usize(s.iters));
                row.insert("ns_per_iter", Value::Num(s.mean * 1e9));
                row.insert("mean_s", Value::Num(s.mean));
                row.insert("p50_s", Value::Num(s.p50));
                row.insert("min_s", Value::Num(s.min));
                row.insert("max_s", Value::Num(s.max));
                row.insert("mb_per_s", Value::Num(thr / 1e6));
                Value::Obj(row)
            })
            .collect();
        root.insert("rows", Value::Arr(rows));
        Value::Obj(root)
    }

    /// Write the suite's CSV + JSON under `results/bench/<name>.{csv,json}`.
    pub fn finish(self) {
        self.finish_to(None);
    }

    /// [`Bench::finish`] plus a repo-root perf-trajectory copy of the JSON
    /// (e.g. `BENCH_quantizer.json`) that CI regenerates and diffs. The
    /// trajectory file is **merged**, not replaced: the committed seeds
    /// carry contract keys (`expected_cases`, `provenance`) that a
    /// refresh run must preserve — only `schema`/`suite`/`rows` are
    /// overwritten.
    pub fn finish_to(self, trajectory: Option<&str>) {
        let json = self.to_json();
        let json_path = format!("results/bench/{}.json", self.name);
        if std::fs::create_dir_all("results/bench").is_ok()
            && std::fs::write(&json_path, json.to_string_pretty()).is_ok()
        {
            println!("(wrote {json_path})");
        }
        if let Some(path) = trajectory {
            // resolve relative trajectory paths against the workspace root
            // (this package lives in rust/), not the cwd: cargo bench runs
            // harness binaries from the package root
            let p = if std::path::Path::new(path).is_absolute() {
                std::path::PathBuf::from(path)
            } else {
                std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/..")).join(path)
            };
            let merged = merge_trajectory(&p, &json);
            if std::fs::write(&p, merged.to_string_pretty()).is_ok() {
                println!("(wrote {})", p.display());
            }
        }
        let path = format!("results/bench/{}.csv", self.name);
        if let Ok(mut csv) = CsvWriter::create(
            &path,
            &["case", "iters", "mean_s", "p50_s", "min_s", "max_s", "throughput_mb_s"],
        ) {
            for (label, s, thr) in &self.rows {
                let _ = csv.row(&[
                    label.clone(),
                    s.iters.to_string(),
                    format!("{:.6e}", s.mean),
                    format!("{:.6e}", s.p50),
                    format!("{:.6e}", s.min),
                    format!("{:.6e}", s.max),
                    format!("{:.2}", thr / 1e6),
                ]);
            }
            let _ = csv.flush();
            println!("(wrote {path})");
        }
    }
}

/// Merge a fresh suite JSON into the trajectory file at `path`: keys the
/// fresh run produces (`schema`, `suite`, `rows`) replace the old values;
/// every other key of the existing file — the seeds' `expected_cases`
/// coverage contract and `provenance` note — is preserved. A missing or
/// unparseable file degrades to the fresh JSON alone.
fn merge_trajectory(path: &std::path::Path, fresh: &Value) -> Value {
    let existing = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| crate::util::json::parse(&text).ok());
    match (existing, fresh) {
        (Some(Value::Obj(mut old)), Value::Obj(new)) => {
            for (k, v) in new.iter() {
                old.insert(k.clone(), v.clone());
            }
            Value::Obj(old)
        }
        _ => fresh.clone(),
    }
}

fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let mut b = Bench::new("self-test");
        let s = b.case("noop", 1, 10, 0.0, || { std::hint::black_box(1 + 1); });
        assert!(s.min <= s.p50 && s.p50 <= s.max);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn merge_trajectory_preserves_contract_keys() {
        // a refresh must keep the seed's expected_cases/provenance while
        // replacing schema/suite/rows
        let dir = std::env::temp_dir().join("fedlite-bench-merge-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_t.json");
        std::fs::write(
            &path,
            r#"{"schema": "fedlite-bench-v1", "suite": "t", "provenance": "seed",
                "rows": [], "expected_cases": ["a", "b"]}"#,
        )
        .unwrap();
        let mut b = Bench::new("t");
        b.case("a", 0, 2, 0.0, || {
            std::hint::black_box(1 + 1);
        });
        let merged = merge_trajectory(&path, &b.to_json());
        assert_eq!(merged.get("provenance").as_str(), Some("seed"));
        assert_eq!(merged.get("expected_cases").as_arr().unwrap().len(), 2);
        assert_eq!(merged.get("rows").as_arr().unwrap().len(), 1);
        assert_eq!(merged.get("suite").as_str(), Some("t"));
        // missing file degrades to the fresh JSON alone
        let fresh = merge_trajectory(&dir.join("nope.json"), &b.to_json());
        assert!(fresh.get("provenance").as_str().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_schema_well_formed() {
        let mut b = Bench::new("json-test");
        b.case("a", 0, 3, 8.0, || { std::hint::black_box(2 * 2); });
        b.case("b", 0, 3, 0.0, || { std::hint::black_box(3 * 3); });
        let v = b.to_json();
        assert_eq!(v.get("schema").as_str(), Some("fedlite-bench-v1"));
        assert_eq!(v.get("suite").as_str(), Some("json-test"));
        let rows = v.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("case").as_str(), Some("a"));
        assert_eq!(rows[0].get("iters").as_usize(), Some(3));
        assert!(rows[0].get("ns_per_iter").as_f64().unwrap() > 0.0);
        assert!(rows[0].get("mb_per_s").as_f64().unwrap() >= 0.0);
        assert_eq!(rows[1].get("mb_per_s").as_f64(), Some(0.0));
        // round-trips through the in-house parser
        let back = crate::util::json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(back, v);
    }
}
