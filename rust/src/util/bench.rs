//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`]:
//! warmup, N timed iterations, mean/min/max/p50 reporting, and CSV
//! persistence under `results/bench/` so §Perf before/after numbers are
//! reproducible files, not terminal scrollback.

use std::time::Instant;

use crate::util::logging::CsvWriter;

/// One benchmark suite (one `cargo bench` target).
pub struct Bench {
    name: String,
    rows: Vec<(String, Stats, f64)>,
}

/// Timing statistics over iterations, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("== bench: {name} ==");
        Bench { name: name.to_string(), rows: Vec::new() }
    }

    /// Time `f` for `iters` iterations after `warmup` runs. `work` is an
    /// optional per-iteration work amount (bytes, elements) used to derive
    /// a throughput column.
    pub fn case<F: FnMut()>(&mut self, label: &str, warmup: usize, iters: usize, work: f64, mut f: F) -> Stats {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            iters,
            mean: samples.iter().sum::<f64>() / iters as f64,
            min: samples[0],
            max: samples[iters - 1],
            p50: samples[iters / 2],
        };
        let thr = if work > 0.0 { work / stats.mean } else { 0.0 };
        println!(
            "{label:<44} mean={:>9} p50={:>9} min={:>9} {}",
            fmt_s(stats.mean),
            fmt_s(stats.p50),
            fmt_s(stats.min),
            if work > 0.0 { format!("thr={:.1} MB/s", thr / 1e6) } else { String::new() }
        );
        self.rows.push((label.to_string(), stats, thr));
        stats
    }

    /// Write the suite's CSV under `results/bench/<name>.csv`.
    pub fn finish(self) {
        let path = format!("results/bench/{}.csv", self.name);
        if let Ok(mut csv) = CsvWriter::create(
            &path,
            &["case", "iters", "mean_s", "p50_s", "min_s", "max_s", "throughput_mb_s"],
        ) {
            for (label, s, thr) in &self.rows {
                let _ = csv.row(&[
                    label.clone(),
                    s.iters.to_string(),
                    format!("{:.6e}", s.mean),
                    format!("{:.6e}", s.p50),
                    format!("{:.6e}", s.min),
                    format!("{:.6e}", s.max),
                    format!("{:.2}", thr / 1e6),
                ]);
            }
            let _ = csv.flush();
            println!("(wrote {path})");
        }
    }
}

fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let mut b = Bench::new("self-test");
        let s = b.case("noop", 1, 10, 0.0, || { std::hint::black_box(1 + 1); });
        assert!(s.min <= s.p50 && s.p50 <= s.max);
        assert!(s.mean > 0.0);
    }
}
