//! Logging + structured run outputs (CSV / JSONL) without external crates.
//!
//! `init(level)` installs a stderr logger for the `log` facade; `CsvWriter`
//! and `JsonlWriter` persist experiment series under `results/` so every
//! figure can be regenerated from a file on disk.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

use crate::util::json::Value;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the global logger. Level names: error/warn/info/debug/trace.
pub fn init(level: &str) {
    let filter = match level {
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { start: Instant::now() }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(filter);
    }
}

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
    pub path: PathBuf,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(&path)?);
        let escaped: Vec<String> = header.iter().map(|h| escape_cell(h)).collect();
        writeln!(w, "{}", escaped.join(","))?;
        Ok(CsvWriter { w, cols: header.len(), path })
    }

    /// Write one row; panics if the column count differs from the header.
    pub fn row(&mut self, values: &[String]) -> anyhow::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row width mismatch");
        let escaped: Vec<String> = values.iter().map(|v| escape_cell(v)).collect();
        writeln!(self.w, "{}", escaped.join(","))?;
        Ok(())
    }

    /// Convenience: format mixed numeric row.
    pub fn row_f(&mut self, values: &[f64]) -> anyhow::Result<()> {
        self.row(&values.iter().map(|v| trim_float(*v)).collect::<Vec<_>>())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

fn escape_cell(v: &str) -> String {
    if v.contains(',') || v.contains('"') || v.contains('\n') {
        format!("\"{}\"", v.replace('"', "\"\""))
    } else {
        v.to_string()
    }
}

/// Compact float formatting for CSV cells.
pub fn trim_float(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        let s = format!("{v:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

/// Line-per-record JSON writer (run logs, checkpoint indexes).
pub struct JsonlWriter {
    w: BufWriter<File>,
    pub path: PathBuf,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(JsonlWriter { w: BufWriter::new(File::create(&path)?), path })
    }

    pub fn record(&mut self, v: &Value) -> anyhow::Result<()> {
        writeln!(self.w, "{v}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "fedlite-log-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmpdir().join("t.csv");
        let mut w = CsvWriter::create(&p, &["a", "b,comma"]).unwrap();
        w.row(&["1".into(), "x,y".into()]).unwrap();
        w.row_f(&[0.5, 3.0]).unwrap();
        w.flush().unwrap();
        let text = fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a,\"b,comma\"");
        assert_eq!(lines[1], "1,\"x,y\"");
        assert_eq!(lines[2], "0.5,3");
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn csv_width_checked() {
        let p = tmpdir().join("w.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }

    #[test]
    fn jsonl_records() {
        let p = tmpdir().join("t.jsonl");
        let mut w = JsonlWriter::create(&p).unwrap();
        w.record(&json::parse(r#"{"round":1,"loss":2.5}"#).unwrap()).unwrap();
        w.record(&json::parse(r#"{"round":2,"loss":2.25}"#).unwrap()).unwrap();
        w.flush().unwrap();
        let text = fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        let v = json::parse(text.lines().nth(1).unwrap()).unwrap();
        assert_eq!(v.get("loss").as_f64(), Some(2.25));
    }

    #[test]
    fn trim_float_formats() {
        assert_eq!(trim_float(3.0), "3");
        assert_eq!(trim_float(0.25), "0.25");
        assert_eq!(trim_float(1.0 / 3.0), "0.333333");
    }
}
