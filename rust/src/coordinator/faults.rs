//! Deterministic fault injection for the round engine.
//!
//! FedLite targets resource-constrained edge clients, where mid-round
//! failure is the *expected* condition, not the exception. This layer
//! turns the happy-path reproduction into a failure-scenario simulator:
//!
//! * **Mid-round dropout** (`drop_prob`): a sampled client vanishes after
//!   `client_fwd` (before uploading activations), after its
//!   quantize-upload, or right before the client-grad upload. Bytes the
//!   client sent before failing stay on the meters; its gradients never
//!   reach the aggregate.
//! * **Stragglers** (`straggler_frac` + `round_deadline`): a straggling
//!   client draws a simulated compute delay. With a deadline configured,
//!   clients whose delay exceeds it are *evicted*: every protocol message
//!   still crosses the (metered) wire — the work arrives — but too late,
//!   and the coordinator discards the contribution.
//! * **Partial cohorts** (`min_survivors`): when fewer clients survive
//!   than the floor, the round aborts and resamples (a fresh attempt with
//!   fresh fault schedules) without advancing the optimizer; see
//!   [`crate::coordinator::engine::RoundDriver::resample`].
//!
//! * **Byzantine clients** (`byzantine_frac` + `byzantine_kind`): the
//!   draws above model *honest* failures; this models dishonest ones. A
//!   flagged client mounts the configured [`ByzantineKind`] attack —
//!   scaled/sign-flipped gradients, label-flip poisoning, corrupt
//!   codeword streams, replayed (stale, zero-delta) uploads — applied
//!   inside the trainers' `client_step`, so socket replica workers
//!   misbehave identically to in-process threads (the plan rides
//!   `StepAssign`). Defenses live server-side: codeword validation
//!   (rejects become [`DropPhase::RejectedCodeword`] drops), `--clip-norm`
//!   update clipping, and trimmed/median aggregation
//!   ([`crate::coordinator::aggregator::UpdateAggregator`]).
//!
//! Every draw comes from an [`Rng`] stream forked from a pure
//! `(round, attempt, client)` key — never wall-clock, never thread
//! identity — so fault schedules are bit-identical at any `--workers`
//! count, and a clean config (`drop_prob = straggler_frac = 0`) draws
//! nothing at all and reproduces historical logs exactly. Byzantine
//! draws come from their *own* fork key ([`byzantine_key`]), so
//! `--byzantine-frac 0` perturbs no existing stream and reproduces
//! today's bits.
//!
//! FedAvg note: FedAvg has no activation upload, so its only mid-round
//! failure surface is "died before the delta upload"; the split-specific
//! drop phases collapse to [`DropPhase::BeforeGradUpload`] there.

use crate::config::{ByzantineKind, RunConfig};
use crate::data::Array;
use crate::util::rng::Rng;

/// Where in the round a client stopped participating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropPhase {
    /// Vanished after `client_fwd`, before uploading activations.
    AfterFwd,
    /// Vanished after the (quantize-)upload reached the server.
    AfterUpload,
    /// Vanished before uploading client-side gradients.
    BeforeGradUpload,
    /// Evicted: finished, but past the round deadline (straggler).
    Deadline,
    /// Rejected: the upload's packed codeword stream failed validation
    /// against the PQ geometry (wrong length or out-of-range codes). The
    /// bytes crossed the (metered) wire; the contribution is discarded.
    RejectedCodeword,
    /// Reaped: the socket member serving this slot failed mid-round
    /// (malformed frame, `StepError`, dead connection). Coordinator-side
    /// only — never planned, never crosses the wire in a worker's reply.
    PeerFailure,
}

impl DropPhase {
    pub fn name(&self) -> &'static str {
        match self {
            DropPhase::AfterFwd => "after_fwd",
            DropPhase::AfterUpload => "after_upload",
            DropPhase::BeforeGradUpload => "before_grad_upload",
            DropPhase::Deadline => "deadline",
            DropPhase::RejectedCodeword => "rejected_codeword",
            DropPhase::PeerFailure => "peer_failure",
        }
    }
}

/// One client's failure schedule for one `(round, attempt)`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Mid-round dropout point (never [`DropPhase::Deadline`] here).
    pub drop_at: Option<DropPhase>,
    /// Simulated straggler compute delay in seconds (0 for punctual
    /// clients). Feeds the round's simulated wall-clock estimate.
    pub delay_seconds: f64,
    /// Straggler past the deadline: runs to completion (all bytes
    /// metered) but the contribution is discarded. Mutually exclusive
    /// with `drop_at` — a client that died mid-round never reaches the
    /// deadline.
    pub evicted: bool,
    /// The attack this client mounts, if flagged byzantine. Orthogonal
    /// to the honest-failure draws above: a byzantine client can also
    /// drop or straggle.
    pub byz: Option<ByzantineKind>,
}

impl FaultPlan {
    /// The phase this client's contribution was lost at, if any.
    pub fn dropped(&self) -> Option<DropPhase> {
        if self.evicted {
            Some(DropPhase::Deadline)
        } else {
            self.drop_at
        }
    }
}

/// Stragglers with no deadline configured still draw a delay (it shows up
/// in the simulated round time) from `[0, this)` seconds.
const DEFAULT_DELAY_CAP: f64 = 10.0;

/// Scale factor a [`ByzantineKind::GradScale`] client multiplies its
/// uploaded update by (gradient-boosting attack).
pub const GRAD_SCALE: f32 = 10.0;

/// Byzantine client-model settings (who attacks, and how).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ByzantineConfig {
    /// Per-client, per-round probability of acting byzantine.
    pub frac: f64,
    /// The attack flagged clients mount.
    pub kind: ByzantineKind,
}

impl Default for ByzantineConfig {
    fn default() -> Self {
        ByzantineConfig { frac: 0.0, kind: ByzantineKind::SignFlip }
    }
}

impl ByzantineConfig {
    /// Whether any byzantine draw happens at all. When false,
    /// [`FaultConfig::plan`] skips the byzantine fork entirely, so
    /// `--byzantine-frac 0` reproduces historical logs bit-for-bit.
    pub fn enabled(&self) -> bool {
        self.frac > 0.0
    }
}

/// Round-level fault injection settings (see module docs for semantics).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultConfig {
    /// Per-client, per-round probability of mid-round dropout.
    pub drop_prob: f64,
    /// Per-client, per-round probability of straggling.
    pub straggler_frac: f64,
    /// Simulated per-round deadline in seconds; 0 disables eviction.
    pub round_deadline: f64,
    /// Abort + resample when fewer clients survive; 0 disables.
    pub min_survivors: usize,
    /// Dishonest-client model (drawn from its own fork key).
    pub byzantine: ByzantineConfig,
}

impl FaultConfig {
    pub fn from_run(cfg: &RunConfig) -> FaultConfig {
        FaultConfig {
            drop_prob: cfg.drop_prob,
            straggler_frac: cfg.straggler_frac,
            round_deadline: cfg.round_deadline,
            min_survivors: cfg.min_survivors,
            byzantine: ByzantineConfig { frac: cfg.byzantine_frac, kind: cfg.byzantine_kind },
        }
    }

    /// Whether any per-client honest-fault draw happens at all. When
    /// false, [`FaultConfig::plan`] skips the fault fork without touching
    /// any RNG, so clean runs stay bit-identical to historical logs.
    /// (Byzantine draws are gated separately by
    /// [`ByzantineConfig::enabled`].)
    pub fn enabled(&self) -> bool {
        self.drop_prob > 0.0 || self.straggler_frac > 0.0
    }

    /// Deterministic failure schedule for one client in one
    /// `(round, attempt)`. Draws from a stream forked off `root` — `fork`
    /// never advances the parent, so planning perturbs nothing else.
    pub fn plan(&self, root: &Rng, round: u64, attempt: u32, client: usize) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if self.enabled() {
            let mut rng = root.fork(fault_key(round, attempt, client));
            if self.drop_prob > 0.0 && rng.bernoulli(self.drop_prob) {
                plan.drop_at = Some(match rng.below(3) {
                    0 => DropPhase::AfterFwd,
                    1 => DropPhase::AfterUpload,
                    _ => DropPhase::BeforeGradUpload,
                });
            }
            if self.straggler_frac > 0.0 && rng.bernoulli(self.straggler_frac) {
                // with a deadline, expected half of stragglers land past it
                let cap = if self.round_deadline > 0.0 {
                    2.0 * self.round_deadline
                } else {
                    DEFAULT_DELAY_CAP
                };
                plan.delay_seconds = rng.uniform_in(0.0, cap);
                plan.evicted = plan.drop_at.is_none()
                    && self.round_deadline > 0.0
                    && plan.delay_seconds > self.round_deadline;
            }
        }
        // the byzantine draw uses its own fork so adding (or zeroing) it
        // perturbs no honest-fault stream
        if self.byzantine.enabled() {
            let mut rng = root.fork(byzantine_key(round, attempt, client));
            if rng.bernoulli(self.byzantine.frac) {
                plan.byz = Some(self.byzantine.kind);
            }
        }
        plan
    }

    /// Deterministic failure schedules for a whole cohort in one
    /// `(round, attempt)`, drawn in cohort-slot order. This is the round
    /// engine's Sampling-phase entry point; per-client draws stay pure
    /// functions of `(round, attempt, client)`, so the slot order here is
    /// bookkeeping only.
    pub fn plans(
        &self,
        root: &Rng,
        round: u64,
        attempt: u32,
        cohort: &[usize],
    ) -> Vec<FaultPlan> {
        cohort
            .iter()
            .map(|&ci| self.plan(root, round, attempt, ci))
            .collect()
    }
}

/// Fork key for a client's fault schedule. Distinct tag from the client
/// work streams (`0xC11E`/`0xFEDA`) so fault draws and batch draws are
/// independent; includes the attempt so a resampled round gets fresh
/// schedules.
pub fn fault_key(round: u64, attempt: u32, client: usize) -> u64 {
    (round << 20) ^ ((attempt as u64) << 44) ^ (client as u64) ^ 0xFA17
}

/// Transport chaos settings for the socket deployment mode (see
/// `coordinator::backend`). The faults above perturb *computation*
/// (which clients fail, straggle, or attack — all of it changes the
/// round records); chaos perturbs only the *transport* between the
/// coordinator and its members. Lost assignments are reassigned,
/// truncated replies get their member reaped and the slot re-executed
/// elsewhere, and delays just slow delivery — every `StepResult` is a
/// pure function of `(round, attempt, client)` + plan, so round records
/// stay byte-identical to a chaos-free run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChaosConfig {
    /// Per-frame probability a coordinator→member `StepAssign` is lost.
    pub drop: f64,
    /// Upper bound (ms) on the uniform delay a member sleeps before
    /// sending each `StepResult`.
    pub delay_ms: f64,
    /// Per-reply probability a member truncates its `StepResult`
    /// mid-frame and drops the connection.
    pub truncate: f64,
}

/// One frame's chaos decision, drawn from the [`chaos_key`] fork.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChaosFrame {
    pub drop: bool,
    /// Artificial delay in milliseconds (0 when `delay_ms` is off).
    pub delay_ms: f64,
    pub truncate: bool,
}

impl ChaosConfig {
    pub fn from_run(cfg: &RunConfig) -> ChaosConfig {
        ChaosConfig {
            drop: cfg.chaos_drop,
            delay_ms: cfg.chaos_delay_ms,
            truncate: cfg.chaos_truncate,
        }
    }

    /// Whether any chaos draw happens at all. When false, [`Self::frame`]
    /// forks nothing, so `--chaos-* 0` is a provable no-op: no RNG
    /// stream is touched and the transport behaves exactly as before.
    pub fn enabled(&self) -> bool {
        self.drop > 0.0 || self.delay_ms > 0.0 || self.truncate > 0.0
    }

    /// Deterministic chaos decision for one frame. `entity` is the
    /// coordinator's member index on the send side and the slot's client
    /// id on the member side; `frame` is the sender's per-entity frame
    /// counter. Each knob gates its own draw, so enabling one never
    /// shifts another's stream.
    pub fn frame(&self, root: &Rng, round: u64, entity: u64, frame: u64) -> ChaosFrame {
        let mut out = ChaosFrame::default();
        if !self.enabled() {
            return out;
        }
        let mut rng = root.fork(chaos_key(round, entity, frame));
        if self.drop > 0.0 {
            out.drop = rng.bernoulli(self.drop);
        }
        if self.delay_ms > 0.0 {
            out.delay_ms = rng.uniform_in(0.0, self.delay_ms);
        }
        if self.truncate > 0.0 {
            out.truncate = rng.bernoulli(self.truncate);
        }
        out
    }
}

/// Fork key for one transport frame's chaos decision. Same shape as
/// [`fault_key`]/[`byzantine_key`] with the frame counter in the
/// attempt's position and its own `0xCA05` tag, so chaos is an
/// independent RNG dimension: enabling it perturbs no fault, byzantine,
/// or client work stream.
pub fn chaos_key(round: u64, entity: u64, frame: u64) -> u64 {
    (round << 20) ^ (frame << 44) ^ entity ^ 0xCA05
}

/// Fork key for a client's byzantine draw. Distinct tag from
/// [`fault_key`] and every client work stream, so the byzantine layer is
/// an independent RNG dimension: enabling it leaves honest-fault and
/// batch streams untouched.
pub fn byzantine_key(round: u64, attempt: u32, client: usize) -> u64 {
    (round << 20) ^ ((attempt as u64) << 44) ^ (client as u64) ^ 0xB12A
}

/// Fork tag for attacker-chosen payload bytes (the corrupt-codeword
/// stream), forked off the client's *work* stream inside `client_step`.
/// Forking never advances the parent, so the honest batch draws of other
/// clients — and of this client in non-byzantine runs — are untouched.
pub const BYZ_PAYLOAD_TAG: u64 = 0xB12A_C0DE;

/// The label-flip poisoning attack: rotate each example's label to its
/// neighbor (`y_i ← y_{i+1}`, wrapping). A pure permutation stays inside
/// the task's valid label space for every representation — class ids,
/// multi-hot rows, token-id rows — because whole per-example label rows
/// (`numel / batch` values) move together. Deterministic, draws no RNG.
pub fn poison_labels(y: &mut Array, batch: usize) {
    let n = y.numel();
    if batch <= 1 || n == 0 || n % batch != 0 {
        return;
    }
    let row = n / batch;
    match y {
        Array::F32 { data, .. } => data.rotate_left(row),
        Array::I32 { data, .. } => data.rotate_left(row),
    }
}

/// The corrupt-codeword attack: replace a packed codeword stream with
/// attacker-chosen bytes and append one extra byte. The extra byte makes
/// the exact-length defense check reject deterministically even for
/// presets where every bit pattern decodes to a valid code (e.g. L = 4,
/// where 2-bit codes fill the byte exactly).
pub fn corrupt_codewords(packed: &mut Vec<u8>, rng: &mut Rng) {
    for b in packed.iter_mut() {
        *b = rng.below(256) as u8;
    }
    packed.push(rng.below(256) as u8);
}

/// Per-phase drop tally for one committed round (the `dropped_at_phase`
/// column of the round logs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DropCounts {
    pub after_fwd: usize,
    pub after_upload: usize,
    pub before_grad_upload: usize,
    pub deadline: usize,
    pub rejected_codeword: usize,
    pub peer_failure: usize,
}

impl DropCounts {
    pub fn add(&mut self, phase: DropPhase) {
        match phase {
            DropPhase::AfterFwd => self.after_fwd += 1,
            DropPhase::AfterUpload => self.after_upload += 1,
            DropPhase::BeforeGradUpload => self.before_grad_upload += 1,
            DropPhase::Deadline => self.deadline += 1,
            DropPhase::RejectedCodeword => self.rejected_codeword += 1,
            DropPhase::PeerFailure => self.peer_failure += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.after_fwd
            + self.after_upload
            + self.before_grad_upload
            + self.deadline
            + self.rejected_codeword
            + self.peer_failure
    }

    /// Fold another tally into this one (integer sums — exact in any
    /// order). Combinator for merging per-shard round partials.
    pub fn merge(&mut self, other: &DropCounts) {
        self.after_fwd += other.after_fwd;
        self.after_upload += other.after_upload;
        self.before_grad_upload += other.before_grad_upload;
        self.deadline += other.deadline;
        self.rejected_codeword += other.rejected_codeword;
        self.peer_failure += other.peer_failure;
    }

    /// Compact log form: `"after_fwd:1;deadline:2"`; empty when nothing
    /// dropped. Uses `;` so the value stays a single CSV cell.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for (n, name) in [
            (self.after_fwd, "after_fwd"),
            (self.after_upload, "after_upload"),
            (self.before_grad_upload, "before_grad_upload"),
            (self.deadline, "deadline"),
            (self.rejected_codeword, "rejected_codeword"),
            (self.peer_failure, "peer_failure"),
        ] {
            if n > 0 {
                parts.push(format!("{name}:{n}"));
            }
        }
        parts.join(";")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn faulty() -> FaultConfig {
        FaultConfig {
            drop_prob: 0.4,
            straggler_frac: 0.5,
            round_deadline: 2.0,
            min_survivors: 1,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn disabled_config_draws_nothing() {
        let fc = FaultConfig {
            round_deadline: 5.0,
            min_survivors: 3,
            ..FaultConfig::default()
        };
        assert!(!fc.enabled());
        assert!(!fc.byzantine.enabled());
        let root = Rng::new(1);
        for c in 0..50 {
            assert_eq!(fc.plan(&root, 0, 1, c), FaultPlan::default());
        }
    }

    #[test]
    fn byzantine_draws_are_independent_of_fault_draws() {
        // honest-fault plans must be byte-identical with and without the
        // byzantine layer enabled (separate fork keys)
        let honest = faulty();
        let byz = FaultConfig {
            byzantine: ByzantineConfig { frac: 0.5, kind: ByzantineKind::GradScale },
            ..honest
        };
        let root = Rng::new(7);
        let (mut flagged, n) = (0, 2000);
        for c in 0..n {
            let a = honest.plan(&root, 2, 1, c);
            let b = byz.plan(&root, 2, 1, c);
            assert_eq!((a.drop_at, a.delay_seconds, a.evicted), (b.drop_at, b.delay_seconds, b.evicted));
            assert_eq!(a.byz, None);
            if let Some(k) = b.byz {
                assert_eq!(k, ByzantineKind::GradScale);
                flagged += 1;
            }
        }
        let frac = flagged as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "byzantine rate {frac}");
        // deterministic per key, fresh per attempt
        assert_eq!(byz.plan(&root, 2, 1, 3), byz.plan(&root, 2, 1, 3));
        assert_ne!(byzantine_key(2, 1, 3), byzantine_key(2, 2, 3));
        assert_ne!(byzantine_key(2, 1, 3), fault_key(2, 1, 3));
    }

    #[test]
    fn byzantine_only_config_draws_byzantine_only() {
        // a pure byzantine config (no honest-fault knobs) must flag
        // clients without ever drawing drop/straggler state
        let fc = FaultConfig {
            byzantine: ByzantineConfig { frac: 1.0, kind: ByzantineKind::Replay },
            ..FaultConfig::default()
        };
        assert!(!fc.enabled());
        assert!(fc.byzantine.enabled());
        let root = Rng::new(3);
        for c in 0..50 {
            let p = fc.plan(&root, 0, 1, c);
            assert_eq!(p.byz, Some(ByzantineKind::Replay));
            assert_eq!(p.drop_at, None);
            assert_eq!(p.delay_seconds, 0.0);
            assert!(!p.evicted);
        }
    }

    #[test]
    fn plans_are_deterministic_and_vary_by_key() {
        let fc = faulty();
        let root = Rng::new(9);
        let a = fc.plan(&root, 3, 1, 7);
        assert_eq!(a, fc.plan(&root, 3, 1, 7), "same key, same plan");
        // across clients/rounds/attempts the schedule must vary somewhere
        let mut distinct = false;
        for c in 0..20 {
            if fc.plan(&root, 3, 1, c) != a || fc.plan(&root, 4, 1, 7) != a {
                distinct = true;
            }
        }
        assert!(distinct);
        assert_ne!(
            fault_key(3, 1, 7),
            fault_key(3, 2, 7),
            "resampled attempts need fresh schedules"
        );
    }

    #[test]
    fn drop_and_eviction_rates_roughly_match() {
        let fc = faulty();
        let root = Rng::new(4);
        let (mut drops, mut evicted, mut delayed) = (0, 0, 0);
        let n = 4000;
        for c in 0..n {
            let p = fc.plan(&root, 0, 1, c);
            if p.drop_at.is_some() {
                drops += 1;
                assert!(!p.evicted, "drop and eviction are exclusive");
            }
            if p.evicted {
                evicted += 1;
                assert!(p.delay_seconds > fc.round_deadline);
            }
            if p.delay_seconds > 0.0 {
                delayed += 1;
                assert!(p.delay_seconds <= 2.0 * fc.round_deadline);
            }
        }
        let frac = |k: usize| k as f64 / n as f64;
        assert!((frac(drops) - 0.4).abs() < 0.05, "drop rate {}", frac(drops));
        assert!((frac(delayed) - 0.5).abs() < 0.05, "straggler rate {}", frac(delayed));
        // evicted ≈ straggler ∧ ¬dropped ∧ past-deadline ≈ 0.5*0.6*0.5
        assert!((frac(evicted) - 0.15).abs() < 0.05, "evict rate {}", frac(evicted));
    }

    #[test]
    fn all_drop_phases_reachable() {
        let fc = FaultConfig { drop_prob: 1.0, ..FaultConfig::default() };
        let root = Rng::new(2);
        let mut counts = DropCounts::default();
        for c in 0..300 {
            counts.add(fc.plan(&root, 1, 1, c).dropped().unwrap());
        }
        assert!(counts.after_fwd > 0);
        assert!(counts.after_upload > 0);
        assert!(counts.before_grad_upload > 0);
        assert_eq!(counts.deadline, 0);
        assert_eq!(counts.total(), 300);
    }

    #[test]
    fn poison_labels_rotates_whole_rows() {
        let mut y = Array::i32(&[4], vec![1, 2, 3, 4]);
        poison_labels(&mut y, 4);
        assert_eq!(y.as_i32().unwrap(), &[2, 3, 4, 1]);
        // multi-hot rows move as units
        let mut y = Array::f32(&[2, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
        poison_labels(&mut y, 2);
        assert_eq!(y.as_f32().unwrap(), &[0.0, 1.0, 1.0, 1.0, 0.0, 0.0]);
        // a batch of one has no neighbor to steal a label from
        let mut y = Array::i32(&[1], vec![9]);
        poison_labels(&mut y, 1);
        assert_eq!(y.as_i32().unwrap(), &[9]);
    }

    #[test]
    fn corrupt_codewords_is_deterministic_and_overlong() {
        let mut packed = vec![0u8; 8];
        corrupt_codewords(&mut packed, &mut Rng::new(5));
        assert_eq!(packed.len(), 9, "extra byte forces length rejection");
        let mut again = vec![0u8; 8];
        corrupt_codewords(&mut again, &mut Rng::new(5));
        assert_eq!(packed, again, "same stream, same corruption");
    }

    #[test]
    fn cohort_plans_match_per_client_draws() {
        let fc = faulty();
        let root = Rng::new(11);
        let cohort = [3usize, 9, 0, 7];
        let batch = fc.plans(&root, 2, 1, &cohort);
        assert_eq!(batch.len(), cohort.len());
        for (slot, &ci) in cohort.iter().enumerate() {
            assert_eq!(batch[slot], fc.plan(&root, 2, 1, ci), "slot {slot}");
        }
    }

    #[test]
    fn chaos_disabled_draws_nothing_and_keys_are_distinct() {
        let chaos = ChaosConfig::default();
        assert!(!chaos.enabled());
        let root = Rng::new(6);
        for f in 0..50 {
            assert_eq!(chaos.frame(&root, 1, 0, f), ChaosFrame::default());
        }
        // the chaos dimension never collides with fault/byzantine keys
        assert_ne!(chaos_key(2, 3, 1), fault_key(2, 1, 3));
        assert_ne!(chaos_key(2, 3, 1), byzantine_key(2, 1, 3));
        assert_ne!(chaos_key(2, 3, 1), chaos_key(2, 3, 2), "fresh per frame");
        assert_ne!(chaos_key(2, 3, 1), chaos_key(2, 4, 1), "fresh per entity");
    }

    #[test]
    fn chaos_rates_and_determinism() {
        let chaos = ChaosConfig { drop: 0.25, delay_ms: 40.0, truncate: 0.1 };
        assert!(chaos.enabled());
        let root = Rng::new(8);
        let (mut drops, mut truncs, n) = (0, 0, 4000);
        for f in 0..n {
            let c = chaos.frame(&root, 3, 1, f);
            assert_eq!(c, chaos.frame(&root, 3, 1, f), "same key, same chaos");
            assert!((0.0..40.0).contains(&c.delay_ms));
            drops += c.drop as usize;
            truncs += c.truncate as usize;
        }
        let frac = |k: usize| k as f64 / n as f64;
        assert!((frac(drops) - 0.25).abs() < 0.05, "drop rate {}", frac(drops));
        assert!((frac(truncs) - 0.1).abs() < 0.05, "truncate rate {}", frac(truncs));
        // enabling the delay knob must not shift the drop stream
        let drop_only = ChaosConfig { drop: 0.25, ..ChaosConfig::default() };
        for f in 0..200 {
            assert_eq!(
                drop_only.frame(&root, 3, 1, f).drop,
                chaos.frame(&root, 3, 1, f).drop
            );
        }
    }

    #[test]
    fn summary_format() {
        let mut c = DropCounts::default();
        assert_eq!(c.summary(), "");
        c.add(DropPhase::AfterFwd);
        c.add(DropPhase::Deadline);
        c.add(DropPhase::Deadline);
        assert_eq!(c.summary(), "after_fwd:1;deadline:2");
        assert_eq!(c.total(), 3);
    }
}
