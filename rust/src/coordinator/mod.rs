//! The Layer-3 coordinator: federated round state machines.
//!
//! Three algorithms share the substrate:
//!
//! * [`split::SplitTrainer`] — SplitFed (paper §3) and FedLite (paper §4):
//!   the four-step round (client forward → server update → client backward
//!   → client-side model sync), with FedLite inserting the PQ quantization
//!   layer ([`quantize`]) into step 1 and the gradient correction
//!   (eq. (5)) into step 3.
//! * [`fedavg::FedAvgTrainer`] — the whole-model baseline with H local
//!   steps.
//!
//! Both trainers implement [`engine::RoundAlgorithm`] and run every round
//! through the one generic [`engine::RoundEngine`] (Sampling → Broadcast →
//! ClientCompute → Aggregate → Commit) with deterministic fault injection
//! from [`faults`] — client dropout, stragglers, deadline eviction, and
//! partial-cohort resampling. The engine owns the round protocol end to
//! end (sampling, fan-out, reduction order, byte/time accounting,
//! degraded commits, record assembly); an algorithm only supplies its
//! broadcast, per-client step, survivor accumulation, and optimizer
//! commit — so the cross-algorithm communication comparison stays
//! apples-to-apples by construction.
//!
//! All model math executes through PJRT artifacts; all transfers go
//! through the metered [`crate::comm::StarNetwork`].
//!
//! *Where* client steps execute is a separate axis: the engine hands each
//! shard to a [`backend::ClientBackend`] — in-process worker threads by
//! default, or TCP loopback members ([`backend::SocketBackend`] driving
//! [`worker`] processes) with identical bits.

pub mod aggregator;
pub mod backend;
pub mod checkpoint;
pub mod client;
pub mod correction;
pub mod engine;
pub mod faults;
pub mod fedavg;
pub mod quantize;
pub mod sampler;
pub mod split;
pub mod worker;

use std::sync::Arc;

use crate::config::{Algorithm, RunConfig};
use crate::data::FederatedDataset;
use crate::data::{femnist::SyntheticFemnist, so_nwp, so_tag};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Common trainer interface.
pub trait Trainer {
    /// Run the configured number of rounds, returning the round log.
    fn run(&mut self) -> anyhow::Result<RunLog>;
}

/// Population size above which [`build_dataset`] switches from dense
/// (materialized per-client state) to streamed (forked-on-demand)
/// populations. Aligned with [`Rng::CHOOSE_K_DENSE_MAX`] so the sampler's
/// O(cohort) Floyd's path and the datasets' O(1) per-client shards cut
/// over at the same population scale: at or below the threshold every run
/// reproduces the historical dense bits (presets and goldens live orders
/// of magnitude below it); above it a round is O(cohort) end to end, and
/// a million-client population costs nothing to construct.
pub const STREAMED_POPULATION_MIN: usize = Rng::CHOOSE_K_DENSE_MAX;

/// Build the dataset a config asks for.
pub fn build_dataset(cfg: &RunConfig) -> anyhow::Result<Arc<dyn FederatedDataset>> {
    let streamed = cfg.num_clients > STREAMED_POPULATION_MIN;
    Ok(match cfg.task.as_str() {
        "femnist" => {
            if streamed {
                Arc::new(SyntheticFemnist::streamed(cfg.seed, cfg.num_clients, cfg.alpha))
            } else {
                Arc::new(SyntheticFemnist::new(cfg.seed, cfg.num_clients, cfg.alpha))
            }
        }
        "so_tag" => {
            let c = if cfg.preset == "paper" {
                so_tag::SoTagConfig::paper()
            } else {
                so_tag::SoTagConfig::small()
            };
            if streamed {
                Arc::new(so_tag::SyntheticSoTag::streamed(cfg.seed, cfg.num_clients, c))
            } else {
                Arc::new(so_tag::SyntheticSoTag::new(cfg.seed, cfg.num_clients, c))
            }
        }
        "so_nwp" => {
            let c = if cfg.preset == "paper" {
                so_nwp::SoNwpConfig::paper()
            } else {
                so_nwp::SoNwpConfig::small()
            };
            if streamed {
                Arc::new(so_nwp::SyntheticSoNwp::streamed(cfg.seed, cfg.num_clients, c))
            } else {
                Arc::new(so_nwp::SyntheticSoNwp::new(cfg.seed, cfg.num_clients, c))
            }
        }
        other => anyhow::bail!("unknown task '{other}'"),
    })
}

/// Build the trainer for a config (entry point used by the CLI and the
/// experiment drivers).
pub fn build_trainer(
    cfg: RunConfig,
    rt: Arc<Runtime>,
) -> anyhow::Result<Box<dyn Trainer>> {
    cfg.validate()?;
    let data = build_dataset(&cfg)?;
    Ok(match cfg.algorithm {
        Algorithm::FedAvg => Box::new(fedavg::FedAvgTrainer::new(cfg, rt, data)?),
        Algorithm::FedLite | Algorithm::SplitFed => {
            Box::new(split::SplitTrainer::new(cfg, rt, data)?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Past the streamed threshold, every task's dataset constructs
    /// without materializing the population — a million-client femnist
    /// (the heaviest dense constructor: per-client styles *and* Dirichlet
    /// rows) builds instantly and still serves its last client.
    #[test]
    fn million_client_configs_build_streamed_datasets() {
        for task in ["femnist", "so_tag", "so_nwp"] {
            let mut cfg = RunConfig::default();
            cfg.task = task.into();
            cfg.num_clients = 1_000_000;
            let t0 = std::time::Instant::now();
            let ds = build_dataset(&cfg).unwrap();
            assert!(
                t0.elapsed().as_secs_f64() < 5.0,
                "{task}: streamed construction must not scale with clients"
            );
            assert_eq!(ds.num_clients(), 1_000_000);
            assert!(ds.client_weight(999_999) > 0.0);
        }
        // at or below the threshold the historical dense path is used
        // (golden configs run 8–100 clients and must keep their bits)
        let cfg = RunConfig::default();
        assert!(cfg.num_clients <= STREAMED_POPULATION_MIN);
        assert!(build_dataset(&cfg).is_ok());
    }
}
