//! Artifact input assembly.
//!
//! Artifacts declare their inputs (name/shape/dtype/role) in the manifest;
//! nothing about input order is hard-coded here. [`assemble`] walks the
//! declared list and pulls each slot from the round's [`InputSources`]:
//! model parameters, the data batch, dropout masks (drawn once per
//! client-step and *reused* between `client_fwd` and `client_bwd`, which
//! recomputes the forward pass), the quantized activations, the returned
//! gradient, and λ.

use std::collections::HashMap;

use crate::data::{Array, Batch};
use crate::runtime::artifact::ArtifactMeta;
use crate::tensor::TensorList;
use crate::util::rng::Rng;

/// Everything an artifact invocation may need.
#[derive(Default)]
pub struct InputSources<'a> {
    pub wc: Option<&'a TensorList>,
    pub ws: Option<&'a TensorList>,
    pub batch: Option<&'a Batch>,
    /// Pre-drawn dropout masks by input name.
    pub masks: Option<&'a HashMap<String, Array>>,
    pub z_tilde: Option<&'a Array>,
    pub grad_z: Option<&'a Array>,
    pub lambda: Option<f32>,
}

/// Draw the dropout masks an artifact set needs, once per client-step.
///
/// Mask inputs are recognized by name (`*mask*`); the probability is
/// chosen by the `client`/`server` prefix. Values are pre-scaled
/// (`1/(1-p)` or `0`), so eval passes ones.
pub fn draw_masks(
    metas: &[&ArtifactMeta],
    p_client: f64,
    p_server: f64,
    rng: &mut Rng,
) -> HashMap<String, Array> {
    let mut out = HashMap::new();
    for meta in metas {
        for spec in &meta.inputs {
            if !spec.name.contains("mask") || out.contains_key(&spec.name) {
                continue;
            }
            let p = if spec.name.starts_with("server") { p_server } else { p_client };
            let n: usize = spec.shape.iter().product();
            let mut data = vec![0.0f32; n];
            rng.dropout_mask(p, &mut data);
            out.insert(spec.name.clone(), Array::f32(&spec.shape, data));
        }
    }
    out
}

/// Build the positional input list for one artifact invocation.
pub fn assemble(meta: &ArtifactMeta, src: &InputSources) -> anyhow::Result<Vec<Array>> {
    let mut out = Vec::with_capacity(meta.inputs.len());
    let mut next_wc = 0usize;
    let mut next_ws = 0usize;
    for spec in &meta.inputs {
        let arr: Array = match spec.role.as_str() {
            "param_client" => {
                let wc = src
                    .wc
                    .ok_or_else(|| anyhow::anyhow!("{}: needs client params", meta.name))?;
                let t = &wc.tensors[next_wc];
                next_wc += 1;
                Array::f32(t.shape(), t.data().to_vec())
            }
            "param_server" => {
                let ws = src
                    .ws
                    .ok_or_else(|| anyhow::anyhow!("{}: needs server params", meta.name))?;
                let t = &ws.tensors[next_ws];
                next_ws += 1;
                Array::f32(t.shape(), t.data().to_vec())
            }
            "cut" => src
                .z_tilde
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("{}: needs z_tilde", meta.name))?,
            "grad_cut" => src
                .grad_z
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("{}: needs grad_z", meta.name))?,
            "hyper" => Array::f32(
                &[],
                vec![src
                    .lambda
                    .ok_or_else(|| anyhow::anyhow!("{}: needs lambda", meta.name))?],
            ),
            "data" => match spec.name.as_str() {
                "x" => src
                    .batch
                    .map(|b| b.x.clone())
                    .ok_or_else(|| anyhow::anyhow!("{}: needs batch x", meta.name))?,
                "y" => src
                    .batch
                    .map(|b| b.y.clone())
                    .ok_or_else(|| anyhow::anyhow!("{}: needs batch y", meta.name))?,
                name if name.contains("mask") => src
                    .masks
                    .and_then(|m| m.get(name))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("{}: mask '{name}' not drawn", meta.name))?,
                other => anyhow::bail!("{}: unknown data input '{other}'", meta.name),
            },
            role => anyhow::bail!("{}: unknown input role '{role}'", meta.name),
        };
        out.push(arr);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::IoSpec;
    use crate::tensor::Tensor;

    fn meta() -> ArtifactMeta {
        ArtifactMeta {
            name: "client_bwd".into(),
            path: "p".into(),
            inputs: vec![
                IoSpec { name: "w".into(), shape: vec![2, 2], dtype: "f32".into(), role: "param_client".into() },
                IoSpec { name: "x".into(), shape: vec![1, 2], dtype: "f32".into(), role: "data".into() },
                IoSpec { name: "client_mask".into(), shape: vec![1, 4], dtype: "f32".into(), role: "data".into() },
                IoSpec { name: "z_tilde".into(), shape: vec![1, 4], dtype: "f32".into(), role: "cut".into() },
                IoSpec { name: "grad_z".into(), shape: vec![1, 4], dtype: "f32".into(), role: "grad_cut".into() },
                IoSpec { name: "lambda".into(), shape: vec![], dtype: "f32".into(), role: "hyper".into() },
            ],
            outputs: vec!["g".into()],
            meta: crate::util::json::Value::Null,
        }
    }

    #[test]
    fn assembles_in_manifest_order() {
        let wc = TensorList::new(
            vec!["w".into()],
            vec![Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.])],
        );
        let batch = Batch {
            x: Array::f32(&[1, 2], vec![5., 6.]),
            y: Array::i32(&[1], vec![0]),
        };
        let m = meta();
        let mut rng = Rng::new(0);
        let masks = draw_masks(&[&m], 0.0, 0.0, &mut rng);
        let zt = Array::f32(&[1, 4], vec![0.0; 4]);
        let gz = Array::f32(&[1, 4], vec![1.0; 4]);
        let src = InputSources {
            wc: Some(&wc),
            batch: Some(&batch),
            masks: Some(&masks),
            z_tilde: Some(&zt),
            grad_z: Some(&gz),
            lambda: Some(0.5),
            ..Default::default()
        };
        let inputs = assemble(&m, &src).unwrap();
        assert_eq!(inputs.len(), 6);
        assert_eq!(inputs[0].as_f32().unwrap(), &[1., 2., 3., 4.]);
        assert_eq!(inputs[1].as_f32().unwrap(), &[5., 6.]);
        // p=0 dropout -> all ones
        assert_eq!(inputs[2].as_f32().unwrap(), &[1.0; 4]);
        assert_eq!(inputs[5].shape(), &[] as &[usize]);
        assert_eq!(inputs[5].as_f32().unwrap(), &[0.5]);
    }

    #[test]
    fn missing_source_is_an_error() {
        let m = meta();
        let src = InputSources::default();
        let err = assemble(&m, &src).unwrap_err().to_string();
        assert!(err.contains("client params"), "{err}");
    }

    #[test]
    fn draw_masks_dedupes_and_scales() {
        let m = meta();
        let mut rng = Rng::new(1);
        let masks = draw_masks(&[&m, &m], 0.5, 0.0, &mut rng);
        assert_eq!(masks.len(), 1);
        let v = masks["client_mask"].as_f32().unwrap();
        assert!(v.iter().all(|&x| x == 0.0 || (x - 2.0).abs() < 1e-6));
    }
}
