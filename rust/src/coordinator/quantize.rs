//! Quantization backend selection: native rust engine vs the Pallas/PJRT
//! artifact (the L1 kernel on the hot path).
//!
//! Both backends implement the same contract — given the cut activations
//! `z [act_batch, d]`, produce `(codebooks, codes, z_tilde, sq_error)` —
//! and both feed the same wire format. Integration tests cross-check them
//! on identical inputs; the artifact path receives its initial centroids
//! from the same RandomRows rule the native engine uses.

use std::sync::Arc;

use crate::config::QuantizerEngine;
use crate::data::Array;
use crate::quantizer::pq::{GroupedPq, PqConfig, PqOutput, QuantizeScratch};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// A quantization backend bound to a task variant + PQ config.
pub struct QuantizeBackend {
    pub config: PqConfig,
    pub d: usize,
    engine: Engine,
}

enum Engine {
    Native(GroupedPq),
    Pjrt { rt: Arc<Runtime>, variant: String, artifact: String, gather: GroupedPq },
}

impl QuantizeBackend {
    pub fn new(
        engine: QuantizerEngine,
        config: PqConfig,
        d: usize,
        rt: Arc<Runtime>,
        variant: &str,
    ) -> anyhow::Result<Self> {
        let native = GroupedPq::new(config, d)?;
        let engine = match engine {
            QuantizerEngine::Native => Engine::Native(native),
            QuantizerEngine::Pjrt => {
                let v = rt.manifest.variant(variant)?;
                let meta = v.find_pq(config.q, config.l, config.r).ok_or_else(|| {
                    anyhow::anyhow!(
                        "no PJRT quantizer artifact for q={} L={} R={} in '{variant}' \
                         (available: {:?}); use --quantizer native or add the config \
                         to PQ_CONFIGS in python/compile/model.py",
                        config.q,
                        config.l,
                        config.r,
                        v.pq_artifacts()
                    )
                })?;
                let artifact = meta.name.clone();
                Engine::Pjrt { rt, variant: variant.to_string(), artifact, gather: native }
            }
        };
        Ok(QuantizeBackend { config, d, engine })
    }

    /// Which engine is active (for logs/benches).
    pub fn engine_name(&self) -> &'static str {
        match self.engine {
            Engine::Native(_) => "native",
            Engine::Pjrt { .. } => "pjrt",
        }
    }

    /// The backend's native [`GroupedPq`] (both engines carry one — the
    /// PJRT path uses it for gathering and host-side init). Lets callers
    /// reconstruct server-side without building a second quantizer.
    pub fn native_pq(&self) -> &GroupedPq {
        match &self.engine {
            Engine::Native(pq) => pq,
            Engine::Pjrt { gather, .. } => gather,
        }
    }

    /// Quantize one activation batch into caller-owned buffers. On the
    /// native engine this is the zero-allocation steady-state path (see
    /// [`GroupedPq::quantize_into`]); the PJRT path round-trips through
    /// the artifact runtime and replaces `out` wholesale (the device
    /// boundary allocates regardless).
    pub fn quantize_into(
        &self,
        z: &[f32],
        b: usize,
        rng: &mut Rng,
        scratch: &mut QuantizeScratch,
        out: &mut PqOutput,
    ) -> anyhow::Result<()> {
        match &self.engine {
            Engine::Native(pq) => {
                pq.quantize_into(z, b, rng, scratch, out);
                Ok(())
            }
            Engine::Pjrt { .. } => {
                *out = self.quantize(z, b, rng)?;
                Ok(())
            }
        }
    }

    /// Quantize one activation batch.
    pub fn quantize(&self, z: &[f32], b: usize, rng: &mut Rng) -> anyhow::Result<PqOutput> {
        match &self.engine {
            Engine::Native(pq) => Ok(pq.quantize(z, b, rng)),
            Engine::Pjrt { rt, variant, artifact, gather } => {
                let c = self.config;
                let dsub = c.dsub(self.d);
                // RandomRows init per group, computed host-side exactly
                // like the native engine's init.
                let ng = c.group_size(b);
                let mut init = Vec::with_capacity(c.r * c.l * dsub);
                let mut buf = Vec::new();
                for g in 0..c.r {
                    gather.gather_group(z, b, g, &mut buf);
                    let idx = if ng >= c.l {
                        rng.choose_k(ng, c.l)
                    } else {
                        (0..c.l).map(|i| i % ng).collect()
                    };
                    for i in idx {
                        init.extend_from_slice(&buf[i * dsub..(i + 1) * dsub]);
                    }
                }
                let outs = rt.run(
                    variant,
                    artifact,
                    &[
                        Array::f32(&[b, self.d], z.to_vec()),
                        Array::f32(&[c.r, c.l, dsub], init),
                    ],
                )?;
                let codebooks = outs[0]
                    .as_f32()
                    .ok_or_else(|| anyhow::anyhow!("codebooks dtype"))?
                    .to_vec();
                let codes: Vec<u32> = outs[1]
                    .as_i32()
                    .ok_or_else(|| anyhow::anyhow!("codes dtype"))?
                    .iter()
                    .map(|&x| x as u32)
                    .collect();
                let z_tilde = outs[2]
                    .as_f32()
                    .ok_or_else(|| anyhow::anyhow!("z_tilde dtype"))?
                    .to_vec();
                let sq_error = outs[3]
                    .as_f32()
                    .and_then(|v| v.first().copied())
                    .unwrap_or(0.0) as f64;
                Ok(PqOutput {
                    codebooks,
                    codes,
                    z_tilde,
                    sq_error,
                    config: c,
                    b,
                    d: self.d,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The native path is covered in quantizer::pq; PJRT cross-checks live
    // in rust/tests/ (they need built artifacts). Here: config wiring.
    #[test]
    fn native_backend_smoke() {
        let rt_unused: Option<Arc<Runtime>> = None;
        let _ = rt_unused; // Runtime not needed for native
        let cfg = PqConfig::new(4, 1, 2);
        let pq = GroupedPq::new(cfg, 16).unwrap();
        let mut rng = Rng::new(0);
        let z: Vec<f32> = (0..64).map(|i| (i % 7) as f32).collect();
        let out = pq.quantize(&z, 4, &mut rng);
        assert_eq!(out.z_tilde.len(), 64);
    }
}
