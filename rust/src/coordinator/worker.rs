//! Replica worker: the `fedlite-client` process behind a
//! [`crate::coordinator::backend::SocketBackend`].
//!
//! A worker connects to a serving coordinator, receives the run config in
//! the `Welcome` frame, and builds a **full replica trainer** from it —
//! same seed, same synthetic dataset, same artifact runtime — so its
//! `client_step` is the very function the in-process backend would have
//! called. Per round it installs the coordinator's mutable state
//! (`RoundState`, then the decoded `Broadcast`) before preparing, which
//! pins the replica's parameters to the coordinator's bit-for-bit; each
//! `StepAssign` then runs one client with the engine's own
//! `client_stream_key` fork and the fault plan that traveled with the
//! assignment — including its byzantine-kind marker, so an adversarial
//! client misbehaves identically whether it runs in-process or on a
//! replica. The result frame carries everything [`ClientOutput`]
//! carries — including the worker-metered [`RoundBytes`], which the
//! coordinator absorbs into its own meter — so a socket run's records are
//! byte-identical to the in-process run of the same config.
//!
//! [`ClientOutput`]: crate::coordinator::engine::ClientOutput
//! [`RoundBytes`]: crate::comm::accounting::RoundBytes

use std::net::TcpStream;
use std::sync::Arc;

use crate::comm::message::Message;
use crate::comm::transport::{self, Frame, StepResult, PROTOCOL_VERSION};
use crate::config::{Algorithm, RunConfig};
use crate::coordinator::engine::{client_stream_key, RoundAlgorithm};
use crate::coordinator::fedavg::FedAvgTrainer;
use crate::coordinator::split::SplitTrainer;
use crate::coordinator::build_dataset;
use crate::runtime::Runtime;
use crate::util::json;

/// Join the coordinator at `connect` and serve client steps until the
/// run ends. `max_rounds > 0` makes the worker leave gracefully after
/// that many rounds (exercises the membership churn path; `0` serves
/// until `Shutdown`).
pub fn run_worker(connect: &str, max_rounds: usize) -> anyhow::Result<()> {
    let mut stream = TcpStream::connect(connect)
        .map_err(|e| anyhow::anyhow!("connect {connect}: {e}"))?;
    // no read deadline on the worker side: between rounds it simply waits
    // for the coordinator's next frame
    transport::configure_stream(&stream, None)?;
    Frame::Join { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
    let config_json = match Frame::read_from(&mut stream)? {
        Frame::Welcome { config_json } => config_json,
        Frame::Shutdown => return Ok(()),
        other => anyhow::bail!("expected Welcome, got {}", other.name()),
    };
    let parsed =
        json::parse(&config_json).map_err(|e| anyhow::anyhow!("welcome config: {e}"))?;
    let mut cfg = RunConfig::from_json(&parsed)?;
    // replicas never write logs or checkpoints: the coordinator owns the
    // run's outputs, a worker owns only its compute
    cfg.out_dir = String::new();
    cfg.validate()?;
    log::info!(
        "joined {connect}: task={} algo={} seed={}",
        cfg.task,
        cfg.algorithm.name(),
        cfg.seed
    );
    let rt = Arc::new(Runtime::open(&cfg.artifacts_dir)?);
    let data = build_dataset(&cfg)?;
    match cfg.algorithm {
        Algorithm::FedAvg => {
            let mut t = FedAvgTrainer::new(cfg, rt, data)?;
            serve_rounds(&mut t, stream, max_rounds)
        }
        Algorithm::FedLite | Algorithm::SplitFed => {
            let mut t = SplitTrainer::new(cfg, rt, data)?;
            serve_rounds(&mut t, stream, max_rounds)
        }
    }
}

/// The worker's frame loop: install round state, answer assignments,
/// leave or shut down when told (or when `max_rounds` is reached).
fn serve_rounds<A: RoundAlgorithm>(
    algo: &mut A,
    mut stream: TcpStream,
    max_rounds: usize,
) -> anyhow::Result<()> {
    Frame::Ready.write_to(&mut stream)?;
    // the round the replica is synced to: (round, prep, broadcast)
    let mut current: Option<(u32, A::Prep, Message)> = None;
    // one warm scratch: a worker runs its assignments serially, so a
    // single slot reaches the same steady state as the engine's pool
    let mut scratch = A::Scratch::default();
    let mut rounds_done = 0usize;
    loop {
        match Frame::read_from(&mut stream)? {
            Frame::RoundState { round: _, tensors } => {
                algo.install_round_state(tensors)?;
                current = None;
            }
            Frame::Broadcast { round, message } => {
                let (msg, _, _) = Message::decode(&message)?;
                algo.install_broadcast(&msg)?;
                // prepare *after* installing, so the prep snapshots the
                // coordinator's parameters, not the replica's stale ones
                let prep = algo.prepare(round as usize)?;
                current = Some((round, prep, msg));
            }
            Frame::StepAssign { round, attempt, client, plan } => {
                let (cur_round, prep, bmsg) = current
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("StepAssign before Broadcast"))?;
                anyhow::ensure!(
                    *cur_round == round,
                    "assignment for round {round}, replica holds round {cur_round}"
                );
                let ci = client as usize;
                // the engine's own key derivation: pure in
                // (round, attempt, client), so the remote step's RNG
                // stream is bit-identical to the in-process one
                let key =
                    client_stream_key(algo.stream_tag(), round as u64, ci, attempt);
                let mut crng = algo.env().rng.fork(key);
                let reply = algo
                    .client_step(prep, bmsg, round, ci, &mut crng, &plan, &mut scratch)
                    .and_then(|out| {
                        let payload = match out.payload {
                            Some(p) => Some(algo.payload_to_wire(p)?),
                            None => None,
                        };
                        Ok(Frame::StepResult(StepResult {
                            client,
                            weight: out.weight,
                            loss: out.loss,
                            metric_sums: out.metric_sums,
                            quant_rel_err: out.quant_rel_err,
                            surrogate_loss: out.surrogate_loss,
                            dropped: out.dropped,
                            delay_seconds: out.delay_seconds,
                            bytes: out.bytes,
                            payload,
                        }))
                    })
                    .unwrap_or_else(|e| Frame::StepError {
                        client,
                        error: format!("{e:#}"),
                    });
                reply.write_to(&mut stream)?;
            }
            Frame::RoundEnd { .. } => {
                // every member answers the round end: Leave to depart,
                // Ready to stay — the coordinator blocks on this reply,
                // which is what makes graceful churn race-free
                rounds_done += 1;
                if max_rounds > 0 && rounds_done >= max_rounds {
                    Frame::Leave.write_to(&mut stream)?;
                    log::info!("served {rounds_done} rounds; leaving");
                    return Ok(());
                }
                Frame::Ready.write_to(&mut stream)?;
            }
            Frame::Shutdown => {
                log::info!("run complete after {rounds_done} rounds; shutting down");
                return Ok(());
            }
            other => anyhow::bail!("unexpected {} frame", other.name()),
        }
    }
}
