//! Replica worker: the `fedlite-client` process behind a
//! [`crate::coordinator::backend::SocketBackend`].
//!
//! A worker connects to a serving coordinator, receives the run config in
//! the `Welcome` frame, and builds a **full replica trainer** from it —
//! same seed, same synthetic dataset, same artifact runtime — so its
//! `client_step` is the very function the in-process backend would have
//! called. Per round it installs the coordinator's mutable state
//! (`RoundState`, then the decoded `Broadcast`) before preparing, which
//! pins the replica's parameters to the coordinator's bit-for-bit; each
//! `StepAssign` then runs one client with the engine's own
//! `client_stream_key` fork and the fault plan that traveled with the
//! assignment — including its byzantine-kind marker, so an adversarial
//! client misbehaves identically whether it runs in-process or on a
//! replica. The result frame carries everything [`ClientOutput`]
//! carries — including the worker-metered [`RoundBytes`], which the
//! coordinator absorbs into its own meter — so a socket run's records are
//! byte-identical to the in-process run of the same config.
//!
//! Sessions are wrapped in a bounded exponential-backoff reconnect loop:
//! a connection lost mid-run (coordinator quarantined us as a straggler,
//! transport chaos severed the link, the network hiccuped) triggers a
//! fresh join rather than worker death. Every round re-syncs the full
//! mutable state (`RoundState` + `Broadcast`), so a rejoining replica is
//! bit-identical to one that never left. The retry budget refills after
//! every successful handshake, so a long-lived worker that rejoins many
//! times over a run never exhausts it; only *consecutive* failed
//! connects do.
//!
//! The worker also honors the run's `--chaos-*` knobs (shipped in the
//! `Welcome` config): each reply frame draws `(delay, truncate)` from a
//! fork keyed `chaos_key(round, client, frame)` off the run seed —
//! deterministic, never from wall clock — and a truncated reply really
//! writes a partial frame and severs the connection, exercising the
//! coordinator's reap + reassignment path end to end.
//!
//! [`ClientOutput`]: crate::coordinator::engine::ClientOutput
//! [`RoundBytes`]: crate::comm::accounting::RoundBytes

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::comm::message::Message;
use crate::comm::transport::{self, Frame, StepResult, PROTOCOL_VERSION};
use crate::config::{Algorithm, RunConfig};
use crate::coordinator::build_dataset;
use crate::coordinator::engine::{client_stream_key, RoundAlgorithm};
use crate::coordinator::faults::ChaosConfig;
use crate::coordinator::fedavg::FedAvgTrainer;
use crate::coordinator::split::SplitTrainer;
use crate::runtime::Runtime;
use crate::util::json;
use crate::util::rng::Rng;

/// Ceiling on the exponential reconnect backoff.
const MAX_BACKOFF_MS: u64 = 10_000;

/// How a worker joins and serves a coordinator.
#[derive(Clone, Copy, Debug)]
pub struct WorkerOptions {
    /// Leave gracefully after serving this many rounds in one session
    /// (exercises the membership churn path; `0` serves until
    /// `Shutdown`).
    pub max_rounds: usize,
    /// Consecutive failed connects (or dropped sessions) tolerated
    /// before giving up. The budget refills after every successful
    /// handshake.
    pub reconnect_tries: u32,
    /// Base reconnect delay; doubles per consecutive failure, capped at
    /// [`MAX_BACKOFF_MS`].
    pub backoff_ms: u64,
    /// Debug knob: sleep this long before every reply, making this
    /// worker a deterministic straggler (drives the coordinator's
    /// deadline → quarantine → reassignment path in CI). `0` disables.
    pub straggle_ms: u64,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            max_rounds: 0,
            reconnect_tries: 5,
            backoff_ms: 100,
            straggle_ms: 0,
        }
    }
}

/// Join the coordinator at `connect` and serve client steps until the
/// run ends, reconnecting with bounded exponential backoff when the
/// session drops (see the module docs).
pub fn run_worker(connect: &str, opts: WorkerOptions) -> anyhow::Result<()> {
    let base = opts.backoff_ms.max(1);
    let mut tries_left = opts.reconnect_tries;
    let mut backoff = base;
    loop {
        let mut joined = false;
        match serve_session(connect, &opts, &mut joined) {
            Ok(()) => return Ok(()),
            Err(e) => {
                if joined {
                    // the handshake succeeded, so this was a live session
                    // dropping (quarantine, chaos, coordinator restart):
                    // refill the retry budget before counting the failure
                    tries_left = opts.reconnect_tries;
                    backoff = base;
                }
                if tries_left == 0 {
                    return Err(e);
                }
                tries_left -= 1;
                log::warn!(
                    "session with {connect} ended ({e:#}); reconnecting in {backoff} ms \
                     ({tries_left} tries left)"
                );
                std::thread::sleep(Duration::from_millis(backoff));
                backoff = (backoff * 2).min(MAX_BACKOFF_MS);
            }
        }
    }
}

/// One connect → join → serve session. Sets `joined` once the handshake
/// completes, so the caller can distinguish "coordinator unreachable"
/// from "live session dropped".
fn serve_session(
    connect: &str,
    opts: &WorkerOptions,
    joined: &mut bool,
) -> anyhow::Result<()> {
    let mut stream = TcpStream::connect(connect)
        .map_err(|e| anyhow::anyhow!("connect {connect}: {e}"))?;
    // no read deadline on the worker side: between rounds it simply waits
    // for the coordinator's next frame
    transport::configure_stream(&stream, None)?;
    Frame::Join { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
    let config_json = match Frame::read_from(&mut stream)? {
        Frame::Welcome { config_json } => config_json,
        Frame::Shutdown => return Ok(()),
        other => anyhow::bail!("expected Welcome, got {}", other.name()),
    };
    *joined = true;
    let parsed =
        json::parse(&config_json).map_err(|e| anyhow::anyhow!("welcome config: {e}"))?;
    let mut cfg = RunConfig::from_json(&parsed)?;
    // replicas never write logs or checkpoints: the coordinator owns the
    // run's outputs, a worker owns only its compute
    cfg.out_dir = String::new();
    cfg.validate()?;
    log::info!(
        "joined {connect}: task={} algo={} seed={}",
        cfg.task,
        cfg.algorithm.name(),
        cfg.seed
    );
    // the chaos knobs travel in the Welcome config, so both link ends
    // draw from the same deterministic schedule space
    let chaos = Chaos {
        cfg: ChaosConfig::from_run(&cfg),
        root: Rng::new(cfg.seed),
        straggle_ms: opts.straggle_ms,
        frame: 0,
    };
    let rt = Arc::new(Runtime::open(&cfg.artifacts_dir)?);
    let data = build_dataset(&cfg)?;
    match cfg.algorithm {
        Algorithm::FedAvg => {
            let mut t = FedAvgTrainer::new(cfg, rt, data)?;
            serve_rounds(&mut t, stream, opts.max_rounds, chaos)
        }
        Algorithm::FedLite | Algorithm::SplitFed => {
            let mut t = SplitTrainer::new(cfg, rt, data)?;
            serve_rounds(&mut t, stream, opts.max_rounds, chaos)
        }
    }
}

/// The worker's reply-side fault injection: deterministic chaos draws
/// plus the straggle debug knob.
struct Chaos {
    cfg: ChaosConfig,
    /// Root for per-reply forks; never advanced (`fork` discipline).
    root: Rng,
    straggle_ms: u64,
    /// Session-scoped reply counter, the `frame` chaos-key component. A
    /// reassigned slot is answered by a different member at a different
    /// counter, so its chaos draw is independent of the one that doomed
    /// the original delivery — redeliveries converge instead of
    /// re-drawing the same fate forever.
    frame: u64,
}

impl Chaos {
    /// Apply the configured faults around sending `reply`. Returns
    /// `Err` after a truncation (the connection is gone); the caller's
    /// session ends and the reconnect loop takes over.
    fn send(
        &mut self,
        stream: &mut TcpStream,
        round: u32,
        client: u64,
        reply: &Frame,
    ) -> anyhow::Result<()> {
        if self.straggle_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.straggle_ms));
        }
        if self.cfg.enabled() {
            let cf = self.cfg.frame(&self.root, round as u64, client, self.frame);
            self.frame += 1;
            if cf.delay_ms > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(cf.delay_ms / 1000.0));
            }
            if cf.truncate {
                // write a real half-frame, then sever the link: the
                // coordinator's poll loop sees a short read, reaps this
                // member as a peer failure, and reassigns the slot
                let body = reply.encode();
                let half = (body.len() / 2).max(1);
                stream.write_all(&(body.len() as u32).to_le_bytes())?;
                stream.write_all(&body[..half])?;
                stream.flush()?;
                let _ = stream.shutdown(std::net::Shutdown::Both);
                anyhow::bail!(
                    "chaos: truncated reply for client {client} mid-frame (round {round})"
                );
            }
        }
        reply.write_to(stream)
    }
}

/// The worker's frame loop: install round state, answer assignments,
/// leave or shut down when told (or when `max_rounds` is reached).
fn serve_rounds<A: RoundAlgorithm>(
    algo: &mut A,
    mut stream: TcpStream,
    max_rounds: usize,
    mut chaos: Chaos,
) -> anyhow::Result<()> {
    Frame::Ready.write_to(&mut stream)?;
    // the round the replica is synced to: (round, prep, broadcast)
    let mut current: Option<(u32, A::Prep, Message)> = None;
    // one warm scratch: a worker runs its assignments serially, so a
    // single slot reaches the same steady state as the engine's pool
    let mut scratch = A::Scratch::default();
    let mut rounds_done = 0usize;
    loop {
        match Frame::read_from(&mut stream)? {
            Frame::RoundState { round: _, tensors } => {
                algo.install_round_state(tensors)?;
                current = None;
            }
            Frame::Broadcast { round, message } => {
                let (msg, _, _) = Message::decode(&message)?;
                algo.install_broadcast(&msg)?;
                // prepare *after* installing, so the prep snapshots the
                // coordinator's parameters, not the replica's stale ones
                let prep = algo.prepare(round as usize)?;
                current = Some((round, prep, msg));
            }
            Frame::StepAssign { round, attempt, client, plan } => {
                let (cur_round, prep, bmsg) = current
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("StepAssign before Broadcast"))?;
                anyhow::ensure!(
                    *cur_round == round,
                    "assignment for round {round}, replica holds round {cur_round}"
                );
                let ci = client as usize;
                // the engine's own key derivation: pure in
                // (round, attempt, client), so the remote step's RNG
                // stream is bit-identical to the in-process one
                let key =
                    client_stream_key(algo.stream_tag(), round as u64, ci, attempt);
                let mut crng = algo.env().rng.fork(key);
                let reply = algo
                    .client_step(prep, bmsg, round, ci, &mut crng, &plan, &mut scratch)
                    .and_then(|out| {
                        let payload = match out.payload {
                            Some(p) => Some(algo.payload_to_wire(p)?),
                            None => None,
                        };
                        Ok(Frame::StepResult(StepResult {
                            client,
                            weight: out.weight,
                            loss: out.loss,
                            metric_sums: out.metric_sums,
                            quant_rel_err: out.quant_rel_err,
                            surrogate_loss: out.surrogate_loss,
                            dropped: out.dropped,
                            delay_seconds: out.delay_seconds,
                            bytes: out.bytes,
                            payload,
                        }))
                    })
                    .unwrap_or_else(|e| Frame::StepError {
                        client,
                        error: format!("{e:#}"),
                    });
                chaos.send(&mut stream, round, client, &reply)?;
            }
            Frame::RoundEnd { .. } => {
                // every member answers the round end: Leave to depart,
                // Ready to stay — the coordinator blocks on this reply,
                // which is what makes graceful churn race-free
                rounds_done += 1;
                if max_rounds > 0 && rounds_done >= max_rounds {
                    Frame::Leave.write_to(&mut stream)?;
                    log::info!("served {rounds_done} rounds; leaving");
                    return Ok(());
                }
                Frame::Ready.write_to(&mut stream)?;
            }
            Frame::Shutdown => {
                log::info!("run complete after {rounds_done} rounds; shutting down");
                return Ok(());
            }
            other => anyhow::bail!("unexpected {} frame", other.name()),
        }
    }
}
