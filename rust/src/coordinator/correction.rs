//! Gradient correction (paper §4.2, eq. (5)).
//!
//! The split trainer applies [`corrected_cotangent`] host-side to the
//! wire gradient before `client_bwd` (whose λ input it pins to 0, so the
//! correction is applied exactly once), and logs [`surrogate_loss`] as
//! the round CSV's `surrogate_loss` column. The artifact family still
//! accepts λ for backends that prefer the correction inside the lowered
//! graph — both paths compute the identical float sequence.

/// Corrected cotangent: `grad_z_tilde + lambda * (z - z_tilde)`.
pub fn corrected_cotangent(
    grad_z_tilde: &[f32],
    z: &[f32],
    z_tilde: &[f32],
    lambda: f32,
) -> Vec<f32> {
    assert_eq!(grad_z_tilde.len(), z.len());
    assert_eq!(z.len(), z_tilde.len());
    grad_z_tilde
        .iter()
        .zip(z.iter().zip(z_tilde))
        .map(|(&g, (&zi, &zt))| g + lambda * (zi - zt))
        .collect()
}

/// The surrogate-loss value whose gradient eq. (5) is (paper eq. (6)),
/// up to the z-independent constant: `<grad, z> + (λ/2)||z - z~||²`.
pub fn surrogate_loss(grad_z_tilde: &[f32], z: &[f32], z_tilde: &[f32], lambda: f32) -> f64 {
    let inner: f64 = grad_z_tilde
        .iter()
        .zip(z)
        .map(|(&g, &zi)| (g as f64) * (zi as f64))
        .sum();
    let qerr: f64 = z
        .iter()
        .zip(z_tilde)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    inner + 0.5 * lambda as f64 * qerr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_zero_passes_gradient_through() {
        let g = vec![1.0, -2.0, 3.0];
        let z = vec![0.5, 0.5, 0.5];
        let zt = vec![0.0, 1.0, 0.5];
        assert_eq!(corrected_cotangent(&g, &z, &zt, 0.0), g);
    }

    #[test]
    fn correction_points_toward_quantized() {
        // with zero server gradient, the correction drives z toward z~
        let g = vec![0.0; 3];
        let z = vec![1.0, 2.0, 3.0];
        let zt = vec![0.0, 0.0, 0.0];
        let c = corrected_cotangent(&g, &z, &zt, 0.1);
        // gradient DESCENT step z -= eta*c moves z toward z~
        for (ci, (zi, zti)) in c.iter().zip(z.iter().zip(&zt)) {
            assert_eq!(*ci, 0.1 * (zi - zti));
        }
    }

    #[test]
    fn correction_is_linear_in_lambda() {
        // eq. (5) is affine in λ: c(λ) − c(0) scales exactly with λ, and
        // the λ-dependent part of eq. (6) scales the same way
        let g = vec![0.4, -1.2, 0.7, 0.0];
        let z = vec![1.5, -0.25, 0.0, 2.0];
        let zt = vec![1.0, 0.25, -0.5, 2.0];
        let base = corrected_cotangent(&g, &z, &zt, 0.0);
        let c1 = corrected_cotangent(&g, &z, &zt, 0.5);
        let c2 = corrected_cotangent(&g, &z, &zt, 1.0);
        for k in 0..g.len() {
            let d1 = c1[k] - base[k];
            let d2 = c2[k] - base[k];
            assert!((d2 - 2.0 * d1).abs() < 1e-6, "k={k}: {d2} vs 2*{d1}");
            assert!((d1 - 0.5 * (z[k] - zt[k])).abs() < 1e-6);
        }
        let s0 = surrogate_loss(&g, &z, &zt, 0.0);
        let s1 = surrogate_loss(&g, &z, &zt, 0.5);
        let s2 = surrogate_loss(&g, &z, &zt, 1.0);
        assert!(((s2 - s0) - 2.0 * (s1 - s0)).abs() < 1e-9);
    }

    #[test]
    fn matches_finite_difference_of_surrogate() {
        let g = vec![0.3, -0.7];
        let zt = vec![1.0, -1.0];
        let z = vec![0.2, 0.4];
        let lam = 0.05;
        let c = corrected_cotangent(&g, &z, &zt, lam);
        let eps = 1e-4f32;
        for k in 0..2 {
            let mut zp = z.clone();
            zp[k] += eps;
            let mut zm = z.clone();
            zm[k] -= eps;
            let fd = (surrogate_loss(&g, &zp, &zt, lam)
                - surrogate_loss(&g, &zm, &zt, lam))
                / (2.0 * eps as f64);
            assert!((fd - c[k] as f64).abs() < 1e-3, "k={k}: {fd} vs {}", c[k]);
        }
    }
}
