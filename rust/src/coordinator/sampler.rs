//! Per-round client sampling.
//!
//! The paper samples a random subset S of clients each iteration
//! (stateless clients, §4.1 "Why not reuse the codebooks"). Uniform
//! without-replacement sampling is the default; weighted sampling by
//! dataset size is available for ablations.

use crate::util::rng::Rng;

/// Sampling strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    UniformWithoutReplacement,
    /// Probability proportional to client weight (with replacement).
    WeightedWithReplacement,
}

pub struct ClientSampler {
    population: usize,
    per_round: usize,
    strategy: Strategy,
}

impl ClientSampler {
    pub fn uniform(population: usize, per_round: usize) -> Self {
        assert!(per_round <= population);
        ClientSampler { population, per_round, strategy: Strategy::UniformWithoutReplacement }
    }

    pub fn weighted(population: usize, per_round: usize) -> Self {
        ClientSampler { population, per_round, strategy: Strategy::WeightedWithReplacement }
    }

    /// Sample the round's cohort. `weights` are the p_i (only used by the
    /// weighted strategy).
    pub fn sample(&self, rng: &mut Rng, weights: &[f64]) -> Vec<usize> {
        match self.strategy {
            Strategy::UniformWithoutReplacement => {
                rng.choose_k(self.population, self.per_round)
            }
            Strategy::WeightedWithReplacement => (0..self.per_round)
                .map(|_| rng.categorical(weights))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distinct_and_in_range() {
        let s = ClientSampler::uniform(50, 10);
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let mut c = s.sample(&mut rng, &[]);
            assert_eq!(c.len(), 10);
            assert!(c.iter().all(|&i| i < 50));
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), 10);
        }
    }

    #[test]
    fn uniform_covers_population() {
        let s = ClientSampler::uniform(20, 5);
        let mut rng = Rng::new(1);
        let mut seen = vec![false; 20];
        for _ in 0..200 {
            for i in s.sample(&mut rng, &[]) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn weighted_prefers_heavy_clients() {
        let s = ClientSampler::weighted(3, 1);
        let w = vec![0.9, 0.05, 0.05];
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            counts[s.sample(&mut rng, &w)[0]] += 1;
        }
        assert!(counts[0] > 700, "{counts:?}");
    }
}
