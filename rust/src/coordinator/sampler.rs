//! Per-round client sampling.
//!
//! The paper samples a random subset S of clients each iteration
//! (stateless clients, §4.1 "Why not reuse the codebooks"). Uniform
//! without-replacement sampling is the default; weighted sampling by
//! dataset size is available for ablations.

use crate::util::rng::Rng;

/// Sampling strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    UniformWithoutReplacement,
    /// Probability proportional to client weight (with replacement).
    WeightedWithReplacement,
}

pub struct ClientSampler {
    population: usize,
    per_round: usize,
    strategy: Strategy,
}

impl ClientSampler {
    pub fn uniform(population: usize, per_round: usize) -> Self {
        assert!(per_round <= population, "cohort {per_round} > population {population}");
        ClientSampler { population, per_round, strategy: Strategy::UniformWithoutReplacement }
    }

    pub fn weighted(population: usize, per_round: usize) -> Self {
        // with-replacement sampling has no structural k <= n requirement,
        // but a cohort larger than the population is a config error here
        // just as it is for the uniform strategy
        assert!(per_round <= population, "cohort {per_round} > population {population}");
        ClientSampler { population, per_round, strategy: Strategy::WeightedWithReplacement }
    }

    /// Sample the round's cohort. `weights` are the p_i (only used by the
    /// weighted strategy, which requires exactly one weight per client —
    /// a longer vector used to silently yield out-of-range client ids).
    pub fn sample(&self, rng: &mut Rng, weights: &[f64]) -> Vec<usize> {
        match self.strategy {
            Strategy::UniformWithoutReplacement => {
                rng.choose_k(self.population, self.per_round)
            }
            Strategy::WeightedWithReplacement => {
                assert_eq!(
                    weights.len(),
                    self.population,
                    "weighted sampling needs one weight per client"
                );
                (0..self.per_round).map(|_| rng.categorical(weights)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distinct_and_in_range() {
        let s = ClientSampler::uniform(50, 10);
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let mut c = s.sample(&mut rng, &[]);
            assert_eq!(c.len(), 10);
            assert!(c.iter().all(|&i| i < 50));
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), 10);
        }
    }

    #[test]
    fn uniform_covers_population() {
        let s = ClientSampler::uniform(20, 5);
        let mut rng = Rng::new(1);
        let mut seen = vec![false; 20];
        for _ in 0..200 {
            for i in s.sample(&mut rng, &[]) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn weighted_prefers_heavy_clients() {
        let s = ClientSampler::weighted(3, 1);
        let w = vec![0.9, 0.05, 0.05];
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            counts[s.sample(&mut rng, &w)[0]] += 1;
        }
        assert!(counts[0] > 700, "{counts:?}");
    }

    #[test]
    fn cohort_equal_to_population_selects_everyone() {
        let s = ClientSampler::uniform(6, 6);
        let mut rng = Rng::new(3);
        let mut c = s.sample(&mut rng, &[]);
        c.sort_unstable();
        assert_eq!(c, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn population_of_one() {
        let u = ClientSampler::uniform(1, 1);
        let w = ClientSampler::weighted(1, 1);
        let mut rng = Rng::new(4);
        assert_eq!(u.sample(&mut rng, &[]), vec![0]);
        assert_eq!(w.sample(&mut rng, &[2.5]), vec![0]);
    }

    #[test]
    fn weighted_never_selects_zero_weight_clients() {
        let s = ClientSampler::weighted(4, 2);
        let w = vec![0.5, 0.0, 0.25, 0.25];
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            for i in s.sample(&mut rng, &w) {
                assert_ne!(i, 1, "sampled a zero-weight client");
            }
        }
    }

    #[test]
    #[should_panic(expected = "one weight per client")]
    fn weighted_rejects_mismatched_weight_vector() {
        // a weights vector longer than the population used to yield
        // client ids beyond the registry
        let s = ClientSampler::weighted(3, 2);
        let mut rng = Rng::new(6);
        s.sample(&mut rng, &[1.0, 1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "cohort 5 > population 3")]
    fn weighted_rejects_cohort_beyond_population() {
        ClientSampler::weighted(3, 5);
    }

    #[test]
    fn uniform_large_population_stays_in_range_and_distinct() {
        // exercises choose_k's Floyd's path through the sampler API
        let n = Rng::CHOOSE_K_DENSE_MAX * 8;
        let s = ClientSampler::uniform(n, 16);
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let mut c = s.sample(&mut rng, &[]);
            assert!(c.iter().all(|&i| i < n));
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), 16);
        }
    }
}
