//! Weighted aggregation of client contributions (paper §3 step 4).
//!
//! `Δw = Σ_{i∈S'} p_i g_i / Σ_{i∈S'} p_i` — the same weighted mean used
//! for client-side gradients in SplitFed/FedLite and for model deltas in
//! FedAvg. With fault injection, `S'` is the *surviving* subset of the
//! sampled cohort `S`: dropped/evicted clients are never `add`ed, and
//! [`WeightedAggregator::finish`] dividing by the accumulated weight *is*
//! the renormalization of the `p_i` over survivors. [`SurvivorSet`]
//! tracks the sampled-vs-survived bookkeeping and exposes the
//! renormalized weights for assertions and logs.
//!
//! With untrusted clients (PR 9), the weighted mean is itself an attack
//! surface: a single scaled or sign-flipped update moves the mean
//! arbitrarily far. [`RobustAggregator`] offers the two classic
//! order-statistic alternatives — coordinate-wise trimmed mean and
//! coordinate-wise median — behind the same accumulator interface, and
//! [`UpdateAggregator`] dispatches on the run's
//! [`AggregationRule`](crate::config::AggregationRule) so trainers stay
//! rule-agnostic. Robust rules buffer survivor updates in cohort-slot
//! order (`merge` concatenates in shard order ≡ the unsharded slot
//! order), so records stay bit-identical at any worker/shard count.

use crate::config::AggregationRule;
use crate::tensor::TensorList;

/// Online weighted-mean accumulator over tensor lists.
pub struct WeightedAggregator {
    acc: Option<TensorList>,
    total_weight: f64,
}

impl WeightedAggregator {
    pub fn new() -> Self {
        WeightedAggregator { acc: None, total_weight: 0.0 }
    }

    /// Add one client's contribution with weight `p_i >= 0`. A weight of
    /// exactly zero (an empty-shard client) contributes nothing to the
    /// mean but is tolerated; the zero-total-mass case is handled in
    /// [`WeightedAggregator::finish`].
    pub fn add(&mut self, contribution: &TensorList, weight: f64) {
        assert!(
            weight >= 0.0 && weight.is_finite(),
            "negative or non-finite aggregation weight"
        );
        match &mut self.acc {
            None => {
                let mut first = contribution.clone();
                first.scale(weight as f32);
                self.acc = Some(first);
            }
            Some(acc) => acc.axpy(weight as f32, contribution),
        }
        self.total_weight += weight;
    }

    pub fn count_weight(&self) -> f64 {
        self.total_weight
    }

    /// Fold another aggregator's partial sums into this one — equivalent
    /// to replaying all of `other`'s `add` calls after this aggregator's
    /// own. This is the combinator for sharded reductions (merge
    /// per-shard partials in a fixed shard order for a deterministic
    /// result); the round loop itself reduces per-client outputs
    /// directly in cohort-slot order via `add`.
    pub fn merge(&mut self, other: WeightedAggregator) {
        if let Some(o) = other.acc {
            match &mut self.acc {
                None => self.acc = Some(o),
                Some(acc) => acc.axpy(1.0, &o),
            }
        }
        self.total_weight += other.total_weight;
    }

    /// Normalized weighted mean; `None` if nothing was added — or if the
    /// accumulated weight mass is zero, where dividing would turn the
    /// aggregate into NaN/Inf and poison the optimizer step (the round
    /// engine treats that case as a degraded commit).
    pub fn finish(self) -> Option<TensorList> {
        let mut acc = self.acc?;
        if self.total_weight <= 0.0 {
            return None;
        }
        acc.scale((1.0 / self.total_weight) as f32);
        Some(acc)
    }
}

impl Default for WeightedAggregator {
    fn default() -> Self {
        Self::new()
    }
}

/// Scale one logical update (possibly spanning several tensor lists)
/// down to the given joint L2-norm bound; returns `true` if anything was
/// scaled (the `clipped_updates` defense meter). The squared norm
/// accumulates in f64 in list/tensor/element order — one fixed sequence,
/// so the clipped bits are identical at any worker/shard count.
pub fn clip_to_norm(lists: &mut [&mut TensorList], max_norm: f64) -> bool {
    debug_assert!(max_norm > 0.0, "clipping needs a positive bound");
    let mut sq = 0.0f64;
    for l in lists.iter() {
        for t in &l.tensors {
            for v in t.data() {
                sq += (*v as f64) * (*v as f64);
            }
        }
    }
    let norm = sq.sqrt();
    if !(norm > max_norm) {
        return false;
    }
    let s = (max_norm / norm) as f32;
    for l in lists.iter_mut() {
        l.scale(s);
    }
    true
}

/// Order-statistic aggregation over buffered survivor updates.
///
/// Robust rules are *unweighted*: the defense point is that no single
/// client — whatever its sample count claims — can dominate the
/// statistic, so `p_i` only gates admission (zero-weight survivors are
/// excluded, as they are from the weighted mean). Updates are buffered
/// in the order they are added; every per-coordinate reduction sorts
/// first, so the result is independent of that order, but the buffer
/// order is kept deterministic anyway (slot order, shard merges
/// concatenate) to keep the structure auditable.
pub struct RobustAggregator {
    rule: AggregationRule,
    updates: Vec<TensorList>,
}

impl RobustAggregator {
    pub fn new(rule: AggregationRule) -> Self {
        RobustAggregator { rule, updates: Vec::new() }
    }

    /// Buffer one survivor's update. Zero-weight contributions carry no
    /// aggregation mass under any rule and are skipped, which keeps the
    /// all-zero-mass degraded-commit path identical to the mean's.
    pub fn add(&mut self, contribution: &TensorList, weight: f64) {
        assert!(
            weight >= 0.0 && weight.is_finite(),
            "negative or non-finite aggregation weight"
        );
        if weight == 0.0 {
            return;
        }
        if let Some(first) = self.updates.first() {
            assert_eq!(
                first.numel(),
                contribution.numel(),
                "robust aggregation needs congruent updates"
            );
        }
        self.updates.push(contribution.clone());
    }

    pub fn count(&self) -> usize {
        self.updates.len()
    }

    /// Append another shard's buffered updates after this one — shard
    /// partials are filled in slot order and merged in shard order, so
    /// the concatenation reproduces the unsharded buffer exactly.
    pub fn merge(&mut self, other: RobustAggregator) {
        assert_eq!(self.rule, other.rule, "cannot merge across rules");
        self.updates.extend(other.updates);
    }

    /// How many values are trimmed from *each* tail for `n` updates: a
    /// quarter of the cohort per side, capped so at least one value
    /// always remains. `n < 4` trims nothing (plain unweighted mean).
    pub fn trim_k(n: usize) -> usize {
        let k = n / 4;
        if 2 * k >= n { (n - 1) / 2 } else { k }
    }

    /// Reduce the buffer coordinate-wise; `None` if nothing was admitted
    /// (every survivor rejected or zero-weight ⇒ degraded commit, the
    /// same contract as [`WeightedAggregator::finish`]).
    pub fn finish(self) -> Option<TensorList> {
        let first = self.updates.first()?;
        let n = self.updates.len();
        let mut out = first.zeros_like();
        let mut col = vec![0.0f32; n];
        for t in 0..out.tensors.len() {
            let dst = out.tensors[t].data_mut();
            for j in 0..dst.len() {
                for (i, u) in self.updates.iter().enumerate() {
                    col[i] = u.tensors[t].data()[j];
                }
                // total order on f32 bits: deterministic for every input
                col.sort_unstable_by(|a, b| a.total_cmp(b));
                dst[j] = match self.rule {
                    AggregationRule::Mean => {
                        unreachable!("mean dispatches to WeightedAggregator")
                    }
                    AggregationRule::Trimmed => {
                        let k = Self::trim_k(n);
                        let kept = &col[k..n - k];
                        let sum: f32 = kept.iter().sum();
                        sum / kept.len() as f32
                    }
                    AggregationRule::Median => {
                        let m = n / 2;
                        if n % 2 == 1 {
                            col[m]
                        } else {
                            (col[m - 1] + col[m]) * 0.5
                        }
                    }
                };
            }
        }
        Some(out)
    }
}

/// The accumulator trainers actually hold: dispatches on the run's
/// `--aggregation` rule. `Mean` delegates to [`WeightedAggregator`]
/// bit-for-bit, so honest runs under the default rule reproduce
/// pre-defense records exactly.
pub enum UpdateAggregator {
    Mean(WeightedAggregator),
    Robust(RobustAggregator),
}

impl UpdateAggregator {
    pub fn new(rule: AggregationRule) -> Self {
        match rule {
            AggregationRule::Mean => UpdateAggregator::Mean(WeightedAggregator::new()),
            r => UpdateAggregator::Robust(RobustAggregator::new(r)),
        }
    }

    pub fn add(&mut self, contribution: &TensorList, weight: f64) {
        match self {
            UpdateAggregator::Mean(a) => a.add(contribution, weight),
            UpdateAggregator::Robust(a) => a.add(contribution, weight),
        }
    }

    pub fn merge(&mut self, other: UpdateAggregator) {
        match (self, other) {
            (UpdateAggregator::Mean(a), UpdateAggregator::Mean(b)) => a.merge(b),
            (UpdateAggregator::Robust(a), UpdateAggregator::Robust(b)) => a.merge(b),
            _ => panic!("cannot merge aggregators of different rules"),
        }
    }

    pub fn finish(self) -> Option<TensorList> {
        match self {
            UpdateAggregator::Mean(a) => a.finish(),
            UpdateAggregator::Robust(a) => a.finish(),
        }
    }
}

/// Sampled-vs-survived bookkeeping for one round attempt.
///
/// The trainers record every cohort slot exactly once — `survivor(p_i)`
/// or `dropped()` in cohort-slot order — and read back the counts for the
/// round record plus the renormalized survivor weights
/// `p_i / Σ_{j∈survivors} p_j` (which sum to 1 whenever anyone survived;
/// asserted in `rust/tests/faults.rs`).
#[derive(Clone, Debug, Default)]
pub struct SurvivorSet {
    weights: Vec<f64>,
    sampled: usize,
}

impl SurvivorSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a surviving client with aggregation weight `p_i >= 0`.
    /// Zero-weight survivors count toward `survived()` but carry no
    /// aggregation mass; when *all* survivors have zero weight the round
    /// engine commits degraded instead of renormalizing (NaN weights).
    pub fn survivor(&mut self, weight: f64) {
        assert!(
            weight >= 0.0 && weight.is_finite(),
            "negative or non-finite survivor weight"
        );
        self.weights.push(weight);
        self.sampled += 1;
    }

    /// Record a client that dropped out or was evicted.
    pub fn dropped(&mut self) {
        self.sampled += 1;
    }

    pub fn sampled(&self) -> usize {
        self.sampled
    }

    pub fn survived(&self) -> usize {
        self.weights.len()
    }

    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// Append another shard's bookkeeping after this one — equivalent to
    /// replaying `other`'s `survivor`/`dropped` calls in order. Both the
    /// counts (integer adds) and the weight list (concatenation) are
    /// exact, so merging per-shard partials in shard order reproduces the
    /// unsharded slot-order recording bit-for-bit.
    pub fn merge(&mut self, other: SurvivorSet) {
        self.weights.extend(other.weights);
        self.sampled += other.sampled;
    }

    /// Survivor weights renormalized over the surviving cohort; empty when
    /// nobody survived *or* the surviving weight mass is zero (no convex
    /// combination exists to renormalize into).
    pub fn normalized(&self) -> Vec<f64> {
        let total = self.total_weight();
        if total <= 0.0 {
            return Vec::new();
        }
        self.weights.iter().map(|w| w / total).collect()
    }
}

/// Weighted mean of scalars with the same normalization (losses/metrics).
pub struct ScalarAggregator {
    sum: f64,
    weight: f64,
}

impl ScalarAggregator {
    pub fn new() -> Self {
        ScalarAggregator { sum: 0.0, weight: 0.0 }
    }

    pub fn add(&mut self, v: f64, weight: f64) {
        self.sum += v * weight;
        self.weight += weight;
    }

    pub fn mean(&self) -> f64 {
        if self.weight > 0.0 {
            self.sum / self.weight
        } else {
            0.0
        }
    }

    /// Fold another scalar aggregator's partial sums into this one (see
    /// [`WeightedAggregator::merge`]).
    pub fn merge(&mut self, other: ScalarAggregator) {
        self.sum += other.sum;
        self.weight += other.weight;
    }
}

impl Default for ScalarAggregator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tl(vals: &[f32]) -> TensorList {
        TensorList::new(
            vec!["t".into()],
            vec![Tensor::from_vec(&[vals.len()], vals.to_vec())],
        )
    }

    #[test]
    fn weighted_mean_exact() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[1.0, 0.0]), 1.0);
        agg.add(&tl(&[4.0, 3.0]), 3.0);
        let out = agg.finish().unwrap();
        // (1*1 + 4*3)/4 = 3.25 ; (0*1 + 3*3)/4 = 2.25
        assert_eq!(out.tensors[0].data(), &[3.25, 2.25]);
    }

    #[test]
    fn single_contribution_is_identity() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[2.0, -1.0]), 0.123);
        let out = agg.finish().unwrap();
        let d = out.tensors[0].data();
        assert!((d[0] - 2.0).abs() < 1e-6 && (d[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn empty_returns_none() {
        assert!(WeightedAggregator::new().finish().is_none());
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn negative_weight_rejected() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[1.0]), -0.1);
    }

    #[test]
    fn zero_total_weight_finishes_none() {
        // a cohort of empty-shard clients must not renormalize into NaN
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[1.0, 2.0]), 0.0);
        agg.add(&tl(&[3.0, 4.0]), 0.0);
        assert_eq!(agg.count_weight(), 0.0);
        assert!(agg.finish().is_none(), "zero mass has no mean");
    }

    #[test]
    fn zero_weight_contributions_are_ignored_in_the_mean() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[100.0, 100.0]), 0.0);
        agg.add(&tl(&[2.0, -4.0]), 0.5);
        let out = agg.finish().unwrap();
        assert_eq!(out.tensors[0].data(), &[2.0, -4.0]);
    }

    #[test]
    fn survivor_set_zero_mass_normalizes_to_empty() {
        let mut s = SurvivorSet::new();
        s.survivor(0.0);
        s.survivor(0.0);
        assert_eq!(s.survived(), 2);
        assert_eq!(s.total_weight(), 0.0);
        assert!(s.normalized().is_empty(), "no convex combination exists");
    }

    #[test]
    fn scalar_aggregator_mean() {
        let mut s = ScalarAggregator::new();
        s.add(2.0, 1.0);
        s.add(6.0, 1.0);
        assert_eq!(s.mean(), 4.0);
        assert_eq!(ScalarAggregator::new().mean(), 0.0);
    }

    #[test]
    fn merge_equals_sequential_adds() {
        // exactly-representable values: merged partials must equal the
        // sequential reduction bit-for-bit
        let parts: [(&[f32], f64); 4] =
            [(&[1.0, 2.0], 1.0), (&[3.0, -4.0], 2.0), (&[0.5, 8.0], 1.0), (&[-2.0, 1.0], 4.0)];
        let mut seq = WeightedAggregator::new();
        for (v, w) in parts {
            seq.add(&tl(v), w);
        }
        let mut left = WeightedAggregator::new();
        left.add(&tl(parts[0].0), parts[0].1);
        left.add(&tl(parts[1].0), parts[1].1);
        let mut right = WeightedAggregator::new();
        right.add(&tl(parts[2].0), parts[2].1);
        right.add(&tl(parts[3].0), parts[3].1);
        left.merge(right);
        assert_eq!(left.count_weight(), 8.0);
        assert_eq!(
            seq.finish().unwrap().tensors[0].data(),
            left.finish().unwrap().tensors[0].data()
        );
    }

    #[test]
    fn merge_with_empty_partials() {
        let mut a = WeightedAggregator::new();
        a.merge(WeightedAggregator::new());
        assert!(a.finish().is_none());
        let mut b = WeightedAggregator::new();
        b.add(&tl(&[2.0]), 1.0);
        let mut empty = WeightedAggregator::new();
        empty.merge(b);
        assert_eq!(empty.finish().unwrap().tensors[0].data(), &[2.0]);
    }

    #[test]
    fn scalar_merge() {
        let mut a = ScalarAggregator::new();
        a.add(2.0, 1.0);
        let mut b = ScalarAggregator::new();
        b.add(6.0, 3.0);
        a.merge(b);
        assert_eq!(a.mean(), 5.0);
        let mut c = ScalarAggregator::new();
        c.merge(ScalarAggregator::new());
        assert_eq!(c.mean(), 0.0);
    }

    #[test]
    fn survivor_set_counts_and_normalization() {
        let mut s = SurvivorSet::new();
        s.survivor(0.2);
        s.dropped();
        s.survivor(0.6);
        s.dropped();
        assert_eq!(s.sampled(), 4);
        assert_eq!(s.survived(), 2);
        assert!((s.total_weight() - 0.8).abs() < 1e-12);
        let norm = s.normalized();
        assert!((norm.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!((norm[0] - 0.25).abs() < 1e-12);
        assert!((norm[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn survivor_set_nobody_survived() {
        let mut s = SurvivorSet::new();
        s.dropped();
        s.dropped();
        assert_eq!(s.sampled(), 2);
        assert_eq!(s.survived(), 0);
        assert!(s.normalized().is_empty());
    }

    #[test]
    fn survivor_normalization_matches_aggregator_mean() {
        // aggregating survivors through WeightedAggregator equals the
        // explicit renormalized-weight combination
        let parts: [(&[f32], f64); 3] =
            [(&[1.0, 2.0], 0.5), (&[3.0, -1.0], 0.25), (&[0.0, 4.0], 0.75)];
        let mut agg = WeightedAggregator::new();
        let mut set = SurvivorSet::new();
        set.dropped(); // a dropped client contributes to neither
        for (v, w) in parts {
            agg.add(&tl(v), w);
            set.survivor(w);
        }
        let out = agg.finish().unwrap();
        let norm = set.normalized();
        for j in 0..2 {
            let manual: f64 = parts
                .iter()
                .zip(&norm)
                .map(|((v, _), p)| v[j] as f64 * p)
                .sum();
            assert!((out.tensors[0].data()[j] as f64 - manual).abs() < 1e-6);
        }
    }

    #[test]
    fn clip_to_norm_scales_only_over_bound() {
        // ‖(3, 4)‖ = 5 > 2.5 → scaled by exactly 0.5
        let mut a = tl(&[3.0, 4.0]);
        assert!(clip_to_norm(&mut [&mut a], 2.5));
        assert_eq!(a.tensors[0].data(), &[1.5, 2.0]);
        // already inside the bound: untouched, not counted
        let mut b = tl(&[0.3, 0.4]);
        assert!(!clip_to_norm(&mut [&mut b], 2.5));
        assert_eq!(b.tensors[0].data(), &[0.3, 0.4]);
        // the bound is joint across lists
        let (mut c, mut d) = (tl(&[3.0]), tl(&[4.0]));
        assert!(clip_to_norm(&mut [&mut c, &mut d], 2.5));
        assert_eq!(c.tensors[0].data(), &[1.5]);
        assert_eq!(d.tensors[0].data(), &[2.0]);
    }

    #[test]
    fn trim_k_schedule() {
        // n < 4 trims nothing; n/4 per side otherwise; never empties
        for (n, k) in [(1, 0), (2, 0), (3, 0), (4, 1), (7, 1), (8, 2), (12, 3)] {
            assert_eq!(RobustAggregator::trim_k(n), k, "n = {n}");
            assert!(n - 2 * RobustAggregator::trim_k(n) >= 1);
        }
    }

    #[test]
    fn trimmed_mean_discards_outliers() {
        let mut agg = RobustAggregator::new(AggregationRule::Trimmed);
        // one byzantine scaled update among four honest-ish ones
        for v in [1.0f32, 2.0, 3.0, 1000.0] {
            agg.add(&tl(&[v]), 1.0);
        }
        // k = 1 per side: keep {2.0, 3.0} -> 2.5
        assert_eq!(agg.finish().unwrap().tensors[0].data(), &[2.5]);
    }

    #[test]
    fn median_odd_and_even() {
        let mut odd = RobustAggregator::new(AggregationRule::Median);
        for v in [5.0f32, -100.0, 1.0] {
            odd.add(&tl(&[v]), 1.0);
        }
        assert_eq!(odd.finish().unwrap().tensors[0].data(), &[1.0]);
        let mut even = RobustAggregator::new(AggregationRule::Median);
        for v in [4.0f32, 1.0, 2.0, 1000.0] {
            even.add(&tl(&[v]), 1.0);
        }
        assert_eq!(even.finish().unwrap().tensors[0].data(), &[3.0]);
    }

    #[test]
    fn robust_rules_ignore_weights() {
        // a huge claimed weight must not move the median
        let mut agg = RobustAggregator::new(AggregationRule::Median);
        agg.add(&tl(&[0.0]), 1.0);
        agg.add(&tl(&[1.0]), 1.0);
        agg.add(&tl(&[1000.0]), 1e9);
        assert_eq!(agg.finish().unwrap().tensors[0].data(), &[1.0]);
    }

    #[test]
    fn robust_empty_and_zero_mass_finish_none() {
        // satellite: a defense rejecting every survivor must surface the
        // same degraded-commit signal as the zero-mass weighted mean
        assert!(RobustAggregator::new(AggregationRule::Median).finish().is_none());
        let mut agg = RobustAggregator::new(AggregationRule::Trimmed);
        agg.add(&tl(&[7.0]), 0.0);
        assert_eq!(agg.count(), 0);
        assert!(agg.finish().is_none());
    }

    #[test]
    fn robust_merge_equals_sequential_adds() {
        let parts: [&[f32]; 5] = [&[1.0, -2.0], &[3.0, 0.5], &[-9.0, 4.0], &[2.0, 2.0], &[0.0, 1.0]];
        for rule in [AggregationRule::Trimmed, AggregationRule::Median] {
            let mut seq = RobustAggregator::new(rule);
            for v in parts {
                seq.add(&tl(v), 1.0);
            }
            let mut left = RobustAggregator::new(rule);
            let mut right = RobustAggregator::new(rule);
            for v in &parts[..2] {
                left.add(&tl(v), 1.0);
            }
            for v in &parts[2..] {
                right.add(&tl(v), 1.0);
            }
            left.merge(right);
            assert_eq!(
                seq.finish().unwrap().tensors[0].data(),
                left.finish().unwrap().tensors[0].data(),
                "{}", rule.name()
            );
        }
    }

    #[test]
    fn update_aggregator_mean_delegates_bit_exactly() {
        let parts: [(&[f32], f64); 3] =
            [(&[1.0, 2.0], 0.25), (&[3.0, -4.0], 0.5), (&[0.5, 8.0], 0.25)];
        let mut plain = WeightedAggregator::new();
        let mut dispatched = UpdateAggregator::new(AggregationRule::Mean);
        for (v, w) in parts {
            plain.add(&tl(v), w);
            dispatched.add(&tl(v), w);
        }
        let a = plain.finish().unwrap();
        let b = dispatched.finish().unwrap();
        for (x, y) in a.tensors[0].data().iter().zip(b.tensors[0].data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn order_invariance() {
        let parts: [(&[f32], f64); 3] =
            [(&[1.0, 2.0], 0.2), (&[3.0, 4.0], 0.5), (&[5.0, 6.0], 0.3)];
        let mut a = WeightedAggregator::new();
        for (v, w) in parts {
            a.add(&tl(v), w);
        }
        let mut b = WeightedAggregator::new();
        for (v, w) in parts.iter().rev() {
            b.add(&tl(v), *w);
        }
        let ra = a.finish().unwrap();
        let rb = b.finish().unwrap();
        for (x, y) in ra.tensors[0].data().iter().zip(rb.tensors[0].data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}
