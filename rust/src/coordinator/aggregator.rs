//! Weighted aggregation of client contributions (paper §3 step 4).
//!
//! `Δw = Σ_{i∈S} p_i g_i / Σ_{i∈S} p_i` — the same weighted mean used for
//! client-side gradients in SplitFed/FedLite and for model deltas in
//! FedAvg.

use crate::tensor::TensorList;

/// Online weighted-mean accumulator over tensor lists.
pub struct WeightedAggregator {
    acc: Option<TensorList>,
    total_weight: f64,
}

impl WeightedAggregator {
    pub fn new() -> Self {
        WeightedAggregator { acc: None, total_weight: 0.0 }
    }

    /// Add one client's contribution with weight `p_i > 0`.
    pub fn add(&mut self, contribution: &TensorList, weight: f64) {
        assert!(weight > 0.0, "non-positive aggregation weight");
        match &mut self.acc {
            None => {
                let mut first = contribution.clone();
                first.scale(weight as f32);
                self.acc = Some(first);
            }
            Some(acc) => acc.axpy(weight as f32, contribution),
        }
        self.total_weight += weight;
    }

    pub fn count_weight(&self) -> f64 {
        self.total_weight
    }

    /// Normalized weighted mean; `None` if nothing was added.
    pub fn finish(self) -> Option<TensorList> {
        let mut acc = self.acc?;
        acc.scale((1.0 / self.total_weight) as f32);
        Some(acc)
    }
}

impl Default for WeightedAggregator {
    fn default() -> Self {
        Self::new()
    }
}

/// Weighted mean of scalars with the same normalization (losses/metrics).
pub struct ScalarAggregator {
    sum: f64,
    weight: f64,
}

impl ScalarAggregator {
    pub fn new() -> Self {
        ScalarAggregator { sum: 0.0, weight: 0.0 }
    }

    pub fn add(&mut self, v: f64, weight: f64) {
        self.sum += v * weight;
        self.weight += weight;
    }

    pub fn mean(&self) -> f64 {
        if self.weight > 0.0 {
            self.sum / self.weight
        } else {
            0.0
        }
    }
}

impl Default for ScalarAggregator {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tl(vals: &[f32]) -> TensorList {
        TensorList::new(
            vec!["t".into()],
            vec![Tensor::from_vec(&[vals.len()], vals.to_vec())],
        )
    }

    #[test]
    fn weighted_mean_exact() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[1.0, 0.0]), 1.0);
        agg.add(&tl(&[4.0, 3.0]), 3.0);
        let out = agg.finish().unwrap();
        // (1*1 + 4*3)/4 = 3.25 ; (0*1 + 3*3)/4 = 2.25
        assert_eq!(out.tensors[0].data(), &[3.25, 2.25]);
    }

    #[test]
    fn single_contribution_is_identity() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[2.0, -1.0]), 0.123);
        let out = agg.finish().unwrap();
        let d = out.tensors[0].data();
        assert!((d[0] - 2.0).abs() < 1e-6 && (d[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn empty_returns_none() {
        assert!(WeightedAggregator::new().finish().is_none());
    }

    #[test]
    #[should_panic(expected = "non-positive")]
    fn zero_weight_rejected() {
        let mut agg = WeightedAggregator::new();
        agg.add(&tl(&[1.0]), 0.0);
    }

    #[test]
    fn scalar_aggregator_mean() {
        let mut s = ScalarAggregator::new();
        s.add(2.0, 1.0);
        s.add(6.0, 1.0);
        assert_eq!(s.mean(), 4.0);
        assert_eq!(ScalarAggregator::new().mean(), 0.0);
    }

    #[test]
    fn order_invariance() {
        let parts: [(&[f32], f64); 3] =
            [(&[1.0, 2.0], 0.2), (&[3.0, 4.0], 0.5), (&[5.0, 6.0], 0.3)];
        let mut a = WeightedAggregator::new();
        for (v, w) in parts {
            a.add(&tl(v), w);
        }
        let mut b = WeightedAggregator::new();
        for (v, w) in parts.iter().rev() {
            b.add(&tl(v), *w);
        }
        let ra = a.finish().unwrap();
        let rb = b.finish().unwrap();
        for (x, y) in ra.tensors[0].data().iter().zip(rb.tensors[0].data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}
