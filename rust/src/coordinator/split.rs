//! The SplitFed / FedLite round state machine (paper §3 + §4).
//!
//! Each round runs the explicit tick-based phase machine of
//! [`crate::coordinator::engine`]:
//!
//! * **Sampling** — pick the cohort (`ClientSampler`) and draw each
//!   client's deterministic fault schedule
//!   ([`crate::coordinator::faults::FaultConfig::plan`]);
//! * **Broadcast** — build the round's client-model broadcast message,
//!   shared read-only by the whole cohort;
//! * **ClientCompute** — fan the cohort across `cfg.workers` threads
//!   ([`crate::util::pool::scoped_parallel_map`]); one client's unit of
//!   work is [`client_step`]: broadcast download → `client_fwd` →
//!   (FedLite) quantize → metered wire round-trip (the server trains on
//!   the *reconstruction from the decoded bytes*) → `server_step` → grad
//!   download → `client_bwd` (gradient correction eq. (5) inside the
//!   artifact) → client-grad upload. Fault injection short-circuits this
//!   pipeline at the scheduled phase: bytes a client sent before failing
//!   stay metered, its gradients never leave the worker;
//! * **Aggregate** — reduce the partials in cohort-slot order; weights
//!   `p_i` renormalize over the *survivors* (the weighted mean divides by
//!   the surviving weight mass — see `aggregator::SurvivorSet`). If fewer
//!   than `min_survivors` clients survived, rewind to **Sampling** for a
//!   fresh attempt (bounded by `engine::MAX_SAMPLING_ATTEMPTS`) without
//!   touching the optimizers;
//! * **Commit** — one optimizer step per side on the survivor aggregate
//!   (skipped when nobody survived), then emit the round record with
//!   `cohort_sampled` / `cohort_survived` / `dropped_at_phase` /
//!   `round_attempts`.
//!
//! Per-client RNG streams (batches *and* fault schedules) are forked from
//! pure `(round, attempt, client)` keys and every reduction has a fixed
//! order, so round records are **bit-identical at any worker count**,
//! clean or faulty (`workers = 1` recovers the serial loop exactly;
//! enforced by `rust/tests/determinism.rs`), and a clean config
//! (`drop_prob = 0`) reproduces the pre-fault engine bit for bit
//! (`rust/tests/faults.rs`).
//!
//! Labels are *not* metered (the paper's cost model excludes them; in the
//! vertical-FL deployment the server owns labels — see DESIGN.md).

use std::sync::Arc;
use std::time::Instant;

use crate::comm::accounting::RoundBytes;
use crate::comm::message::{self, Message};
use crate::comm::StarNetwork;
use crate::config::{Algorithm, RunConfig};
use crate::coordinator::aggregator::{ScalarAggregator, SurvivorSet, WeightedAggregator};
use crate::coordinator::client::{assemble, draw_masks, InputSources};
use crate::coordinator::engine::{client_stream_key, sample_key, RoundDriver, RoundPhase};
use crate::coordinator::faults::{DropCounts, DropPhase, FaultConfig, FaultPlan};
use crate::coordinator::quantize::QuantizeBackend;
use crate::coordinator::sampler::ClientSampler;
use crate::coordinator::Trainer;
use crate::data::{Array, FederatedDataset};
use crate::metrics::{RoundRecord, RunLog, TaskMetric};
use crate::models::ModelSpec;
use crate::optim::Optimizer;
use crate::runtime::{ArtifactMeta, Runtime};
use crate::tensor::{Tensor, TensorList};
use crate::util::logging::{CsvWriter, JsonlWriter};
use crate::util::pool::scoped_parallel_map;
use crate::util::rng::Rng;

/// Split-learning trainer (SplitFed when `quantizer` is None).
pub struct SplitTrainer {
    cfg: RunConfig,
    rt: Arc<Runtime>,
    data: Arc<dyn FederatedDataset>,
    spec: ModelSpec,
    wc: TensorList,
    ws: TensorList,
    opt_c: Box<dyn Optimizer>,
    opt_s: Box<dyn Optimizer>,
    net: StarNetwork,
    sampler: ClientSampler,
    quantizer: Option<QuantizeBackend>,
    metric: TaskMetric,
    faults: FaultConfig,
    rng: Rng,
    csv: Option<CsvWriter>,
    jsonl: Option<JsonlWriter>,
}

/// What one client contributes to a round: produced on a worker thread by
/// [`client_step`], reduced on the coordinator thread in cohort-slot
/// order.
pub struct ClientRoundOutput {
    /// Aggregation weight p_i (dataset share), floored at 1e-12.
    pub weight: f64,
    pub loss: f64,
    /// Raw metric sums in manifest order.
    pub metric_sums: Vec<f64>,
    /// Relative quantization error (0 for SplitFed).
    pub quant_rel_err: f64,
    pub wc_grads: TensorList,
    pub ws_grads: TensorList,
    /// This client's metered transfers (merged after the barrier). Bytes
    /// sent before a mid-round failure are included — they crossed the
    /// wire.
    pub bytes: RoundBytes,
    /// Where the client's contribution was lost, if anywhere. Dropped and
    /// evicted clients carry empty gradient lists and are excluded from
    /// every aggregate.
    pub dropped: Option<DropPhase>,
    /// Simulated straggler compute delay (feeds the round-time estimate).
    pub delay_seconds: f64,
}

impl ClientRoundOutput {
    /// A failed client's partial contribution: the bytes it sent, nothing
    /// else.
    fn failed(
        phase: DropPhase,
        weight: f64,
        bytes: RoundBytes,
        delay_seconds: f64,
    ) -> ClientRoundOutput {
        ClientRoundOutput {
            weight,
            loss: 0.0,
            metric_sums: Vec::new(),
            quant_rel_err: 0.0,
            wc_grads: TensorList::new(Vec::new(), Vec::new()),
            ws_grads: TensorList::new(Vec::new(), Vec::new()),
            bytes,
            dropped: Some(phase),
            delay_seconds,
        }
    }
}

/// Immutable view of the round state shared (read-only) by the cohort
/// workers. Everything here is `Sync`; per-client mutability lives in the
/// worker's own `Rng` and locals.
struct ClientStepCtx<'a> {
    rt: &'a Runtime,
    data: &'a dyn FederatedDataset,
    net: &'a StarNetwork,
    quantizer: Option<&'a QuantizeBackend>,
    spec: &'a ModelSpec,
    variant: &'a str,
    fwd: &'a ArtifactMeta,
    step: &'a ArtifactMeta,
    bwd: &'a ArtifactMeta,
    wc: &'a TensorList,
    ws: &'a TensorList,
    /// The round's model broadcast, built once and shared: the payload is
    /// identical for every client, and `StarNetwork::download` only needs
    /// `&Message`.
    broadcast: &'a Message,
    /// Gradient-correction strength (0 when not quantizing).
    lambda: f32,
    dropout_client: f64,
    dropout_server: f64,
    round: u32,
}

/// One client's full round pipeline: broadcast → `client_fwd` → quantize →
/// metered wire round-trip → `server_step` → `client_bwd` → grad upload.
///
/// `plan` injects this client's scheduled faults: the pipeline stops at
/// the scheduled drop phase (bytes sent so far stay metered, nothing else
/// is produced), and an evicted straggler runs to completion — all its
/// bytes cross the wire — but returns a discarded contribution.
fn client_step(
    ctx: &ClientStepCtx<'_>,
    ci: usize,
    crng: &mut Rng,
    plan: &FaultPlan,
) -> anyhow::Result<ClientRoundOutput> {
    let mut up_bytes = 0usize;
    let mut down_bytes = 0usize;
    let mut up_msgs = 0u64;
    let mut down_msgs = 0u64;
    let act_b = ctx.spec.act_batch;
    let d = ctx.spec.cut_dim;
    let nmetrics = ctx.spec.metrics.len();
    let weight = ctx.data.client_weight(ci).max(1e-12);

    // 0. model broadcast (downlink)
    let (_, n) = ctx.net.download(ci, ctx.round, ctx.broadcast)?;
    down_bytes += n;
    down_msgs += 1;

    // 1. client forward
    let batch = ctx.data.train_batch(ci, ctx.spec.batch, crng);
    let masks = draw_masks(
        &[ctx.fwd, ctx.step, ctx.bwd],
        ctx.dropout_client,
        ctx.dropout_server,
        crng,
    );
    let src = InputSources {
        wc: Some(ctx.wc),
        batch: Some(&batch),
        masks: Some(&masks),
        ..Default::default()
    };
    let z_arr = ctx
        .rt
        .run(ctx.variant, "client_fwd", &assemble(ctx.fwd, &src)?)?
        .remove(0);
    let z = z_arr
        .as_f32()
        .ok_or_else(|| anyhow::anyhow!("z dtype"))?
        .to_vec();
    if plan.drop_at == Some(DropPhase::AfterFwd) {
        // vanished before uploading: only the broadcast crossed the wire
        return Ok(ClientRoundOutput::failed(
            DropPhase::AfterFwd,
            weight,
            RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
            plan.delay_seconds,
        ));
    }

    // 2. upload: quantized (FedLite) or raw (SplitFed); the server
    //    trains on what came off the wire.
    let (z_tilde_server, quant_rel_err) = match ctx.quantizer {
        Some(qz) => {
            let out = qz.quantize(&z, act_b, crng)?;
            let msg = Message::from_pq(&qz.config, act_b, d, &out.codebooks, &out.codes);
            let (decoded, n) = ctx.net.upload(ci, ctx.round, &msg)?;
            up_bytes += n;
            up_msgs += 1;
            let codes = decoded.unpack_codes()?;
            let cbs = match &decoded {
                Message::QuantizedUpload { codebooks, .. } => codebooks.clone(),
                _ => anyhow::bail!("wrong upload variant"),
            };
            let native = crate::quantizer::GroupedPq::new(qz.config, d)?;
            let rec = native.reconstruct(&cbs, &codes, act_b);
            debug_assert_eq!(rec, out.z_tilde, "wire changed z~");
            (rec, out.relative_error(&z))
        }
        None => {
            let msg = Message::ActivationUpload { z: z.clone(), b: act_b, d };
            let (decoded, n) = ctx.net.upload(ci, ctx.round, &msg)?;
            up_bytes += n;
            up_msgs += 1;
            match decoded {
                Message::ActivationUpload { z, .. } => (z, 0.0),
                _ => anyhow::bail!("wrong upload variant"),
            }
        }
    };
    if plan.drop_at == Some(DropPhase::AfterUpload) {
        // the activation upload landed (and is metered); the client is
        // gone, so the server never trains on it
        return Ok(ClientRoundOutput::failed(
            DropPhase::AfterUpload,
            weight,
            RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
            plan.delay_seconds,
        ));
    }
    let z_tilde = Array::f32(&[act_b, d], z_tilde_server);

    // 3. server update
    let src = InputSources {
        ws: Some(ctx.ws),
        batch: Some(&batch),
        masks: Some(&masks),
        z_tilde: Some(&z_tilde),
        ..Default::default()
    };
    let outs = ctx
        .rt
        .run(ctx.variant, "server_step", &assemble(ctx.step, &src)?)?;
    let loss = scalar(&outs[0])? as f64;
    let mut metric_sums = vec![0.0f64; nmetrics];
    for (k, s) in metric_sums.iter_mut().enumerate() {
        *s = scalar(&outs[1 + k])? as f64;
    }
    let grad_z = outs[1 + nmetrics].clone();
    let ws_grads = arrays_to_tensors(&outs[2 + nmetrics..], ctx.ws)?;

    // 4. gradient download
    let gz_vec = grad_z
        .as_f32()
        .ok_or_else(|| anyhow::anyhow!("grad_z dtype"))?
        .to_vec();
    let gmsg = Message::GradDownload { grad: gz_vec, b: act_b, d };
    let (decoded, n) = ctx.net.download(ci, ctx.round, &gmsg)?;
    down_bytes += n;
    down_msgs += 1;
    let grad_wire = match decoded {
        Message::GradDownload { grad, .. } => Array::f32(&[act_b, d], grad),
        _ => anyhow::bail!("wrong download variant"),
    };
    if plan.drop_at == Some(DropPhase::BeforeGradUpload) {
        // uplink activations and the grad download are metered; the
        // client-side gradient never comes back
        return Ok(ClientRoundOutput::failed(
            DropPhase::BeforeGradUpload,
            weight,
            RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
            plan.delay_seconds,
        ));
    }

    // 5. client backward (gradient correction inside the artifact)
    let src = InputSources {
        wc: Some(ctx.wc),
        batch: Some(&batch),
        masks: Some(&masks),
        z_tilde: Some(&z_tilde),
        grad_z: Some(&grad_wire),
        lambda: Some(ctx.lambda),
        ..Default::default()
    };
    let bwd = ctx
        .rt
        .run(ctx.variant, "client_bwd", &assemble(ctx.bwd, &src)?)?;
    let wc_grads = arrays_to_tensors(&bwd[..bwd.len() - 1], ctx.wc)?;

    // 6. client-side grad sync (uplink)
    let cmsg = Message::ClientGrads { grads: message::tensors_to_payload(&wc_grads) };
    let (decoded, n) = ctx.net.upload(ci, ctx.round, &cmsg)?;
    up_bytes += n;
    up_msgs += 1;
    let synced = match decoded {
        Message::ClientGrads { grads } => message::payload_to_tensors(
            &grads,
            &ctx.wc.tensors.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>(),
            &ctx.wc.names,
        ),
        _ => anyhow::bail!("wrong sync variant"),
    };

    let bytes = RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs);
    if plan.evicted {
        // straggler past the deadline: every message crossed the wire,
        // but the round committed without it
        return Ok(ClientRoundOutput::failed(
            DropPhase::Deadline,
            weight,
            bytes,
            plan.delay_seconds,
        ));
    }
    Ok(ClientRoundOutput {
        weight,
        loss,
        metric_sums,
        quant_rel_err,
        wc_grads: synced,
        ws_grads,
        bytes,
        dropped: None,
        delay_seconds: plan.delay_seconds,
    })
}

impl SplitTrainer {
    pub fn new(
        cfg: RunConfig,
        rt: Arc<Runtime>,
        data: Arc<dyn FederatedDataset>,
    ) -> anyhow::Result<Self> {
        let variant = cfg.variant();
        let spec = rt.manifest.variant(&variant)?.spec.clone();
        let rng = Rng::new(cfg.seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let quantizer = match cfg.algorithm {
            Algorithm::FedLite => Some(QuantizeBackend::new(
                cfg.quantizer,
                cfg.pq,
                spec.cut_dim,
                Arc::clone(&rt),
                &variant,
            )?),
            _ => None,
        };
        let (csv, jsonl) = open_logs(&cfg)?;
        Ok(SplitTrainer {
            sampler: ClientSampler::uniform(cfg.num_clients, cfg.clients_per_round),
            net: StarNetwork::with_defaults(cfg.num_clients),
            opt_c: crate::optim::build(&cfg.optimizer, cfg.client_lr)?,
            opt_s: crate::optim::build(&cfg.optimizer, cfg.server_lr)?,
            metric: TaskMetric::for_task(&cfg.task),
            faults: FaultConfig::from_run(&cfg),
            quantizer,
            spec,
            wc,
            ws,
            rng,
            data,
            rt,
            cfg,
            csv,
            jsonl,
        })
    }

    pub fn params(&self) -> (&TensorList, &TensorList) {
        (&self.wc, &self.ws)
    }

    pub fn set_params(&mut self, wc: TensorList, ws: TensorList) {
        self.wc = wc;
        self.ws = ws;
    }

    /// Evaluate the current model on `batches` held-out batches.
    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        let variant = self.cfg.variant();
        let meta = self.rt.manifest.artifact(&variant, "full_eval")?.clone();
        let mut loss = ScalarAggregator::new();
        let mut sums = vec![0.0f64; self.spec.metrics.len()];
        let mut examples = 0.0f64;
        let mut rng = self.rng.fork(0xE7A1);
        for _ in 0..batches {
            let batch = self.data.eval_batch(self.spec.eval_batch, &mut rng);
            let src = InputSources {
                wc: Some(&self.wc),
                ws: Some(&self.ws),
                batch: Some(&batch),
                ..Default::default()
            };
            let inputs = assemble(&meta, &src)?;
            let outs = self.rt.run(&variant, "full_eval", &inputs)?;
            loss.add(scalar(&outs[0])? as f64, 1.0);
            for (k, s) in sums.iter_mut().enumerate() {
                *s += scalar(&outs[1 + k])? as f64;
            }
            examples += self.spec.eval_batch as f64;
            if self.cfg.task == "so_nwp" {
                // token metrics carry their own denominator
            }
        }
        Ok((loss.mean(), self.metric.value(&sums, examples)))
    }

    /// One full round through the tick-based phase machine (see the
    /// module docs); returns the committed round record.
    fn round(&mut self, round: usize) -> anyhow::Result<RoundRecord> {
        let t0 = Instant::now();
        let variant = self.cfg.variant();
        let fwd_meta = self.rt.manifest.artifact(&variant, "client_fwd")?.clone();
        let step_meta = self.rt.manifest.artifact(&variant, "server_step")?.clone();
        let bwd_meta = self.rt.manifest.artifact(&variant, "client_bwd")?.clone();
        let nmetrics = self.spec.metrics.len();

        self.net.begin_round();
        let mut driver = RoundDriver::new();
        // carried across phases within one attempt
        let mut cohort: Vec<usize> = Vec::new();
        let mut plans: Vec<FaultPlan> = Vec::new();
        let mut broadcast: Option<Message> = None;
        let mut results: Vec<anyhow::Result<ClientRoundOutput>> = Vec::new();
        // carried across *attempts*: aborted attempts really used the
        // wire and the simulated clock, so bytes/time accumulate
        let mut round_bytes = RoundBytes::default();
        let mut sim_seconds = 0.0f64;
        // survivor aggregates of the attempt that commits
        let mut ws_agg = WeightedAggregator::new();
        let mut wc_agg = WeightedAggregator::new();
        let mut loss_agg = ScalarAggregator::new();
        let mut qerr_agg = ScalarAggregator::new();
        let mut metric_sums = vec![0.0f64; nmetrics];
        let mut examples = 0.0f64;
        let mut survivors = SurvivorSet::new();
        let mut drops = DropCounts::default();

        loop {
            match driver.phase() {
                RoundPhase::Sampling => {
                    let attempt = driver.attempt();
                    cohort = self.sampler.sample(
                        &mut self.rng.fork(sample_key(round as u64, attempt)),
                        &[],
                    );
                    plans = cohort
                        .iter()
                        .map(|&ci| {
                            self.faults.plan(&self.rng, round as u64, attempt, ci)
                        })
                        .collect();
                    driver.advance();
                }
                RoundPhase::Broadcast => {
                    // parameters can't change between attempts (aborts
                    // never touch the optimizers), so the payload is
                    // built once and re-sent on resampled attempts
                    if broadcast.is_none() {
                        broadcast = Some(Message::ModelBroadcast {
                            params: message::tensors_to_payload(&self.wc),
                        });
                    }
                    driver.advance();
                }
                RoundPhase::ClientCompute => {
                    // Per-client RNG streams use the same (round, client)
                    // fork keys as the original serial loop; `fork` never
                    // advances the root stream, so hoisting the forks out
                    // of the loop is behavior-preserving.
                    let attempt = driver.attempt();
                    let tasks: Vec<(usize, Rng, FaultPlan)> = cohort
                        .iter()
                        .zip(&plans)
                        .map(|(&ci, &plan)| {
                            let key =
                                client_stream_key(0xC11E, round as u64, ci, attempt);
                            (ci, self.rng.fork(key), plan)
                        })
                        .collect();
                    let ctx = ClientStepCtx {
                        rt: &*self.rt,
                        data: self.data.as_ref(),
                        net: &self.net,
                        quantizer: self.quantizer.as_ref(),
                        spec: &self.spec,
                        variant: &variant,
                        fwd: &fwd_meta,
                        step: &step_meta,
                        bwd: &bwd_meta,
                        wc: &self.wc,
                        ws: &self.ws,
                        broadcast: broadcast.as_ref().expect("broadcast built"),
                        lambda: if self.quantizer.is_some() {
                            self.cfg.lambda
                        } else {
                            0.0
                        },
                        dropout_client: self.cfg.dropout_client,
                        dropout_server: self.cfg.dropout_server,
                        round: round as u32,
                    };
                    // fan the cohort across the worker threads;
                    // collection is the round barrier
                    results = scoped_parallel_map(
                        self.cfg.resolved_workers(),
                        tasks,
                        |_slot, (ci, mut crng, plan)| {
                            client_step(&ctx, ci, &mut crng, &plan)
                        },
                    );
                    driver.advance();
                }
                RoundPhase::Aggregate => {
                    // reduce the partials in cohort-slot order: every
                    // accumulation below happens in the same order the
                    // serial loop used, so the records are bit-identical
                    // at any worker count
                    ws_agg = WeightedAggregator::new();
                    wc_agg = WeightedAggregator::new();
                    loss_agg = ScalarAggregator::new();
                    qerr_agg = ScalarAggregator::new();
                    metric_sums = vec![0.0f64; nmetrics];
                    examples = 0.0;
                    survivors = SurvivorSet::new();
                    drops = DropCounts::default();
                    let mut per_client: Vec<(usize, usize, f64)> =
                        Vec::with_capacity(cohort.len());
                    for result in std::mem::take(&mut results) {
                        let out = result?;
                        per_client.push((
                            out.bytes.up as usize,
                            out.bytes.down as usize,
                            out.delay_seconds,
                        ));
                        round_bytes.merge(&out.bytes);
                        match out.dropped {
                            Some(phase) => {
                                drops.add(phase);
                                survivors.dropped();
                            }
                            None => {
                                survivors.survivor(out.weight);
                                loss_agg.add(out.loss, out.weight);
                                for (k, s) in metric_sums.iter_mut().enumerate() {
                                    *s += out.metric_sums[k];
                                }
                                examples += self.spec.batch as f64;
                                ws_agg.add(&out.ws_grads, out.weight);
                                wc_agg.add(&out.wc_grads, out.weight);
                                qerr_agg.add(out.quant_rel_err, 1.0);
                            }
                        }
                    }
                    sim_seconds += self
                        .net
                        .estimate_round_time_with_delays(&per_client, self.faults.round_deadline);
                    // survivor weights renormalize to a convex combination
                    debug_assert!(
                        survivors.survived() == 0
                            || (survivors.normalized().iter().sum::<f64>() - 1.0).abs()
                                < 1e-9,
                        "survivor weights must renormalize to 1"
                    );
                    if self.faults.min_survivors > 0
                        && survivors.survived() < self.faults.min_survivors
                        && driver.resample()
                    {
                        // too few survivors: abort the attempt (its bytes
                        // stay metered) and resample a fresh cohort
                        // without touching the optimizers
                        continue;
                    }
                    driver.advance();
                }
                RoundPhase::Commit => break,
            }
        }

        // optimizer steps on the survivor-aggregated gradients (skipped
        // when nobody survived a degraded commit)
        if let Some(g) = ws_agg.finish() {
            self.opt_s.step(&mut self.ws, &g);
        }
        if let Some(g) = wc_agg.finish() {
            self.opt_c.step(&mut self.wc, &g);
        }
        anyhow::ensure!(self.wc.is_finite() && self.ws.is_finite(),
            "parameters diverged (NaN/Inf) at round {round}");

        // archive the meter's per-round delta (cumulative totals live
        // there too); the record reports the slot-order merged partials,
        // which must agree with the meter while all round traffic flows
        // through client_step — including aborted attempts
        let meter_delta = self.net.end_round();
        debug_assert_eq!(meter_delta, round_bytes, "meter vs merged partials");
        let mut rec = RoundRecord {
            round,
            train_loss: loss_agg.mean(),
            train_metric: self.metric.value(&metric_sums, examples),
            quant_error: qerr_agg.mean(),
            uplink_bytes: round_bytes.up,
            downlink_bytes: round_bytes.down,
            cumulative_uplink: self.net.totals().up,
            wall_seconds: t0.elapsed().as_secs_f64(),
            sim_comm_seconds: sim_seconds,
            cohort_sampled: cohort.len(),
            cohort_survived: survivors.survived(),
            dropped: drops,
            attempts: driver.attempt(),
            ..Default::default()
        };
        if self.cfg.eval_every > 0
            && (round % self.cfg.eval_every == self.cfg.eval_every - 1 || round == 0)
        {
            let (el, em) = self.evaluate(self.cfg.eval_batches)?;
            rec.eval_loss = Some(el);
            rec.eval_metric = Some(em);
        }
        Ok(rec)
    }
}

impl Trainer for SplitTrainer {
    fn run(&mut self) -> anyhow::Result<RunLog> {
        let mut log = RunLog::default();
        let algo = self.cfg.algorithm.name();
        for round in 0..self.cfg.rounds {
            let rec = self.round(round)?;
            if round == 0 || (round + 1) % 10 == 0 {
                log::info!(
                    "{algo} {} r{:>4}: loss={:.4} metric={:.4} upKB={:.1} qerr={:.3}",
                    self.cfg.task,
                    round,
                    rec.train_loss,
                    rec.train_metric,
                    rec.uplink_bytes as f64 / 1024.0,
                    rec.quant_error,
                );
            }
            write_round(&mut self.csv, &mut self.jsonl, &rec)?;
            log.push(rec);
        }
        if let Some(c) = &mut self.csv {
            c.flush()?;
        }
        if let Some(j) = &mut self.jsonl {
            j.flush()?;
        }
        Ok(log)
    }
}

// -- shared helpers (also used by fedavg.rs) ---------------------------------

pub fn scalar(a: &Array) -> anyhow::Result<f32> {
    a.as_f32()
        .and_then(|v| v.first().copied())
        .ok_or_else(|| anyhow::anyhow!("expected f32 scalar output"))
}

/// Convert artifact gradient outputs into a TensorList shaped like `like`.
pub fn arrays_to_tensors(arrs: &[Array], like: &TensorList) -> anyhow::Result<TensorList> {
    anyhow::ensure!(
        arrs.len() == like.len(),
        "got {} grads, expected {}",
        arrs.len(),
        like.len()
    );
    let tensors = arrs
        .iter()
        .zip(&like.tensors)
        .map(|(a, t)| {
            let data = a
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("gradient not f32"))?;
            anyhow::ensure!(a.shape() == t.shape(), "grad shape mismatch");
            Ok(Tensor::from_vec(t.shape(), data.to_vec()))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(TensorList::new(like.names.clone(), tensors))
}

pub(crate) fn open_logs(
    cfg: &RunConfig,
) -> anyhow::Result<(Option<CsvWriter>, Option<JsonlWriter>)> {
    if cfg.out_dir.is_empty() {
        return Ok((None, None));
    }
    let base = format!(
        "{}/{}_{}_{}", cfg.out_dir, cfg.task, cfg.algorithm.name(), cfg.seed
    );
    let csv = CsvWriter::create(
        format!("{base}.csv"),
        &[
            "round", "train_loss", "train_metric", "eval_loss", "eval_metric",
            "quant_error", "uplink_bytes", "downlink_bytes", "cumulative_uplink",
            "wall_seconds", "sim_comm_seconds", "cohort_sampled", "cohort_survived",
            "dropped_at_phase", "round_attempts",
        ],
    )?;
    let jsonl = JsonlWriter::create(format!("{base}.jsonl"))?;
    Ok((Some(csv), Some(jsonl)))
}

pub(crate) fn write_round(
    csv: &mut Option<CsvWriter>,
    jsonl: &mut Option<JsonlWriter>,
    rec: &RoundRecord,
) -> anyhow::Result<()> {
    if let Some(c) = csv {
        c.row(&[
            rec.round.to_string(),
            format!("{:.6}", rec.train_loss),
            format!("{:.6}", rec.train_metric),
            rec.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            rec.eval_metric.map(|v| format!("{v:.6}")).unwrap_or_default(),
            format!("{:.6}", rec.quant_error),
            rec.uplink_bytes.to_string(),
            rec.downlink_bytes.to_string(),
            rec.cumulative_uplink.to_string(),
            format!("{:.4}", rec.wall_seconds),
            format!("{:.4}", rec.sim_comm_seconds),
            rec.cohort_sampled.to_string(),
            rec.cohort_survived.to_string(),
            rec.dropped.summary(),
            rec.attempts.to_string(),
        ])?;
    }
    if let Some(j) = jsonl {
        j.record(&rec.to_json())?;
    }
    Ok(())
}
