//! The SplitFed / FedLite round state machine (paper §3 + §4).
//!
//! Per round:
//! 1. sample a cohort; broadcast the client-side model (downlink);
//! 2. **client forward** — `client_fwd` artifact per client;
//! 3. **FedLite only**: quantize the activations (native or Pallas/PJRT
//!    backend), serialize codebook+codewords through the metered wire, and
//!    let the *server-side reconstruction from the decoded bytes* be the
//!    `z~` that trains the server (the bytes really round-trip);
//! 4. **server update** — `server_step` artifact: loss, metrics, `∂h/∂z~`,
//!    server grads; weighted-aggregate server grads (p_i over cohort);
//! 5. **client backward** — send `∂h/∂z~` down (metered), run `client_bwd`
//!    (gradient correction eq. (5) happens inside the artifact);
//! 6. **client-side model sync** — upload client grads (metered),
//!    weighted-aggregate, one optimizer step on each side.
//!
//! Labels are *not* metered (the paper's cost model excludes them; in the
//! vertical-FL deployment the server owns labels — see DESIGN.md).

use std::sync::Arc;
use std::time::Instant;

use crate::comm::message::{self, Message};
use crate::comm::StarNetwork;
use crate::config::{Algorithm, RunConfig};
use crate::coordinator::aggregator::{ScalarAggregator, WeightedAggregator};
use crate::coordinator::client::{assemble, draw_masks, InputSources};
use crate::coordinator::quantize::QuantizeBackend;
use crate::coordinator::sampler::ClientSampler;
use crate::coordinator::Trainer;
use crate::data::{Array, FederatedDataset};
use crate::metrics::{RoundRecord, RunLog, TaskMetric};
use crate::models::ModelSpec;
use crate::optim::Optimizer;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorList};
use crate::util::logging::{CsvWriter, JsonlWriter};
use crate::util::rng::Rng;

/// Split-learning trainer (SplitFed when `quantizer` is None).
pub struct SplitTrainer {
    cfg: RunConfig,
    rt: Arc<Runtime>,
    data: Arc<dyn FederatedDataset>,
    spec: ModelSpec,
    wc: TensorList,
    ws: TensorList,
    opt_c: Box<dyn Optimizer>,
    opt_s: Box<dyn Optimizer>,
    net: StarNetwork,
    sampler: ClientSampler,
    quantizer: Option<QuantizeBackend>,
    metric: TaskMetric,
    rng: Rng,
    csv: Option<CsvWriter>,
    jsonl: Option<JsonlWriter>,
}

impl SplitTrainer {
    pub fn new(
        cfg: RunConfig,
        rt: Arc<Runtime>,
        data: Arc<dyn FederatedDataset>,
    ) -> anyhow::Result<Self> {
        let variant = cfg.variant();
        let spec = rt.manifest.variant(&variant)?.spec.clone();
        let rng = Rng::new(cfg.seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let quantizer = match cfg.algorithm {
            Algorithm::FedLite => Some(QuantizeBackend::new(
                cfg.quantizer,
                cfg.pq,
                spec.cut_dim,
                Arc::clone(&rt),
                &variant,
            )?),
            _ => None,
        };
        let (csv, jsonl) = open_logs(&cfg)?;
        Ok(SplitTrainer {
            sampler: ClientSampler::uniform(cfg.num_clients, cfg.clients_per_round),
            net: StarNetwork::with_defaults(cfg.num_clients),
            opt_c: crate::optim::build(&cfg.optimizer, cfg.client_lr)?,
            opt_s: crate::optim::build(&cfg.optimizer, cfg.server_lr)?,
            metric: TaskMetric::for_task(&cfg.task),
            quantizer,
            spec,
            wc,
            ws,
            rng,
            data,
            rt,
            cfg,
            csv,
            jsonl,
        })
    }

    pub fn params(&self) -> (&TensorList, &TensorList) {
        (&self.wc, &self.ws)
    }

    pub fn set_params(&mut self, wc: TensorList, ws: TensorList) {
        self.wc = wc;
        self.ws = ws;
    }

    /// Evaluate the current model on `batches` held-out batches.
    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        let variant = self.cfg.variant();
        let meta = self.rt.manifest.artifact(&variant, "full_eval")?.clone();
        let mut loss = ScalarAggregator::new();
        let mut sums = vec![0.0f64; self.spec.metrics.len()];
        let mut examples = 0.0f64;
        let mut rng = self.rng.fork(0xE7A1);
        for _ in 0..batches {
            let batch = self.data.eval_batch(self.spec.eval_batch, &mut rng);
            let src = InputSources {
                wc: Some(&self.wc),
                ws: Some(&self.ws),
                batch: Some(&batch),
                ..Default::default()
            };
            let inputs = assemble(&meta, &src)?;
            let outs = self.rt.run(&variant, "full_eval", &inputs)?;
            loss.add(scalar(&outs[0])? as f64, 1.0);
            for (k, s) in sums.iter_mut().enumerate() {
                *s += scalar(&outs[1 + k])? as f64;
            }
            examples += self.spec.eval_batch as f64;
            if self.cfg.task == "so_nwp" {
                // token metrics carry their own denominator
            }
        }
        Ok((loss.mean(), self.metric.value(&sums, examples)))
    }

    /// One full round; returns the round record.
    fn round(&mut self, round: usize) -> anyhow::Result<RoundRecord> {
        let t0 = Instant::now();
        let variant = self.cfg.variant();
        let fwd_meta = self.rt.manifest.artifact(&variant, "client_fwd")?.clone();
        let step_meta = self.rt.manifest.artifact(&variant, "server_step")?.clone();
        let bwd_meta = self.rt.manifest.artifact(&variant, "client_bwd")?.clone();
        let nmetrics = self.spec.metrics.len();

        self.net.begin_round();
        let cohort = self.sampler.sample(&mut self.rng.fork(round as u64), &[]);

        let mut ws_agg = WeightedAggregator::new();
        let mut wc_agg = WeightedAggregator::new();
        let mut loss_agg = ScalarAggregator::new();
        let mut qerr_agg = ScalarAggregator::new();
        let mut metric_sums = vec![0.0f64; nmetrics];
        let mut examples = 0.0f64;
        let mut per_client_bytes: Vec<(usize, usize)> = Vec::new();

        let wc_payload = message::tensors_to_payload(&self.wc);

        for (slot, &ci) in cohort.iter().enumerate() {
            let mut crng = self.rng.fork(((round as u64) << 20) ^ (ci as u64) ^ 0xC11E);
            let mut up_bytes = 0usize;
            let mut down_bytes = 0usize;

            // 0. model broadcast (downlink)
            let bc = Message::ModelBroadcast { params: wc_payload.clone() };
            let (_, n) = self.net.download(ci, round as u32, &bc)?;
            down_bytes += n;

            // 1. client forward
            let batch = self.data.train_batch(ci, self.spec.batch, &mut crng);
            let masks = draw_masks(
                &[&fwd_meta, &step_meta, &bwd_meta],
                self.cfg.dropout_client,
                self.cfg.dropout_server,
                &mut crng,
            );
            let src = InputSources {
                wc: Some(&self.wc),
                batch: Some(&batch),
                masks: Some(&masks),
                ..Default::default()
            };
            let z_arr = self
                .rt
                .run(&variant, "client_fwd", &assemble(&fwd_meta, &src)?)?
                .remove(0);
            let z = z_arr
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("z dtype"))?
                .to_vec();
            let act_b = self.spec.act_batch;
            let d = self.spec.cut_dim;

            // 2. upload: quantized (FedLite) or raw (SplitFed); the server
            //    trains on what came off the wire.
            let (z_tilde_server, quant_rel_err) = match &self.quantizer {
                Some(qz) => {
                    let out = qz.quantize(&z, act_b, &mut crng)?;
                    let msg =
                        Message::from_pq(&qz.config, act_b, d, &out.codebooks, &out.codes);
                    let (decoded, n) = self.net.upload(ci, round as u32, &msg)?;
                    up_bytes += n;
                    let codes = decoded.unpack_codes()?;
                    let cbs = match &decoded {
                        Message::QuantizedUpload { codebooks, .. } => codebooks.clone(),
                        _ => anyhow::bail!("wrong upload variant"),
                    };
                    let native = crate::quantizer::GroupedPq::new(qz.config, d)?;
                    let rec = native.reconstruct(&cbs, &codes, act_b);
                    debug_assert_eq!(rec, out.z_tilde, "wire changed z~");
                    (rec, out.relative_error(&z))
                }
                None => {
                    let msg = Message::ActivationUpload { z: z.clone(), b: act_b, d };
                    let (decoded, n) = self.net.upload(ci, round as u32, &msg)?;
                    up_bytes += n;
                    match decoded {
                        Message::ActivationUpload { z, .. } => (z, 0.0),
                        _ => anyhow::bail!("wrong upload variant"),
                    }
                }
            };
            let z_tilde = Array::f32(&[act_b, d], z_tilde_server);

            // 3. server update
            let src = InputSources {
                ws: Some(&self.ws),
                batch: Some(&batch),
                masks: Some(&masks),
                z_tilde: Some(&z_tilde),
                ..Default::default()
            };
            let outs = self.rt.run(&variant, "server_step", &assemble(&step_meta, &src)?)?;
            let weight = self.data.client_weight(ci).max(1e-12);
            loss_agg.add(scalar(&outs[0])? as f64, weight);
            for k in 0..nmetrics {
                metric_sums[k] += scalar(&outs[1 + k])? as f64;
            }
            examples += self.spec.batch as f64;
            let grad_z = outs[1 + nmetrics].clone();
            let ws_grads = arrays_to_tensors(&outs[2 + nmetrics..], &self.ws)?;
            ws_agg.add(&ws_grads, weight);
            qerr_agg.add(quant_rel_err, 1.0);

            // 4. gradient download
            let gz_vec = grad_z
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("grad_z dtype"))?
                .to_vec();
            let gmsg = Message::GradDownload { grad: gz_vec, b: act_b, d };
            let (decoded, n) = self.net.download(ci, round as u32, &gmsg)?;
            down_bytes += n;
            let grad_wire = match decoded {
                Message::GradDownload { grad, .. } => Array::f32(&[act_b, d], grad),
                _ => anyhow::bail!("wrong download variant"),
            };

            // 5. client backward (gradient correction inside the artifact)
            let src = InputSources {
                wc: Some(&self.wc),
                batch: Some(&batch),
                masks: Some(&masks),
                z_tilde: Some(&z_tilde),
                grad_z: Some(&grad_wire),
                lambda: Some(if self.quantizer.is_some() { self.cfg.lambda } else { 0.0 }),
                ..Default::default()
            };
            let bwd = self.rt.run(&variant, "client_bwd", &assemble(&bwd_meta, &src)?)?;
            let wc_grads = arrays_to_tensors(&bwd[..bwd.len() - 1], &self.wc)?;

            // 6. client-side grad sync (uplink)
            let cmsg = Message::ClientGrads { grads: message::tensors_to_payload(&wc_grads) };
            let (decoded, n) = self.net.upload(ci, round as u32, &cmsg)?;
            up_bytes += n;
            let synced = match decoded {
                Message::ClientGrads { grads } => message::payload_to_tensors(
                    &grads,
                    &self.wc.tensors.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>(),
                    &self.wc.names,
                ),
                _ => anyhow::bail!("wrong sync variant"),
            };
            wc_agg.add(&synced, weight);
            per_client_bytes.push((up_bytes, down_bytes));
            let _ = slot;
        }

        // optimizer steps on the aggregated gradients
        if let Some(g) = ws_agg.finish() {
            self.opt_s.step(&mut self.ws, &g);
        }
        if let Some(g) = wc_agg.finish() {
            self.opt_c.step(&mut self.wc, &g);
        }
        anyhow::ensure!(self.wc.is_finite() && self.ws.is_finite(),
            "parameters diverged (NaN/Inf) at round {round}");

        let rb = self.net.end_round();
        let mut rec = RoundRecord {
            round,
            train_loss: loss_agg.mean(),
            train_metric: self.metric.value(&metric_sums, examples),
            quant_error: qerr_agg.mean(),
            uplink_bytes: rb.up,
            downlink_bytes: rb.down,
            cumulative_uplink: self.net.totals().up,
            wall_seconds: t0.elapsed().as_secs_f64(),
            sim_comm_seconds: self.net.estimate_round_time(&per_client_bytes),
            ..Default::default()
        };
        if self.cfg.eval_every > 0
            && (round % self.cfg.eval_every == self.cfg.eval_every - 1 || round == 0)
        {
            let (el, em) = self.evaluate(self.cfg.eval_batches)?;
            rec.eval_loss = Some(el);
            rec.eval_metric = Some(em);
        }
        Ok(rec)
    }
}

impl Trainer for SplitTrainer {
    fn run(&mut self) -> anyhow::Result<RunLog> {
        let mut log = RunLog::default();
        let algo = self.cfg.algorithm.name();
        for round in 0..self.cfg.rounds {
            let rec = self.round(round)?;
            if round == 0 || (round + 1) % 10 == 0 {
                log::info!(
                    "{algo} {} r{:>4}: loss={:.4} metric={:.4} upKB={:.1} qerr={:.3}",
                    self.cfg.task,
                    round,
                    rec.train_loss,
                    rec.train_metric,
                    rec.uplink_bytes as f64 / 1024.0,
                    rec.quant_error,
                );
            }
            write_round(&mut self.csv, &mut self.jsonl, &rec)?;
            log.push(rec);
        }
        if let Some(c) = &mut self.csv {
            c.flush()?;
        }
        if let Some(j) = &mut self.jsonl {
            j.flush()?;
        }
        Ok(log)
    }
}

// -- shared helpers (also used by fedavg.rs) ---------------------------------

pub fn scalar(a: &Array) -> anyhow::Result<f32> {
    a.as_f32()
        .and_then(|v| v.first().copied())
        .ok_or_else(|| anyhow::anyhow!("expected f32 scalar output"))
}

/// Convert artifact gradient outputs into a TensorList shaped like `like`.
pub fn arrays_to_tensors(arrs: &[Array], like: &TensorList) -> anyhow::Result<TensorList> {
    anyhow::ensure!(
        arrs.len() == like.len(),
        "got {} grads, expected {}",
        arrs.len(),
        like.len()
    );
    let tensors = arrs
        .iter()
        .zip(&like.tensors)
        .map(|(a, t)| {
            let data = a
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("gradient not f32"))?;
            anyhow::ensure!(a.shape() == t.shape(), "grad shape mismatch");
            Ok(Tensor::from_vec(t.shape(), data.to_vec()))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(TensorList::new(like.names.clone(), tensors))
}

pub(crate) fn open_logs(
    cfg: &RunConfig,
) -> anyhow::Result<(Option<CsvWriter>, Option<JsonlWriter>)> {
    if cfg.out_dir.is_empty() {
        return Ok((None, None));
    }
    let base = format!(
        "{}/{}_{}_{}", cfg.out_dir, cfg.task, cfg.algorithm.name(), cfg.seed
    );
    let csv = CsvWriter::create(
        format!("{base}.csv"),
        &[
            "round", "train_loss", "train_metric", "eval_loss", "eval_metric",
            "quant_error", "uplink_bytes", "downlink_bytes", "cumulative_uplink",
            "wall_seconds", "sim_comm_seconds",
        ],
    )?;
    let jsonl = JsonlWriter::create(format!("{base}.jsonl"))?;
    Ok((Some(csv), Some(jsonl)))
}

pub(crate) fn write_round(
    csv: &mut Option<CsvWriter>,
    jsonl: &mut Option<JsonlWriter>,
    rec: &RoundRecord,
) -> anyhow::Result<()> {
    if let Some(c) = csv {
        c.row(&[
            rec.round.to_string(),
            format!("{:.6}", rec.train_loss),
            format!("{:.6}", rec.train_metric),
            rec.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            rec.eval_metric.map(|v| format!("{v:.6}")).unwrap_or_default(),
            format!("{:.6}", rec.quant_error),
            rec.uplink_bytes.to_string(),
            rec.downlink_bytes.to_string(),
            rec.cumulative_uplink.to_string(),
            format!("{:.4}", rec.wall_seconds),
            format!("{:.4}", rec.sim_comm_seconds),
        ])?;
    }
    if let Some(j) = jsonl {
        j.record(&rec.to_json())?;
    }
    Ok(())
}
