//! The SplitFed / FedLite trainer (paper §3 + §4) on the generic engine.
//!
//! Each round runs through [`crate::coordinator::engine::RoundEngine`] —
//! the Sampling → Broadcast → ClientCompute → Aggregate → Commit phase
//! machine, fault injection, survivor reduction, byte accounting, and
//! record assembly all live there, shared verbatim with FedAvg. This
//! module only supplies the split-learning payload hooks
//! ([`crate::coordinator::engine::RoundAlgorithm`]):
//!
//! * **broadcast** — the client-side model `w_c`;
//! * **client step** — broadcast download → `client_fwd` → (FedLite)
//!   quantize → metered wire round-trip (the server trains on the
//!   *reconstruction from the decoded bytes*) → `server_step` → grad
//!   download → gradient correction eq. (5) applied host-side to the
//!   wire gradient (`coordinator::correction`; the surrogate objective
//!   eq. (6) is logged per round as the `surrogate_loss` CSV column) →
//!   `client_bwd` (the artifact's λ input stays 0 so the correction is
//!   applied exactly once) → client-grad upload. Fault injection
//!   short-circuits this pipeline at the scheduled phase: bytes a client
//!   sent before failing stay metered, its gradients never leave the
//!   worker;
//! * **accumulate** — fold a survivor's `(w_s, w_c)` gradients into the
//!   weighted aggregates (weights renormalize over survivors — see
//!   `aggregator::SurvivorSet`);
//! * **commit** — one optimizer step per side on the survivor aggregate
//!   (skipped on a degraded commit).
//!
//! Per-client RNG streams (batches *and* fault schedules) are forked from
//! pure `(round, attempt, client)` keys and every reduction has a fixed
//! order, so round records are **bit-identical at any worker count**,
//! clean or faulty (`workers = 1` recovers the serial loop exactly;
//! enforced by `rust/tests/determinism.rs`), and a clean config
//! (`drop_prob = 0`) reproduces the pre-fault engine bit for bit
//! (`rust/tests/faults.rs`).
//!
//! Labels are *not* metered (the paper's cost model excludes them; in the
//! vertical-FL deployment the server owns labels — see DESIGN.md).

use std::sync::Arc;

use crate::comm::accounting::RoundBytes;
use crate::comm::message::{self, Message};
use crate::comm::StarNetwork;
use crate::config::{Algorithm, ByzantineKind, RunConfig};
use crate::coordinator::aggregator::{clip_to_norm, ScalarAggregator, UpdateAggregator};
use crate::coordinator::client::{assemble, draw_masks, InputSources};
use crate::coordinator::correction;
use crate::coordinator::engine::{
    open_logs, ClientOutput, RoundAlgorithm, RoundEngine, RoundEnv, MAX_SAMPLING_ATTEMPTS,
};
use crate::coordinator::faults::{self, DropPhase, FaultConfig, FaultPlan};
use crate::coordinator::quantize::QuantizeBackend;
use crate::coordinator::sampler::ClientSampler;
use crate::coordinator::Trainer;
use crate::data::{Array, FederatedDataset};
use crate::metrics::{RoundRecord, RunLog, TaskMetric};
use crate::models::ModelSpec;
use crate::optim::Optimizer;
use crate::quantizer::{PqOutput, QuantizeScratch};
use crate::runtime::native::EngineScratch;
use crate::runtime::{ArtifactMeta, Runtime};
use crate::tensor::{Tensor, TensorList};
use crate::util::logging::{CsvWriter, JsonlWriter};
use crate::util::rng::Rng;

/// Split-learning trainer (SplitFed when `quantizer` is None).
pub struct SplitTrainer {
    cfg: RunConfig,
    rt: Arc<Runtime>,
    data: Arc<dyn FederatedDataset>,
    spec: ModelSpec,
    wc: TensorList,
    ws: TensorList,
    opt_c: Box<dyn Optimizer>,
    opt_s: Box<dyn Optimizer>,
    net: StarNetwork,
    sampler: ClientSampler,
    quantizer: Option<QuantizeBackend>,
    metric: TaskMetric,
    faults: FaultConfig,
    rng: Rng,
    csv: Option<CsvWriter>,
    jsonl: Option<JsonlWriter>,
    /// Warm engine buffers for the eval pass (the round path's scratches
    /// live in the engine's per-slot pool).
    eval_scratch: EngineScratch,
}

/// Per-round artifact handles, fetched once and shared by the cohort.
pub struct SplitPrep {
    variant: String,
    fwd: ArtifactMeta,
    step: ArtifactMeta,
    bwd: ArtifactMeta,
}

/// What one surviving client contributes to the split aggregates.
pub struct SplitPayload {
    pub wc_grads: TensorList,
    pub ws_grads: TensorList,
}

/// The split trainer's survivor accumulator: one aggregate per model
/// side, dispatching on the run's `--aggregation` rule (the default mean
/// delegates to the weighted aggregator bit-for-bit).
pub struct SplitAccum {
    ws_agg: UpdateAggregator,
    wc_agg: UpdateAggregator,
}

/// Per-cohort-slot reusable buffers for the split client step: the
/// quantizer's scratch arena, a warm [`PqOutput`], and the native
/// engine's [`EngineScratch`] (every forward/backward intermediate).
/// Owned by the round engine's scratch pool, so after round 1 the
/// quantize path performs no heap allocation and the compute path reuses
/// all of its intermediates (see `tests/alloc.rs`).
#[derive(Default)]
pub struct SplitScratch {
    quant: QuantizeScratch,
    pq: PqOutput,
    engine: EngineScratch,
}

impl SplitTrainer {
    pub fn new(
        cfg: RunConfig,
        rt: Arc<Runtime>,
        data: Arc<dyn FederatedDataset>,
    ) -> anyhow::Result<Self> {
        let variant = cfg.variant();
        let spec = rt.manifest.variant(&variant)?.spec.clone();
        let rng = Rng::new(cfg.seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let quantizer = match cfg.algorithm {
            Algorithm::FedLite => Some(QuantizeBackend::new(
                cfg.quantizer,
                cfg.pq,
                spec.cut_dim,
                Arc::clone(&rt),
                &variant,
            )?),
            _ => None,
        };
        let (csv, jsonl) = open_logs(&cfg)?;
        Ok(SplitTrainer {
            sampler: ClientSampler::uniform(cfg.num_clients, cfg.clients_per_round),
            net: StarNetwork::with_defaults(cfg.num_clients),
            opt_c: crate::optim::build(&cfg.optimizer, cfg.client_lr)?,
            opt_s: crate::optim::build(&cfg.optimizer, cfg.server_lr)?,
            metric: TaskMetric::for_task(&cfg.task),
            faults: FaultConfig::from_run(&cfg),
            quantizer,
            spec,
            wc,
            ws,
            rng,
            data,
            rt,
            cfg,
            csv,
            jsonl,
            eval_scratch: EngineScratch::new(),
        })
    }

    pub fn params(&self) -> (&TensorList, &TensorList) {
        (&self.wc, &self.ws)
    }

    pub fn set_params(&mut self, wc: TensorList, ws: TensorList) {
        self.wc = wc;
        self.ws = ws;
    }

    /// Evaluate the current model on `batches` held-out batches.
    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        let variant = self.cfg.variant();
        let meta = self.rt.manifest.artifact(&variant, "full_eval")?.clone();
        let mut loss = ScalarAggregator::new();
        let mut sums = vec![0.0f64; self.spec.metrics.len()];
        let mut examples = 0.0f64;
        let mut rng = self.rng.fork(0xE7A1);
        for _ in 0..batches {
            let batch = self.data.eval_batch(self.spec.eval_batch, &mut rng);
            let src = InputSources {
                wc: Some(&self.wc),
                ws: Some(&self.ws),
                batch: Some(&batch),
                ..Default::default()
            };
            let inputs = assemble(&meta, &src)?;
            let outs = self
                .rt
                .run_scratch(&variant, "full_eval", &inputs, &mut self.eval_scratch)?;
            loss.add(scalar(&outs[0])? as f64, 1.0);
            for (k, s) in sums.iter_mut().enumerate() {
                *s += scalar(&outs[1 + k])? as f64;
            }
            examples += self.spec.eval_batch as f64;
        }
        Ok((loss.mean(), self.metric.value(&sums, examples)))
    }
}

impl RoundAlgorithm for SplitTrainer {
    type Prep = SplitPrep;
    type Payload = SplitPayload;
    type Accum = SplitAccum;
    type Scratch = SplitScratch;

    fn stream_tag(&self) -> u64 {
        0xC11E
    }

    fn env(&self) -> RoundEnv<'_> {
        RoundEnv {
            net: &self.net,
            sampler: &self.sampler,
            faults: &self.faults,
            rng: &self.rng,
            metric: self.metric,
            batch_examples: self.spec.batch as f64,
            nmetrics: self.spec.metrics.len(),
            clip_norm: self.cfg.clip_norm,
            workers: self.cfg.resolved_workers(),
            shards: self.cfg.shards,
            rounds: self.cfg.rounds,
            eval_every: self.cfg.eval_every,
            eval_batches: self.cfg.eval_batches,
            max_attempts: MAX_SAMPLING_ATTEMPTS,
        }
    }

    fn prepare(&self, _round: usize) -> anyhow::Result<SplitPrep> {
        let variant = self.cfg.variant();
        Ok(SplitPrep {
            fwd: self.rt.manifest.artifact(&variant, "client_fwd")?.clone(),
            step: self.rt.manifest.artifact(&variant, "server_step")?.clone(),
            bwd: self.rt.manifest.artifact(&variant, "client_bwd")?.clone(),
            variant,
        })
    }

    fn build_broadcast(&self, _prep: &SplitPrep) -> Message {
        Message::ModelBroadcast { params: message::tensors_to_payload(&self.wc) }
    }

    /// One client's full round pipeline (see the module docs); runs on a
    /// worker thread against `&self`.
    fn client_step(
        &self,
        prep: &SplitPrep,
        broadcast: &Message,
        round: u32,
        ci: usize,
        crng: &mut Rng,
        plan: &FaultPlan,
        scratch: &mut SplitScratch,
    ) -> anyhow::Result<ClientOutput<SplitPayload>> {
        let mut up_bytes = 0usize;
        let mut down_bytes = 0usize;
        let mut up_msgs = 0u64;
        let mut down_msgs = 0u64;
        let act_b = self.spec.act_batch;
        let d = self.spec.cut_dim;
        let nmetrics = self.spec.metrics.len();
        let weight = self.data.client_weight(ci).max(1e-12);
        let lambda = if self.quantizer.is_some() { self.cfg.lambda } else { 0.0 };

        // 0. model broadcast (downlink)
        let (_, n) = self.net.download(ci, round, broadcast)?;
        down_bytes += n;
        down_msgs += 1;

        // 1. client forward
        let mut batch = self.data.train_batch(ci, self.spec.batch, crng);
        if plan.byz == Some(ByzantineKind::LabelFlip) {
            // poisoned labels feed the whole pipeline from here on; the
            // rotation draws no RNG, so honest clients are unperturbed
            faults::poison_labels(&mut batch.y, self.spec.batch);
        }
        let masks = draw_masks(
            &[&prep.fwd, &prep.step, &prep.bwd],
            self.cfg.dropout_client,
            self.cfg.dropout_server,
            crng,
        );
        let src = InputSources {
            wc: Some(&self.wc),
            batch: Some(&batch),
            masks: Some(&masks),
            ..Default::default()
        };
        let z_arr = self
            .rt
            .run_scratch(
                &prep.variant,
                "client_fwd",
                &assemble(&prep.fwd, &src)?,
                &mut scratch.engine,
            )?
            .remove(0);
        let z = match z_arr {
            Array::F32 { data, .. } => data,
            _ => anyhow::bail!("z dtype"),
        };
        if plan.drop_at == Some(DropPhase::AfterFwd) {
            // vanished before uploading: only the broadcast crossed the wire
            return Ok(ClientOutput::failed(
                DropPhase::AfterFwd,
                weight,
                RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
                plan.delay_seconds,
            ));
        }

        // 2. upload: quantized (FedLite) or raw (SplitFed); the server
        //    trains on what came off the wire.
        let (z_tilde_server, quant_rel_err) = match &self.quantizer {
            Some(qz) => {
                qz.quantize_into(&z, act_b, crng, &mut scratch.quant, &mut scratch.pq)?;
                let out = &mut scratch.pq;
                let mut msg = Message::from_pq(&qz.config, act_b, d, &out.codebooks, &out.codes);
                if plan.byz == Some(ByzantineKind::CorruptCodeword) {
                    if let Message::QuantizedUpload { packed_codes, .. } = &mut msg {
                        // attacker bytes come from a dedicated fork of the
                        // client work stream — deterministic, and honest
                        // draws never see it (fork never advances crng)
                        let mut brng = crng.fork(faults::BYZ_PAYLOAD_TAG);
                        faults::corrupt_codewords(packed_codes, &mut brng);
                    }
                }
                let (decoded, n) = self.net.upload(ci, round, &msg)?;
                up_bytes += n;
                up_msgs += 1;
                // always-on server-side defense: validate the decoded
                // stream against the PQ geometry before anything derived
                // from it trains the server. Honest uploads always pass
                // (pure integer checks); a corrupt stream drops the
                // client here — its bytes stay metered, they crossed the
                // wire — instead of aborting the round.
                if decoded.validate_codewords().is_err() {
                    return Ok(ClientOutput::failed(
                        DropPhase::RejectedCodeword,
                        weight,
                        RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
                        plan.delay_seconds,
                    ));
                }
                let cbs = match &decoded {
                    Message::QuantizedUpload { codebooks, .. } => codebooks,
                    _ => anyhow::bail!("wrong upload variant"),
                };
                // the wire is lossless for codebooks + codes, so the
                // decoded reconstruction equals the quantizer's own z~
                // bit for bit; re-proving it (decode → reconstruct →
                // compare) is debug-only — it used to build a second
                // GroupedPq and re-reconstruct per client per round
                if cfg!(debug_assertions) {
                    let codes = decoded.unpack_codes()?;
                    let rec = qz.native_pq().reconstruct(cbs, &codes, act_b);
                    debug_assert_eq!(rec, out.z_tilde, "wire changed z~");
                }
                let rel = out.relative_error(&z);
                // the server trains on the wire-equivalent z~; the buffer
                // is lent out and recovered after the backward pass
                (std::mem::take(&mut out.z_tilde), rel)
            }
            None => {
                let msg = Message::ActivationUpload { z: z.clone(), b: act_b, d };
                let (decoded, n) = self.net.upload(ci, round, &msg)?;
                up_bytes += n;
                up_msgs += 1;
                match decoded {
                    Message::ActivationUpload { z, .. } => (z, 0.0),
                    _ => anyhow::bail!("wrong upload variant"),
                }
            }
        };
        if plan.drop_at == Some(DropPhase::AfterUpload) {
            // the activation upload landed (and is metered); the client is
            // gone, so the server never trains on it. The z~ buffer still
            // goes back to the slot scratch — faulty rounds must not
            // reintroduce steady-state allocations
            if self.quantizer.is_some() {
                scratch.pq.z_tilde = z_tilde_server;
            }
            return Ok(ClientOutput::failed(
                DropPhase::AfterUpload,
                weight,
                RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
                plan.delay_seconds,
            ));
        }
        let z_tilde = Array::f32(&[act_b, d], z_tilde_server);

        // 3. server update
        let src = InputSources {
            ws: Some(&self.ws),
            batch: Some(&batch),
            masks: Some(&masks),
            z_tilde: Some(&z_tilde),
            ..Default::default()
        };
        let outs = self.rt.run_scratch(
            &prep.variant,
            "server_step",
            &assemble(&prep.step, &src)?,
            &mut scratch.engine,
        )?;
        let loss = scalar(&outs[0])? as f64;
        let mut metric_sums = vec![0.0f64; nmetrics];
        for (k, s) in metric_sums.iter_mut().enumerate() {
            *s = scalar(&outs[1 + k])? as f64;
        }
        let grad_z = outs[1 + nmetrics].clone();
        let ws_grads = arrays_to_tensors(&outs[2 + nmetrics..], &self.ws)?;

        // 4. gradient download
        let gz_vec = grad_z
            .as_f32()
            .ok_or_else(|| anyhow::anyhow!("grad_z dtype"))?
            .to_vec();
        let gmsg = Message::GradDownload { grad: gz_vec, b: act_b, d };
        let (decoded, n) = self.net.download(ci, round, &gmsg)?;
        down_bytes += n;
        down_msgs += 1;
        let grad_wire_vec = match decoded {
            Message::GradDownload { grad, .. } => grad,
            _ => anyhow::bail!("wrong download variant"),
        };
        if plan.drop_at == Some(DropPhase::BeforeGradUpload) {
            // uplink activations and the grad download are metered; the
            // client-side gradient never comes back. Recover the z~
            // buffer here too — this exit skips the backward pass
            if self.quantizer.is_some() {
                if let Array::F32 { data, .. } = z_tilde {
                    scratch.pq.z_tilde = data;
                }
            }
            return Ok(ClientOutput::failed(
                DropPhase::BeforeGradUpload,
                weight,
                RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs),
                plan.delay_seconds,
            ));
        }

        // 5. gradient correction (paper eq. (5)) applied host-side to the
        //    wire gradient, then the client backward. The artifact still
        //    takes a λ input but receives 0 here, so the correction is
        //    applied exactly once — and the float sequence
        //    `g + λ(z − z̃)` is identical to the in-artifact path the
        //    golden fixtures were blessed on.
        let zt = z_tilde
            .as_f32()
            .ok_or_else(|| anyhow::anyhow!("z_tilde dtype"))?;
        let corrected = correction::corrected_cotangent(&grad_wire_vec, &z, zt, lambda);
        // surrogate objective eq. (6) at this client's cut; only meaningful
        // when a quantization gap exists (the CSV logs the survivor mean)
        let surrogate = if self.quantizer.is_some() {
            correction::surrogate_loss(&grad_wire_vec, &z, zt, lambda)
        } else {
            0.0
        };
        let grad_wire = Array::f32(&[act_b, d], corrected);
        let src = InputSources {
            wc: Some(&self.wc),
            batch: Some(&batch),
            masks: Some(&masks),
            z_tilde: Some(&z_tilde),
            grad_z: Some(&grad_wire),
            lambda: Some(0.0),
            ..Default::default()
        };
        let bwd = self.rt.run_scratch(
            &prep.variant,
            "client_bwd",
            &assemble(&prep.bwd, &src)?,
            &mut scratch.engine,
        )?;
        let mut wc_grads = arrays_to_tensors(&bwd[..bwd.len() - 1], &self.wc)?;
        // hand the z~ buffer back to the slot scratch so the next round's
        // quantize reuses it instead of allocating
        if self.quantizer.is_some() {
            if let Array::F32 { data, .. } = z_tilde {
                scratch.pq.z_tilde = data;
            }
        }

        // byzantine payload attacks, applied before the wire upload so
        // socket replicas ship the same poisoned bits as the in-process
        // fan-out. Sizes are unchanged — the byte meters look honest.
        // Replay free-rides by shipping a null update (the effect of
        // replaying stale state against an unchanged aggregate).
        let mut ws_grads = ws_grads;
        match plan.byz {
            Some(ByzantineKind::GradScale) => {
                wc_grads.scale(faults::GRAD_SCALE);
                ws_grads.scale(faults::GRAD_SCALE);
            }
            Some(ByzantineKind::SignFlip) => {
                wc_grads.scale(-1.0);
                ws_grads.scale(-1.0);
            }
            Some(ByzantineKind::Replay) => {
                wc_grads.scale(0.0);
                ws_grads.scale(0.0);
            }
            _ => {}
        }

        // 6. client-side grad sync (uplink)
        let cmsg = Message::ClientGrads { grads: message::tensors_to_payload(&wc_grads) };
        let (decoded, n) = self.net.upload(ci, round, &cmsg)?;
        up_bytes += n;
        up_msgs += 1;
        let synced = match decoded {
            Message::ClientGrads { grads } => message::payload_to_tensors(
                &grads,
                &self.wc.tensors.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>(),
                &self.wc.names,
            ),
            _ => anyhow::bail!("wrong sync variant"),
        };

        let bytes = RoundBytes::client(up_bytes, down_bytes, up_msgs, down_msgs);
        if plan.evicted {
            // straggler past the deadline: every message crossed the wire,
            // but the round committed without it
            return Ok(ClientOutput::failed(
                DropPhase::Deadline,
                weight,
                bytes,
                plan.delay_seconds,
            ));
        }
        Ok(ClientOutput {
            weight,
            loss,
            metric_sums,
            quant_rel_err,
            surrogate_loss: surrogate,
            payload: Some(SplitPayload { wc_grads: synced, ws_grads }),
            bytes,
            dropped: None,
            delay_seconds: plan.delay_seconds,
        })
    }

    fn new_accum(&self) -> SplitAccum {
        SplitAccum {
            ws_agg: UpdateAggregator::new(self.cfg.aggregation),
            wc_agg: UpdateAggregator::new(self.cfg.aggregation),
        }
    }

    fn clip_payload(&self, payload: &mut SplitPayload, max_norm: f64) -> bool {
        // one joint bound over both model sides: a scaled update is
        // scaled everywhere or nowhere
        clip_to_norm(&mut [&mut payload.wc_grads, &mut payload.ws_grads], max_norm)
    }

    fn accumulate(&self, acc: &mut SplitAccum, payload: SplitPayload, weight: f64) {
        acc.ws_agg.add(&payload.ws_grads, weight);
        acc.wc_agg.add(&payload.wc_grads, weight);
    }

    fn commit(
        &mut self,
        _prep: SplitPrep,
        survivors: Option<SplitAccum>,
        round: usize,
    ) -> anyhow::Result<()> {
        // optimizer steps on the survivor-aggregated gradients (skipped
        // on a degraded commit)
        if let Some(acc) = survivors {
            if let Some(g) = acc.ws_agg.finish() {
                self.opt_s.step(&mut self.ws, &g);
            }
            if let Some(g) = acc.wc_agg.finish() {
                self.opt_c.step(&mut self.wc, &g);
            }
        }
        anyhow::ensure!(self.wc.is_finite() && self.ws.is_finite(),
            "parameters diverged (NaN/Inf) at round {round}");
        Ok(())
    }

    fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        SplitTrainer::evaluate(self, batches)
    }

    fn writers(&mut self) -> (&mut Option<CsvWriter>, &mut Option<JsonlWriter>) {
        (&mut self.csv, &mut self.jsonl)
    }

    fn log_round(&self, rec: &RoundRecord) {
        log::info!(
            "{} {} r{:>4}: loss={:.4} metric={:.4} upKB={:.1} qerr={:.3}",
            self.cfg.algorithm.name(),
            self.cfg.task,
            rec.round,
            rec.train_loss,
            rec.train_metric,
            rec.uplink_bytes as f64 / 1024.0,
            rec.quant_error,
        );
    }

    // -- remote-execution hooks: the broadcast carries w_c, so the only
    // extra round state a replica needs is the server-side w_s (the
    // server half runs inside `client_step` in split learning).

    fn round_state(&self, _prep: &SplitPrep) -> Vec<Vec<f32>> {
        message::tensors_to_payload(&self.ws)
    }

    fn install_round_state(&mut self, state: Vec<Vec<f32>>) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.len() == self.ws.len(),
            "round state carries {} tensors, server model has {}",
            state.len(),
            self.ws.len()
        );
        let shapes: Vec<Vec<usize>> =
            self.ws.tensors.iter().map(|t| t.shape().to_vec()).collect();
        self.ws = message::payload_to_tensors(&state, &shapes, &self.ws.names);
        Ok(())
    }

    fn install_broadcast(&mut self, broadcast: &Message) -> anyhow::Result<()> {
        let params = match broadcast {
            Message::ModelBroadcast { params } => params,
            _ => anyhow::bail!("split broadcast must be a ModelBroadcast"),
        };
        anyhow::ensure!(
            params.len() == self.wc.len(),
            "broadcast carries {} tensors, client model has {}",
            params.len(),
            self.wc.len()
        );
        let shapes: Vec<Vec<usize>> =
            self.wc.tensors.iter().map(|t| t.shape().to_vec()).collect();
        self.wc = message::payload_to_tensors(params, &shapes, &self.wc.names);
        Ok(())
    }

    fn payload_to_wire(&self, payload: SplitPayload) -> anyhow::Result<Vec<Vec<f32>>> {
        let mut wire = message::tensors_to_payload(&payload.wc_grads);
        wire.extend(message::tensors_to_payload(&payload.ws_grads));
        Ok(wire)
    }

    fn payload_from_wire(&self, wire: Vec<Vec<f32>>) -> anyhow::Result<SplitPayload> {
        anyhow::ensure!(
            wire.len() == self.wc.len() + self.ws.len(),
            "wire payload carries {} tensors, split model has {}+{}",
            wire.len(),
            self.wc.len(),
            self.ws.len()
        );
        let ws_wire = wire[self.wc.len()..].to_vec();
        let wc_wire = &wire[..self.wc.len()];
        let wc_shapes: Vec<Vec<usize>> =
            self.wc.tensors.iter().map(|t| t.shape().to_vec()).collect();
        let ws_shapes: Vec<Vec<usize>> =
            self.ws.tensors.iter().map(|t| t.shape().to_vec()).collect();
        Ok(SplitPayload {
            wc_grads: message::payload_to_tensors(wc_wire, &wc_shapes, &self.wc.names),
            ws_grads: message::payload_to_tensors(&ws_wire, &ws_shapes, &self.ws.names),
        })
    }
}

impl Trainer for SplitTrainer {
    fn run(&mut self) -> anyhow::Result<RunLog> {
        RoundEngine::new(self).run()
    }
}

// -- shared helpers (also used by fedavg.rs) ---------------------------------

pub fn scalar(a: &Array) -> anyhow::Result<f32> {
    a.as_f32()
        .and_then(|v| v.first().copied())
        .ok_or_else(|| anyhow::anyhow!("expected f32 scalar output"))
}

/// Convert artifact gradient outputs into a TensorList shaped like `like`.
pub fn arrays_to_tensors(arrs: &[Array], like: &TensorList) -> anyhow::Result<TensorList> {
    anyhow::ensure!(
        arrs.len() == like.len(),
        "got {} grads, expected {}",
        arrs.len(),
        like.len()
    );
    let tensors = arrs
        .iter()
        .zip(&like.tensors)
        .map(|(a, t)| {
            let data = a
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("gradient not f32"))?;
            anyhow::ensure!(a.shape() == t.shape(), "grad shape mismatch");
            Ok(Tensor::from_vec(t.shape(), data.to_vec()))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(TensorList::new(like.names.clone(), tensors))
}
