//! The generic, algorithm-agnostic round engine shared by every trainer.
//!
//! Every federated round is an explicit state machine (in the style of the
//! Psyche coordinator's `RunState`/`tick` loop):
//!
//! ```text
//! Sampling → Broadcast → ClientCompute → Aggregate → Commit
//!     ▲                                      │
//!     └────────── resample (too few ─────────┘
//!                 survivors, attempt += 1)
//! ```
//!
//! [`RoundEngine`] owns everything the algorithms share — cohort sampling,
//! fault-plan drawing, the per-shard fan-out (delegated to a
//! [`crate::coordinator::backend::ClientBackend`]: in-process worker
//! threads by default, TCP loopback members in socket deployments),
//! survivor/drop reduction in cohort-slot order, resample
//! decisions, byte and simulated-time accumulation, degraded commits, and
//! [`RoundRecord`] assembly — so that FedLite, SplitFed, and FedAvg run
//! the *same* round protocol and only the payloads differ (the
//! precondition for the paper's cross-algorithm communication comparison,
//! Figs. 4–6). An algorithm plugs in through the small [`RoundAlgorithm`]
//! trait: build the broadcast, run one client's step, fold a survivor's
//! payload into the aggregate, and apply the committed optimizer step.
//!
//! Engine invariants, enforced here for every algorithm:
//!
//! * **Determinism** — all RNG keys are pure functions of
//!   `(round, attempt, client)` — never wall-clock, thread, or shard
//!   identity — and every floating-point reduction runs in flat
//!   cohort-slot order, so round records are bit-identical at any
//!   `--workers` *and* `--shards` count (`rust/tests/determinism.rs`).
//! * **Sharded fan-out** — the sampled cohort is partitioned into
//!   `RoundEnv::shards` contiguous slices; each shard draws its own fault
//!   plans and runs its own worker fan-out, and only *exact* partials
//!   (survivor sets, drop tallies, byte counts, a max-time) merge
//!   shard-by-shard. Floats never reduce per shard — float addition is
//!   non-associative, and per-shard float sums would tie the bits to the
//!   shard count.
//! * **Metered exits** — `net.begin_round()`/`end_round()` bracket the
//!   round on *every* exit path, including a client step failing with an
//!   error mid-attempt. (Before the engine existed, each trainer's `?` on
//!   a failed client skipped `end_round`, bleeding the aborted round's
//!   bytes into the next round's meter delta and desyncing the per-round
//!   archive from the `RoundRecord`s.)
//! * **Degraded commits** — when nobody survived, *or* when the survivors'
//!   total aggregation weight is zero (e.g. a cohort of empty-shard
//!   clients, which would otherwise renormalize into NaN weights), the
//!   round commits without an optimizer step.
//! * **Bounded resampling** — `Aggregate` may rewind to `Sampling` when
//!   the surviving cohort is smaller than `min_survivors`; the attempt
//!   budget is bounded so a pathological fault config degrades instead of
//!   livelocking.

use std::time::Instant;

use crate::comm::accounting::RoundBytes;
use crate::comm::message::Message;
use crate::comm::StarNetwork;
use crate::config::RunConfig;
use crate::coordinator::aggregator::{ScalarAggregator, SurvivorSet};
use crate::coordinator::backend::{ClientBackend, InProcessBackend};
use crate::coordinator::faults::{DropCounts, DropPhase, FaultConfig, FaultPlan};
use crate::coordinator::sampler::ClientSampler;
use crate::metrics::{RoundRecord, RunLog, TaskMetric};
use crate::util::logging::{CsvWriter, JsonlWriter};
use crate::util::rng::Rng;

/// The phases of one federated round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPhase {
    /// Pick the round's cohort and draw its fault schedules.
    Sampling,
    /// Build the model broadcast shared by the cohort.
    Broadcast,
    /// Fan the cohort across the worker threads (the round barrier).
    ClientCompute,
    /// Reduce partials in cohort-slot order; decide survive/resample.
    Aggregate,
    /// Step the optimizers on the survivor aggregate and emit the record.
    Commit,
}

/// Upper bound on sampling attempts per round before the round commits
/// degraded (fewer survivors than `min_survivors`, no optimizer step when
/// nobody survived). Bounds the resample loop deterministically.
pub const MAX_SAMPLING_ATTEMPTS: u32 = 16;

/// Phase/attempt bookkeeping for one round.
#[derive(Debug)]
pub struct RoundDriver {
    phase: RoundPhase,
    attempt: u32,
    max_attempts: u32,
}

impl RoundDriver {
    pub fn new() -> Self {
        Self::with_max_attempts(MAX_SAMPLING_ATTEMPTS)
    }

    pub fn with_max_attempts(max_attempts: u32) -> Self {
        RoundDriver {
            phase: RoundPhase::Sampling,
            attempt: 1,
            max_attempts: max_attempts.max(1),
        }
    }

    pub fn phase(&self) -> RoundPhase {
        self.phase
    }

    /// 1-based sampling attempt (1 = the round committed first try).
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// Advance to the next phase in order; `Commit` is terminal.
    pub fn advance(&mut self) {
        self.phase = match self.phase {
            RoundPhase::Sampling => RoundPhase::Broadcast,
            RoundPhase::Broadcast => RoundPhase::ClientCompute,
            RoundPhase::ClientCompute => RoundPhase::Aggregate,
            RoundPhase::Aggregate | RoundPhase::Commit => RoundPhase::Commit,
        };
    }

    /// Called from `Aggregate` when the surviving cohort is too small.
    /// Rewinds to `Sampling` with the next attempt and returns `true`
    /// while budget remains; returns `false` once the attempt budget is
    /// exhausted (caller proceeds to a degraded `Commit`).
    pub fn resample(&mut self) -> bool {
        debug_assert_eq!(self.phase, RoundPhase::Aggregate, "resample outside Aggregate");
        if self.attempt >= self.max_attempts {
            return false;
        }
        self.attempt += 1;
        self.phase = RoundPhase::Sampling;
        true
    }
}

impl Default for RoundDriver {
    fn default() -> Self {
        Self::new()
    }
}

/// Fork key for the round's cohort sampling. Attempt 1 must reproduce the
/// pre-fault engine exactly (`fork(round)`), so clean configs stay
/// bit-identical to historical logs; later attempts mix the attempt in.
pub fn sample_key(round: u64, attempt: u32) -> u64 {
    if attempt <= 1 {
        round
    } else {
        round ^ ((attempt as u64) << 48) ^ 0x5EED_0A17
    }
}

/// Fork key for one client's round work stream. `tag` distinguishes the
/// trainers (split: `0xC11E`, fedavg: `0xFEDA` — unchanged from the serial
/// engine); attempt 1 reproduces the historical key exactly.
pub fn client_stream_key(tag: u64, round: u64, client: usize, attempt: u32) -> u64 {
    ((round << 20) ^ (client as u64) ^ tag) ^ (((attempt as u64) - 1) << 52)
}

/// The contiguous cohort slice owned by shard `g` of `shards`: the
/// balanced partition `[g·len/shards, (g+1)·len/shards)`. Shard counts
/// beyond the cohort size yield empty slices, so any `--shards` value is
/// safe. Note what is deliberately *absent*: no shard-keyed RNG. A
/// per-shard fork feeding fault or client streams would make the bits a
/// function of the shard count; deriving every draw from the same pure
/// `(round, attempt, client)` keys makes shard identity irrelevant to
/// the bits, which is the stronger property (`--shards 1` ≡ `--shards G`,
/// enforced in `rust/tests/determinism.rs`).
pub fn shard_bounds(len: usize, shards: usize, g: usize) -> (usize, usize) {
    debug_assert!(g < shards, "shard {g} out of {shards}");
    (g * len / shards, (g + 1) * len / shards)
}

/// The algorithm-independent slice of one client's round contribution:
/// produced on a worker thread by [`RoundAlgorithm::client_step`], reduced
/// on the coordinator thread in cohort-slot order by the engine.
pub struct ClientOutput<P> {
    /// Aggregation weight p_i (dataset share).
    pub weight: f64,
    pub loss: f64,
    /// Raw metric sums in manifest order. Surviving clients must supply
    /// exactly [`RoundEnv::nmetrics`] entries (debug-asserted in the
    /// Aggregate reduction); dropped clients leave this empty.
    pub metric_sums: Vec<f64>,
    /// Relative quantization error (0 when not quantizing).
    pub quant_rel_err: f64,
    /// FedLite surrogate objective eq. (6) at this client's cut (0 when
    /// the algorithm has no cut or the run is unquantized).
    pub surrogate_loss: f64,
    /// The algorithm-specific survivor payload (gradients, model delta,
    /// …); `None` for dropped and evicted clients, which are excluded
    /// from every aggregate.
    pub payload: Option<P>,
    /// This client's metered transfers (merged after the barrier). Bytes
    /// sent before a mid-round failure are included — they crossed the
    /// wire.
    pub bytes: RoundBytes,
    /// Where the client's contribution was lost, if anywhere.
    pub dropped: Option<DropPhase>,
    /// Simulated straggler compute delay (feeds the round-time estimate).
    pub delay_seconds: f64,
}

impl<P> ClientOutput<P> {
    /// A failed client's partial contribution: the bytes it sent, nothing
    /// else.
    pub fn failed(
        phase: DropPhase,
        weight: f64,
        bytes: RoundBytes,
        delay_seconds: f64,
    ) -> ClientOutput<P> {
        ClientOutput {
            weight,
            loss: 0.0,
            metric_sums: Vec::new(),
            quant_rel_err: 0.0,
            surrogate_loss: 0.0,
            payload: None,
            bytes,
            dropped: Some(phase),
            delay_seconds,
        }
    }
}

/// Borrowed view of the round infrastructure an algorithm shares with the
/// engine. Everything the phase loop needs that is not algorithm-specific
/// comes through here, so the engine (and its tests) never depend on a
/// concrete trainer.
pub struct RoundEnv<'a> {
    pub net: &'a StarNetwork,
    pub sampler: &'a ClientSampler,
    pub faults: &'a FaultConfig,
    /// Root RNG; the engine only ever forks it (forking never advances
    /// the parent stream).
    pub rng: &'a Rng,
    pub metric: TaskMetric,
    /// Examples contributed per surviving client (the task batch size).
    pub batch_examples: f64,
    /// Number of raw metric sums each surviving client reports.
    pub nmetrics: usize,
    /// L2-norm bound applied to survivor payloads before aggregation
    /// (`--clip-norm`; 0 disables). Clipping runs in the Aggregate
    /// phase's flat slot-order loop via [`RoundAlgorithm::clip_payload`],
    /// so it is bit-identical at any worker/shard count.
    pub clip_norm: f64,
    /// Cohort fan-out width (resolved `--workers`).
    pub workers: usize,
    /// Independent cohort shards per round (`--shards`, >= 1). The cohort
    /// is partitioned into `shards` contiguous slices; each slice draws
    /// its own fault plans and runs its own worker fan-out, and the
    /// engine merges the shards' exact partials (survivors, drops, bytes,
    /// max-time) in shard order. All RNG keys stay pure functions of
    /// `(round, attempt, client)` — shard identity never feeds a key —
    /// so records are bit-identical at any shard count.
    pub shards: usize,
    /// Total rounds in the run (drives [`RoundEngine::run`]).
    pub rounds: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Sampling-attempt budget per round (trainers pass
    /// [`MAX_SAMPLING_ATTEMPTS`]; tests may shrink it).
    pub max_attempts: u32,
}

/// What an algorithm plugs into the engine: the payload-specific hooks of
/// the round protocol. Everything else — sampling, fault plans, fan-out,
/// reduction order, byte/time accounting, resampling, degraded commits,
/// record assembly — is the engine's, identical for every algorithm.
///
/// `Sync` is required because `client_step` runs concurrently on the
/// cohort workers against `&self`.
pub trait RoundAlgorithm: Sync {
    /// Per-round precomputed state (artifact metas, broadcast inputs);
    /// built once per round, shared read-only by the cohort workers, and
    /// handed back to [`RoundAlgorithm::commit`].
    type Prep: Sync;
    /// Algorithm-specific survivor payload carried by [`ClientOutput`].
    type Payload: Send;
    /// Survivor accumulator, reset at the start of every attempt.
    type Accum;
    /// Per-cohort-slot reusable working buffers, owned by the engine and
    /// lent to [`RoundAlgorithm::client_step`] for the step's duration.
    /// The pool persists across rounds, so warm scratches make repeated
    /// client steps allocation-quiet (the FedLite quantize path performs
    /// zero heap allocations after round 1). Use `()` when the algorithm
    /// has nothing to reuse.
    type Scratch: Send + Default;

    /// RNG stream tag distinguishing this algorithm's client work streams
    /// (see [`client_stream_key`]).
    fn stream_tag(&self) -> u64;

    /// The engine's borrowed view of the shared round infrastructure.
    fn env(&self) -> RoundEnv<'_>;

    /// Fetch per-round state (artifact metas, parameter snapshots). Runs
    /// before the round's byte meter opens — no network traffic here.
    fn prepare(&self, round: usize) -> anyhow::Result<Self::Prep>;

    /// Build the round's model broadcast. Called at most once per round:
    /// parameters can't change between attempts (aborts never touch the
    /// optimizers), so the payload is re-sent on resampled attempts.
    fn build_broadcast(&self, prep: &Self::Prep) -> Message;

    /// One client's full round pipeline, run on a worker thread. `plan`
    /// injects the client's scheduled faults; bytes sent before a failure
    /// must be returned in `ClientOutput::bytes` (they crossed the wire).
    /// `scratch` is this cohort slot's reusable buffer set — state left
    /// in it must never affect results (it is lent slot-by-slot, warm
    /// from arbitrary earlier rounds and attempts).
    #[allow(clippy::too_many_arguments)]
    fn client_step(
        &self,
        prep: &Self::Prep,
        broadcast: &Message,
        round: u32,
        client: usize,
        rng: &mut Rng,
        plan: &FaultPlan,
        scratch: &mut Self::Scratch,
    ) -> anyhow::Result<ClientOutput<Self::Payload>>;

    /// Fresh survivor accumulator for one attempt.
    fn new_accum(&self) -> Self::Accum;

    /// Fold one survivor's payload into the attempt's accumulator. Called
    /// in cohort-slot order with the client's aggregation weight.
    fn accumulate(&self, acc: &mut Self::Accum, payload: Self::Payload, weight: f64);

    /// Scale the payload down to the given L2-norm bound if it exceeds
    /// it; returns `true` when anything was scaled (the defense meter).
    /// Called by the engine only when `--clip-norm` is set, in the same
    /// flat slot-order loop as [`RoundAlgorithm::accumulate`]. Default:
    /// no-op, for algorithms without a clippable payload (mock tests).
    fn clip_payload(&self, _payload: &mut Self::Payload, _max_norm: f64) -> bool {
        false
    }

    /// Apply the committed round: step the optimizers on the survivor
    /// aggregate. `survivors` is `None` for a degraded commit (nobody
    /// survived, or the surviving weight mass is zero) — parameters must
    /// not move.
    fn commit(
        &mut self,
        prep: Self::Prep,
        survivors: Option<Self::Accum>,
        round: usize,
    ) -> anyhow::Result<()>;

    /// Evaluate the current model on held-out batches (loss, metric).
    fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)>;

    /// The run's CSV/JSONL writers (either may be absent).
    fn writers(&mut self) -> (&mut Option<CsvWriter>, &mut Option<JsonlWriter>);

    /// Emit the periodic progress log line for a committed record.
    fn log_round(&self, rec: &RoundRecord);

    // -- remote-execution hooks ------------------------------------------
    //
    // Socket deployments run `client_step` on worker processes holding a
    // replica trainer. These hooks move the round's mutable state and the
    // payloads across the wire as flat f32 tensor lists. All have
    // defaults, so in-process-only algorithms (and the engine's mock
    // tests) need not implement them.

    /// Per-round mutable state a replica must install before stepping
    /// (e.g. the split trainer's server-side parameters, which the
    /// broadcast does not carry). Empty when the broadcast alone fully
    /// determines `client_step`.
    fn round_state(&self, _prep: &Self::Prep) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Install a [`RoundAlgorithm::round_state`] snapshot received over
    /// the wire (replica side).
    fn install_round_state(&mut self, state: Vec<Vec<f32>>) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.is_empty(),
            "algorithm carries no round state, got {} tensors",
            state.len()
        );
        Ok(())
    }

    /// Install the round's decoded broadcast into the replica's own
    /// parameters (replica side; called before [`RoundAlgorithm::prepare`]
    /// so the replica's prep is built from the coordinator's parameters).
    fn install_broadcast(&mut self, _broadcast: &Message) -> anyhow::Result<()> {
        Ok(())
    }

    /// Flatten a survivor payload into wire tensors (replica side).
    fn payload_to_wire(&self, _payload: Self::Payload) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::bail!("algorithm has no wire payload codec")
    }

    /// Rebuild a survivor payload from wire tensors (coordinator side).
    /// Must be the exact inverse of [`RoundAlgorithm::payload_to_wire`] —
    /// f32 bits round-trip the wire unchanged, so aggregation over
    /// remote payloads is bit-identical to in-process.
    fn payload_from_wire(&self, _wire: Vec<Vec<f32>>) -> anyhow::Result<Self::Payload> {
        anyhow::bail!("algorithm has no wire payload codec")
    }
}

/// Everything one round produced before the commit: the survivor
/// aggregates plus the engine-side bookkeeping that becomes the record.
struct RoundOutcome<Acc> {
    accum: Acc,
    loss_agg: ScalarAggregator,
    qerr_agg: ScalarAggregator,
    surr_agg: ScalarAggregator,
    metric_sums: Vec<f64>,
    examples: f64,
    survivors: SurvivorSet,
    drops: DropCounts,
    /// Byte totals merged from the per-client partials, accumulated
    /// across *attempts* (aborted attempts really used the wire).
    bytes: RoundBytes,
    sim_seconds: f64,
    cohort_sampled: usize,
    attempts: u32,
    /// Committed-attempt cohort members whose plan carried an attack.
    byzantine_sampled: usize,
    /// Survivor payloads the clip-norm defense scaled down.
    clipped_updates: usize,
}

/// The generic round engine: drives [`RoundAlgorithm`] hooks through the
/// tick-based phase machine. See the module docs for the invariants.
pub struct RoundEngine<'a, A: RoundAlgorithm> {
    algo: &'a mut A,
    /// Per-cohort-slot scratch pool, lent to `client_step` and recovered
    /// after the round barrier. Grows to the largest cohort seen and then
    /// persists across rounds (the zero-allocation steady state).
    scratches: Vec<A::Scratch>,
    /// Where client steps execute (in-process threads by default).
    backend: Box<dyn ClientBackend<A> + 'a>,
}

impl<'a, A: RoundAlgorithm> RoundEngine<'a, A> {
    pub fn new(algo: &'a mut A) -> Self {
        Self::with_backend(algo, Box::new(InProcessBackend))
    }

    /// Build an engine whose client fan-out runs on the given backend.
    /// The phase machine, reduction order, and records are backend-
    /// independent; only the placement of `client_step` changes.
    pub fn with_backend(algo: &'a mut A, backend: Box<dyn ClientBackend<A> + 'a>) -> Self {
        RoundEngine { algo, scratches: Vec::new(), backend }
    }

    /// Run the configured number of rounds — the trainers' `run` entry
    /// point (logging, CSV/JSONL writing, and flushing included).
    pub fn run(&mut self) -> anyhow::Result<RunLog> {
        self.run_hooked(0, 0, |_, _| Ok(()))
    }

    /// Run rounds `start_round..rounds`, invoking `on_checkpoint(algo,
    /// completed_rounds)` after every `checkpoint_every`-th committed
    /// round (absolute cadence: rounds 0-indexed, fires when
    /// `(round + 1) % checkpoint_every == 0`; 0 disables). `start_round`
    /// supports `--resume`: round `r`'s bits depend only on `(r,
    /// attempt, client)` keys and the restored parameters, never on how
    /// many rounds this process already ran, so a resumed suffix is
    /// bit-identical to the same rounds of an uninterrupted run. Writers
    /// are flushed before each checkpoint so the on-disk logs never
    /// trail the snapshot.
    pub fn run_hooked(
        &mut self,
        start_round: usize,
        checkpoint_every: usize,
        mut on_checkpoint: impl FnMut(&mut A, usize) -> anyhow::Result<()>,
    ) -> anyhow::Result<RunLog> {
        let rounds = self.algo.env().rounds;
        let mut log = RunLog::default();
        for round in start_round..rounds {
            let rec = self.round(round)?;
            // after the commit: socket backends notify members here,
            // opening the between-rounds window in which they may leave
            self.backend.round_complete(round)?;
            if round == 0 || (round + 1) % 10 == 0 {
                self.algo.log_round(&rec);
            }
            let (csv, jsonl) = self.algo.writers();
            write_round(csv, jsonl, &rec)?;
            log.push(rec);
            if checkpoint_every > 0 && (round + 1) % checkpoint_every == 0 {
                let (csv, jsonl) = self.algo.writers();
                if let Some(c) = csv {
                    c.flush()?;
                }
                if let Some(j) = jsonl {
                    j.flush()?;
                }
                on_checkpoint(self.algo, round + 1)?;
            }
        }
        let (csv, jsonl) = self.algo.writers();
        if let Some(c) = csv {
            c.flush()?;
        }
        if let Some(j) = jsonl {
            j.flush()?;
        }
        Ok(log)
    }

    /// One full round through the phase machine; returns the committed
    /// round record.
    pub fn round(&mut self, round: usize) -> anyhow::Result<RoundRecord> {
        let t0 = Instant::now();
        let prep = self.algo.prepare(round)?;
        self.algo.env().net.begin_round();
        let outcome = drive(
            &*self.algo,
            &prep,
            round,
            &mut self.scratches,
            self.backend.as_mut(),
        );
        // close the round meter on *every* exit path: an error
        // mid-attempt must still archive this round's delta, or its bytes
        // bleed into the next round's delta and the per-round archive
        // desyncs from the records
        let meter_delta = self.algo.env().net.end_round();
        let outcome = outcome?;
        debug_assert_eq!(meter_delta, outcome.bytes, "meter vs merged partials");

        // degraded commit (no optimizer step) when nobody survived — or
        // when the survivors' total weight is zero, which would otherwise
        // renormalize into NaN aggregation weights
        let survived = outcome.survivors.survived();
        let committed = if survived > 0 && outcome.survivors.total_weight() > 0.0 {
            Some(outcome.accum)
        } else {
            None
        };
        self.algo.commit(prep, committed, round)?;

        let metric = self.algo.env().metric;
        // drain the backend's transport tally for this round (slot
        // reassignments, quarantined members) — always zero in-process
        let telemetry = self.backend.take_telemetry();
        let mut rec = RoundRecord {
            round,
            train_loss: outcome.loss_agg.mean(),
            train_metric: metric.value(&outcome.metric_sums, outcome.examples),
            quant_error: outcome.qerr_agg.mean(),
            uplink_bytes: outcome.bytes.up,
            downlink_bytes: outcome.bytes.down,
            cumulative_uplink: self.algo.env().net.totals().up,
            wall_seconds: t0.elapsed().as_secs_f64(),
            sim_comm_seconds: outcome.sim_seconds,
            cohort_sampled: outcome.cohort_sampled,
            cohort_survived: survived,
            byzantine_sampled: outcome.byzantine_sampled,
            rejected_codewords: outcome.drops.rejected_codeword,
            clipped_updates: outcome.clipped_updates,
            dropped: outcome.drops,
            attempts: outcome.attempts,
            surrogate_loss: outcome.surr_agg.mean(),
            reassigned_steps: telemetry.reassigned_steps,
            quarantined_members: telemetry.quarantined_members,
            ..Default::default()
        };
        let (eval_every, eval_batches) = {
            let env = self.algo.env();
            (env.eval_every, env.eval_batches)
        };
        if eval_every > 0 && (round % eval_every == eval_every - 1 || round == 0) {
            let (el, em) = self.algo.evaluate(eval_batches)?;
            rec.eval_loss = Some(el);
            rec.eval_metric = Some(em);
        }
        Ok(rec)
    }
}

/// The attempt loop: Sampling → Broadcast → ClientCompute → Aggregate,
/// rewinding on resample, until the phase machine reaches `Commit`. Pure
/// with respect to the algorithm (`&A`): optimizer movement happens in
/// [`RoundAlgorithm::commit`], outside.
fn drive<A: RoundAlgorithm>(
    algo: &A,
    prep: &A::Prep,
    round: usize,
    scratches: &mut Vec<A::Scratch>,
    backend: &mut dyn ClientBackend<A>,
) -> anyhow::Result<RoundOutcome<A::Accum>> {
    let env = algo.env();
    let shards = env.shards.max(1);
    let mut driver = RoundDriver::with_max_attempts(env.max_attempts);
    // carried across phases within one attempt
    let mut cohort: Vec<usize> = Vec::new();
    let mut plans: Vec<FaultPlan> = Vec::new();
    let mut broadcast: Option<Message> = None;
    let mut results: Vec<anyhow::Result<ClientOutput<A::Payload>>> = Vec::new();
    // carried across *attempts*: aborted attempts really used the wire
    // and the simulated clock, so bytes/time accumulate
    let mut bytes = RoundBytes::default();
    let mut sim_seconds = 0.0f64;
    // survivor aggregates of the attempt that commits
    let mut accum = algo.new_accum();
    let mut loss_agg = ScalarAggregator::new();
    let mut qerr_agg = ScalarAggregator::new();
    let mut surr_agg = ScalarAggregator::new();
    let mut metric_sums = vec![0.0f64; env.nmetrics];
    let mut examples = 0.0f64;
    let mut survivors = SurvivorSet::new();
    let mut drops = DropCounts::default();
    let mut clipped_updates = 0usize;

    loop {
        match driver.phase() {
            RoundPhase::Sampling => {
                // the cohort is sampled *globally* (one stream, unchanged
                // keys) and then partitioned into contiguous shard slices;
                // per-shard sampling would make membership depend on the
                // shard count and break `--shards` invariance
                let attempt = driver.attempt();
                cohort = env.sampler.sample(
                    &mut env.rng.fork(sample_key(round as u64, attempt)),
                    &[],
                );
                // each shard draws its own slice's fault plans; per-client
                // plans are pure functions of (round, attempt, client), so
                // the concatenation over slices is bit-identical to one
                // cohort-wide draw
                plans.clear();
                for g in 0..shards {
                    let (s, e) = shard_bounds(cohort.len(), shards, g);
                    plans.extend(env.faults.plans(
                        env.rng,
                        round as u64,
                        attempt,
                        &cohort[s..e],
                    ));
                }
                driver.advance();
            }
            RoundPhase::Broadcast => {
                // parameters can't change between attempts (aborts never
                // touch the optimizers), so the payload is built once and
                // re-sent on resampled attempts
                if broadcast.is_none() {
                    broadcast = Some(algo.build_broadcast(prep));
                }
                driver.advance();
            }
            RoundPhase::ClientCompute => {
                // Per-client RNG streams use pure (round, attempt, client)
                // fork keys; `fork` never advances the root stream, so the
                // fan-out is behavior-preserving at any worker and shard
                // count. Shards run their slices one after another, each
                // with its own worker fan-out, and hand back exact partials
                // (survivor/drop/byte counts, a max-time) that merge in
                // shard order. Floats that *sum* (losses, metrics,
                // payloads) are deliberately left to the Aggregate phase's
                // flat slot-order loop: float addition is non-associative,
                // so per-shard float partials would make the bits a
                // function of the shard count.
                let attempt = driver.attempt();
                // attempt-scoped exact partials (bytes/time accumulate
                // across attempts and are merged below instead)
                survivors = SurvivorSet::new();
                drops = DropCounts::default();
                let mut attempt_sim = 0.0f64;
                results = Vec::with_capacity(cohort.len());
                let mut per_client: Vec<(usize, usize, f64)> = Vec::new();
                let msg = broadcast.as_ref().expect("broadcast built");
                for g in 0..shards {
                    let (s, e) = shard_bounds(cohort.len(), shards, g);
                    let shard_cohort = &cohort[s..e];
                    // the backend owns *where* the steps run (in-process
                    // worker threads, socket members); it returns the
                    // shard's outputs in slot order and the engine folds
                    // them exactly as the unsharded reduction would
                    let outs = backend.run_shard(
                        algo,
                        prep,
                        msg,
                        round,
                        attempt,
                        shard_cohort,
                        &plans[s..e],
                        scratches,
                    );
                    // fold this shard's exact partials: integer counts, a
                    // weight-list concatenation, u64 byte sums, and an f64
                    // max — all order-exact, so the shard merge replays the
                    // unsharded slot-order reduction bit-for-bit
                    let mut shard_survivors = SurvivorSet::new();
                    let mut shard_drops = DropCounts::default();
                    let mut shard_bytes = RoundBytes::default();
                    per_client.clear();
                    for out in outs {
                        if let Ok(o) = &out {
                            shard_bytes.merge(&o.bytes);
                            per_client.push((
                                o.bytes.up as usize,
                                o.bytes.down as usize,
                                o.delay_seconds,
                            ));
                            match o.dropped {
                                Some(phase) => {
                                    shard_drops.add(phase);
                                    shard_survivors.dropped();
                                }
                                None => shard_survivors.survivor(o.weight),
                            }
                        }
                        results.push(out);
                    }
                    // a synchronous round waits for its slowest client, so
                    // the global round time is the max over the shard maxima
                    let shard_sim = env
                        .net
                        .estimate_round_time_with_delays(&per_client, env.faults.round_deadline);
                    survivors.merge(shard_survivors);
                    drops.merge(&shard_drops);
                    bytes.merge(&shard_bytes);
                    attempt_sim = attempt_sim.max(shard_sim);
                }
                sim_seconds += attempt_sim;
                driver.advance();
            }
            RoundPhase::Aggregate => {
                // reduce the floating-point partials in flat cohort-slot
                // order — the one order every shard count shares. The exact
                // bookkeeping (survivors, drops, bytes, time) was already
                // merged shard-by-shard in ClientCompute; everything that
                // sums in f64/f32 reduces here, so the records are
                // bit-identical at any worker *and* shard count.
                accum = algo.new_accum();
                loss_agg = ScalarAggregator::new();
                qerr_agg = ScalarAggregator::new();
                surr_agg = ScalarAggregator::new();
                metric_sums = vec![0.0f64; env.nmetrics];
                examples = 0.0;
                clipped_updates = 0;
                for result in std::mem::take(&mut results) {
                    let out = result?;
                    if out.dropped.is_none() {
                        debug_assert_eq!(
                            out.metric_sums.len(),
                            env.nmetrics,
                            "RoundAlgorithm contract: a surviving client's \
                             metric_sums must have exactly env().nmetrics entries"
                        );
                        loss_agg.add(out.loss, out.weight);
                        for (k, s) in metric_sums.iter_mut().enumerate() {
                            *s += out.metric_sums[k];
                        }
                        examples += env.batch_examples;
                        let mut payload =
                            out.payload.expect("surviving client carries a payload");
                        // the clip defense runs in the same flat slot-order
                        // loop as the accumulation, so the clipped bits are
                        // worker/shard-count independent like everything else
                        if env.clip_norm > 0.0
                            && algo.clip_payload(&mut payload, env.clip_norm)
                        {
                            clipped_updates += 1;
                        }
                        algo.accumulate(&mut accum, payload, out.weight);
                        qerr_agg.add(out.quant_rel_err, 1.0);
                        surr_agg.add(out.surrogate_loss, out.weight);
                    }
                }
                // survivor weights renormalize to a convex combination
                // (except the zero-mass degenerate case, which commits
                // degraded instead of dividing by zero)
                debug_assert!(
                    survivors.survived() == 0
                        || survivors.total_weight() <= 0.0
                        || (survivors.normalized().iter().sum::<f64>() - 1.0).abs() < 1e-9,
                    "survivor weights must renormalize to 1"
                );
                if env.faults.min_survivors > 0
                    && survivors.survived() < env.faults.min_survivors
                    && driver.resample()
                {
                    // too few survivors: abort the attempt (its bytes stay
                    // metered) and resample a fresh cohort without
                    // touching the optimizers
                    continue;
                }
                driver.advance();
            }
            RoundPhase::Commit => break,
        }
    }

    Ok(RoundOutcome {
        accum,
        loss_agg,
        qerr_agg,
        surr_agg,
        metric_sums,
        examples,
        survivors,
        drops,
        bytes,
        sim_seconds,
        cohort_sampled: cohort.len(),
        attempts: driver.attempt(),
        // `plans` still holds the committed attempt's draws — the same
        // scope as `cohort_sampled`
        byzantine_sampled: plans.iter().filter(|p| p.byz.is_some()).count(),
        clipped_updates,
    })
}

/// Open the run's CSV + JSONL writers under `cfg.out_dir` (none when the
/// out dir is empty). The column schema is
/// [`RoundRecord::CSV_COLUMNS`] — one source of truth shared with the CI
/// schema diff.
pub(crate) fn open_logs(
    cfg: &RunConfig,
) -> anyhow::Result<(Option<CsvWriter>, Option<JsonlWriter>)> {
    if cfg.out_dir.is_empty() {
        return Ok((None, None));
    }
    let base = format!(
        "{}/{}_{}_{}", cfg.out_dir, cfg.task, cfg.algorithm.name(), cfg.seed
    );
    let csv = CsvWriter::create(format!("{base}.csv"), &RoundRecord::CSV_COLUMNS)?;
    let jsonl = JsonlWriter::create(format!("{base}.jsonl"))?;
    Ok((Some(csv), Some(jsonl)))
}

/// Append one committed record to the run's writers.
pub(crate) fn write_round(
    csv: &mut Option<CsvWriter>,
    jsonl: &mut Option<JsonlWriter>,
    rec: &RoundRecord,
) -> anyhow::Result<()> {
    if let Some(c) = csv {
        c.row(&rec.csv_row())?;
    }
    if let Some(j) = jsonl {
        j.record(&rec.to_json())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::accounting::RoundBytes;

    #[test]
    fn phases_advance_in_order() {
        let mut d = RoundDriver::new();
        assert_eq!(d.phase(), RoundPhase::Sampling);
        assert_eq!(d.attempt(), 1);
        for want in [
            RoundPhase::Broadcast,
            RoundPhase::ClientCompute,
            RoundPhase::Aggregate,
            RoundPhase::Commit,
        ] {
            d.advance();
            assert_eq!(d.phase(), want);
        }
        d.advance(); // Commit is terminal
        assert_eq!(d.phase(), RoundPhase::Commit);
    }

    #[test]
    fn resample_rewinds_until_budget_exhausted() {
        let mut d = RoundDriver::with_max_attempts(3);
        for expected_attempt in [2u32, 3] {
            for _ in 0..3 {
                d.advance(); // to Aggregate
            }
            assert!(d.resample());
            assert_eq!(d.phase(), RoundPhase::Sampling);
            assert_eq!(d.attempt(), expected_attempt);
        }
        for _ in 0..3 {
            d.advance();
        }
        assert!(!d.resample(), "budget of 3 attempts is spent");
        assert_eq!(d.attempt(), 3);
        assert_eq!(d.phase(), RoundPhase::Aggregate);
    }

    #[test]
    fn first_attempt_keys_match_legacy_engine() {
        // bit-identity of clean runs depends on these exact values
        assert_eq!(sample_key(7, 1), 7);
        assert_eq!(
            client_stream_key(0xC11E, 3, 5, 1),
            ((3u64 << 20) ^ 5) ^ 0xC11E
        );
        assert_eq!(
            client_stream_key(0xFEDA, 3, 5, 1),
            ((3u64 << 20) ^ 5) ^ 0xFEDA
        );
    }

    #[test]
    fn later_attempts_get_distinct_keys() {
        assert_ne!(sample_key(7, 1), sample_key(7, 2));
        assert_ne!(sample_key(7, 2), sample_key(7, 3));
        assert_ne!(
            client_stream_key(0xC11E, 3, 5, 1),
            client_stream_key(0xC11E, 3, 5, 2)
        );
    }

    // -- engine semantics, driven through a mock algorithm -------------------

    const COHORT: usize = 4;

    fn clean_faults() -> FaultConfig {
        FaultConfig::default()
    }

    /// Minimal algorithm: every client downloads the broadcast (metered),
    /// then survives/drops per its fault plan. Lets the tests observe
    /// commit decisions and meter behavior without a full trainer.
    struct MockAlgo {
        net: StarNetwork,
        sampler: ClientSampler,
        faults: FaultConfig,
        rng: Rng,
        max_attempts: u32,
        shards: usize,
        /// Client index whose step fails with an error (the error path).
        fail_client: Option<usize>,
        /// Aggregation weight every survivor carries.
        weight: f64,
        /// One entry per committed round: did commit get an aggregate?
        committed: Vec<bool>,
        csv: Option<CsvWriter>,
        jsonl: Option<JsonlWriter>,
    }

    impl MockAlgo {
        fn new(faults: FaultConfig, max_attempts: u32) -> MockAlgo {
            MockAlgo {
                net: StarNetwork::with_defaults(COHORT),
                sampler: ClientSampler::uniform(COHORT, COHORT),
                faults,
                rng: Rng::new(0x7E57),
                max_attempts,
                shards: 1,
                fail_client: None,
                weight: 1.0,
                committed: Vec::new(),
                csv: None,
                jsonl: None,
            }
        }

        fn broadcast_wire_len() -> u64 {
            Message::ModelBroadcast { params: vec![vec![0.0f32; 4]] }.wire_len() as u64
        }
    }

    impl RoundAlgorithm for MockAlgo {
        type Prep = ();
        type Payload = ();
        type Accum = usize;
        type Scratch = ();

        fn stream_tag(&self) -> u64 {
            0x7E57
        }

        fn env(&self) -> RoundEnv<'_> {
            RoundEnv {
                net: &self.net,
                sampler: &self.sampler,
                faults: &self.faults,
                rng: &self.rng,
                metric: TaskMetric::Accuracy,
                batch_examples: 1.0,
                nmetrics: 0,
                clip_norm: 0.0,
                workers: 1,
                shards: self.shards,
                rounds: 1,
                eval_every: 0,
                eval_batches: 0,
                max_attempts: self.max_attempts,
            }
        }

        fn prepare(&self, _round: usize) -> anyhow::Result<()> {
            Ok(())
        }

        fn build_broadcast(&self, _prep: &()) -> Message {
            Message::ModelBroadcast { params: vec![vec![0.0f32; 4]] }
        }

        fn client_step(
            &self,
            _prep: &(),
            broadcast: &Message,
            round: u32,
            client: usize,
            _rng: &mut Rng,
            plan: &FaultPlan,
            _scratch: &mut (),
        ) -> anyhow::Result<ClientOutput<()>> {
            let (_, n) = self.net.download(client, round, broadcast)?;
            let bytes = RoundBytes::client(0, n, 0, 1);
            if self.fail_client == Some(client) {
                anyhow::bail!("injected client failure");
            }
            if let Some(phase) = plan.dropped() {
                return Ok(ClientOutput::failed(
                    phase,
                    self.weight,
                    bytes,
                    plan.delay_seconds,
                ));
            }
            Ok(ClientOutput {
                weight: self.weight,
                loss: 1.0,
                metric_sums: Vec::new(),
                quant_rel_err: 0.0,
                surrogate_loss: 0.0,
                payload: Some(()),
                bytes,
                dropped: None,
                delay_seconds: plan.delay_seconds,
            })
        }

        fn new_accum(&self) -> usize {
            0
        }

        fn accumulate(&self, acc: &mut usize, _payload: (), _weight: f64) {
            *acc += 1;
        }

        fn commit(
            &mut self,
            _prep: (),
            survivors: Option<usize>,
            _round: usize,
        ) -> anyhow::Result<()> {
            self.committed.push(survivors.is_some());
            Ok(())
        }

        fn evaluate(&mut self, _batches: usize) -> anyhow::Result<(f64, f64)> {
            Ok((0.0, 0.0))
        }

        fn writers(&mut self) -> (&mut Option<CsvWriter>, &mut Option<JsonlWriter>) {
            (&mut self.csv, &mut self.jsonl)
        }

        fn log_round(&self, _rec: &RoundRecord) {}
    }

    /// The error-path byte-accounting bugfix: a client step failing with
    /// an error must still close the round meter, so the aborted round's
    /// delta is archived and the next round's delta carries only its own
    /// bytes.
    #[test]
    fn error_mid_round_closes_the_byte_meter() {
        let mut m = MockAlgo::new(clean_faults(), MAX_SAMPLING_ATTEMPTS);
        m.fail_client = Some(1);
        assert!(RoundEngine::new(&mut m).round(0).is_err());
        assert!(m.committed.is_empty(), "a failed round must not commit");
        assert_eq!(
            m.net.meter.per_round().len(),
            1,
            "the aborted round's delta must be archived"
        );

        m.fail_client = None;
        let rec = RoundEngine::new(&mut m).round(1).unwrap();
        let per_round = m.net.meter.per_round();
        assert_eq!(per_round.len(), 2);
        let one_round = COHORT as u64 * MockAlgo::broadcast_wire_len();
        // without the fix, round 1's delta would also contain round 0's
        // leaked bytes (2x the cohort broadcast)
        assert_eq!(per_round[0].down, one_round);
        assert_eq!(per_round[1].down, one_round);
        assert_eq!(rec.downlink_bytes, one_round);
        assert_eq!(m.committed, vec![true]);
    }

    /// A cohort whose survivors all carry weight zero must commit degraded
    /// (no optimizer step) instead of renormalizing into NaN weights.
    #[test]
    fn zero_total_weight_commits_degraded() {
        let mut m = MockAlgo::new(clean_faults(), MAX_SAMPLING_ATTEMPTS);
        m.weight = 0.0;
        let rec = RoundEngine::new(&mut m).round(0).unwrap();
        assert_eq!(rec.cohort_survived, COHORT);
        assert_eq!(rec.attempts, 1);
        assert_eq!(
            m.committed,
            vec![false],
            "zero-weight survivors must not step the optimizer"
        );
        assert_eq!(rec.train_loss, 0.0, "zero weight mass yields no loss signal");
    }

    /// `max_attempts = 1`: the resample path is disabled — one failed
    /// floor check commits degraded immediately.
    #[test]
    fn max_attempts_one_commits_degraded_without_resampling() {
        let faults = FaultConfig {
            drop_prob: 1.0,
            min_survivors: 1,
            ..FaultConfig::default()
        };
        let mut m = MockAlgo::new(faults, 1);
        let rec = RoundEngine::new(&mut m).round(0).unwrap();
        assert_eq!(rec.attempts, 1, "no resampling budget");
        assert_eq!(rec.cohort_survived, 0);
        assert_eq!(rec.dropped.total(), COHORT);
        assert_eq!(m.committed, vec![false]);
    }

    /// A survivor floor above the cohort size can never be met: the round
    /// exhausts its attempt budget, then commits with whoever survived
    /// (the optimizer still steps — survivors exist).
    #[test]
    fn floor_above_cohort_exhausts_budget_then_commits_survivors() {
        let faults = FaultConfig {
            min_survivors: COHORT + 1,
            ..FaultConfig::default()
        };
        let mut m = MockAlgo::new(faults, 4);
        let rec = RoundEngine::new(&mut m).round(0).unwrap();
        assert_eq!(rec.attempts, 4, "budget fully spent on an unreachable floor");
        assert_eq!(rec.cohort_survived, COHORT);
        assert_eq!(m.committed, vec![true], "whoever survived still commits");
        // every aborted attempt broadcast to its whole cohort: bytes from
        // all 4 attempts are metered and merged into the one record
        let expect = 4 * COHORT as u64 * MockAlgo::broadcast_wire_len();
        assert_eq!(rec.downlink_bytes, expect);
        assert_eq!(m.net.meter.per_round()[0].down, expect);
    }

    #[test]
    fn shard_bounds_partition_the_cohort() {
        for (len, shards) in [(10usize, 3usize), (4, 4), (4, 7), (0, 2), (100, 1)] {
            let mut covered = 0;
            for g in 0..shards {
                let (s, e) = shard_bounds(len, shards, g);
                assert!(s <= e && e <= len, "bad slice {s}..{e} of {len}");
                assert_eq!(s, covered, "gap or overlap at shard {g}");
                covered = e;
            }
            assert_eq!(covered, len, "partition must cover the cohort");
        }
    }

    /// The tentpole invariance at engine level: a faulty round produces
    /// bit-identical records at any shard count, including shard counts
    /// beyond the cohort size (empty slices).
    #[test]
    fn shard_count_leaves_round_records_bit_identical() {
        let faults = FaultConfig {
            drop_prob: 0.4,
            straggler_frac: 0.5,
            round_deadline: 0.05,
            min_survivors: 1,
            ..FaultConfig::default()
        };
        let run = |shards: usize| {
            let mut m = MockAlgo::new(faults, 4);
            m.shards = shards;
            let rec = RoundEngine::new(&mut m).round(0).unwrap();
            (rec, m.committed)
        };
        let (base, base_committed) = run(1);
        for shards in [2, 3, COHORT, COHORT + 5] {
            let (rec, committed) = run(shards);
            assert_eq!(rec.train_loss.to_bits(), base.train_loss.to_bits());
            assert_eq!(
                rec.sim_comm_seconds.to_bits(),
                base.sim_comm_seconds.to_bits(),
                "round time must merge exactly across {shards} shards"
            );
            assert_eq!(rec.uplink_bytes, base.uplink_bytes);
            assert_eq!(rec.downlink_bytes, base.downlink_bytes);
            assert_eq!(rec.cohort_sampled, base.cohort_sampled);
            assert_eq!(rec.cohort_survived, base.cohort_survived);
            assert_eq!(rec.dropped, base.dropped);
            assert_eq!(rec.attempts, base.attempts);
            assert_eq!(committed, base_committed);
        }
    }
}
