//! Tick-based round phase driver shared by the trainers.
//!
//! Every federated round is an explicit state machine (in the style of the
//! Psyche coordinator's `RunState`/`tick` loop):
//!
//! ```text
//! Sampling → Broadcast → ClientCompute → Aggregate → Commit
//!     ▲                                      │
//!     └────────── resample (too few ─────────┘
//!                 survivors, attempt += 1)
//! ```
//!
//! The driver owns only the phase/attempt bookkeeping; the trainers own
//! the per-phase work. `Aggregate` may rewind to `Sampling` when the
//! surviving cohort is smaller than `min_survivors` — each rewind is a new
//! *attempt* with fresh sampling and fault-schedule RNG keys. The attempt
//! budget is bounded so a pathological fault config degrades (commit with
//! whatever survived, possibly nobody, and no optimizer step) instead of
//! livelocking.
//!
//! All RNG keys are pure functions of `(round, attempt, client)` — never
//! of wall-clock or thread identity — so the engine stays bit-identical at
//! any `--workers` count (see `rust/tests/determinism.rs`).

/// The phases of one federated round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPhase {
    /// Pick the round's cohort and draw its fault schedules.
    Sampling,
    /// Build the model broadcast shared by the cohort.
    Broadcast,
    /// Fan the cohort across the worker threads (the round barrier).
    ClientCompute,
    /// Reduce partials in cohort-slot order; decide survive/resample.
    Aggregate,
    /// Step the optimizers on the survivor aggregate and emit the record.
    Commit,
}

/// Upper bound on sampling attempts per round before the round commits
/// degraded (fewer survivors than `min_survivors`, no optimizer step when
/// nobody survived). Bounds the resample loop deterministically.
pub const MAX_SAMPLING_ATTEMPTS: u32 = 16;

/// Phase/attempt bookkeeping for one round.
#[derive(Debug)]
pub struct RoundDriver {
    phase: RoundPhase,
    attempt: u32,
    max_attempts: u32,
}

impl RoundDriver {
    pub fn new() -> Self {
        Self::with_max_attempts(MAX_SAMPLING_ATTEMPTS)
    }

    pub fn with_max_attempts(max_attempts: u32) -> Self {
        RoundDriver {
            phase: RoundPhase::Sampling,
            attempt: 1,
            max_attempts: max_attempts.max(1),
        }
    }

    pub fn phase(&self) -> RoundPhase {
        self.phase
    }

    /// 1-based sampling attempt (1 = the round committed first try).
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// Advance to the next phase in order; `Commit` is terminal.
    pub fn advance(&mut self) {
        self.phase = match self.phase {
            RoundPhase::Sampling => RoundPhase::Broadcast,
            RoundPhase::Broadcast => RoundPhase::ClientCompute,
            RoundPhase::ClientCompute => RoundPhase::Aggregate,
            RoundPhase::Aggregate | RoundPhase::Commit => RoundPhase::Commit,
        };
    }

    /// Called from `Aggregate` when the surviving cohort is too small.
    /// Rewinds to `Sampling` with the next attempt and returns `true`
    /// while budget remains; returns `false` once the attempt budget is
    /// exhausted (caller proceeds to a degraded `Commit`).
    pub fn resample(&mut self) -> bool {
        debug_assert_eq!(self.phase, RoundPhase::Aggregate, "resample outside Aggregate");
        if self.attempt >= self.max_attempts {
            return false;
        }
        self.attempt += 1;
        self.phase = RoundPhase::Sampling;
        true
    }
}

impl Default for RoundDriver {
    fn default() -> Self {
        Self::new()
    }
}

/// Fork key for the round's cohort sampling. Attempt 1 must reproduce the
/// pre-fault engine exactly (`fork(round)`), so clean configs stay
/// bit-identical to historical logs; later attempts mix the attempt in.
pub fn sample_key(round: u64, attempt: u32) -> u64 {
    if attempt <= 1 {
        round
    } else {
        round ^ ((attempt as u64) << 48) ^ 0x5EED_0A17
    }
}

/// Fork key for one client's round work stream. `tag` distinguishes the
/// trainers (split: `0xC11E`, fedavg: `0xFEDA` — unchanged from the serial
/// engine); attempt 1 reproduces the historical key exactly.
pub fn client_stream_key(tag: u64, round: u64, client: usize, attempt: u32) -> u64 {
    ((round << 20) ^ (client as u64) ^ tag) ^ (((attempt as u64) - 1) << 52)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_advance_in_order() {
        let mut d = RoundDriver::new();
        assert_eq!(d.phase(), RoundPhase::Sampling);
        assert_eq!(d.attempt(), 1);
        for want in [
            RoundPhase::Broadcast,
            RoundPhase::ClientCompute,
            RoundPhase::Aggregate,
            RoundPhase::Commit,
        ] {
            d.advance();
            assert_eq!(d.phase(), want);
        }
        d.advance(); // Commit is terminal
        assert_eq!(d.phase(), RoundPhase::Commit);
    }

    #[test]
    fn resample_rewinds_until_budget_exhausted() {
        let mut d = RoundDriver::with_max_attempts(3);
        for expected_attempt in [2u32, 3] {
            for _ in 0..3 {
                d.advance(); // to Aggregate
            }
            assert!(d.resample());
            assert_eq!(d.phase(), RoundPhase::Sampling);
            assert_eq!(d.attempt(), expected_attempt);
        }
        for _ in 0..3 {
            d.advance();
        }
        assert!(!d.resample(), "budget of 3 attempts is spent");
        assert_eq!(d.attempt(), 3);
        assert_eq!(d.phase(), RoundPhase::Aggregate);
    }

    #[test]
    fn first_attempt_keys_match_legacy_engine() {
        // bit-identity of clean runs depends on these exact values
        assert_eq!(sample_key(7, 1), 7);
        assert_eq!(
            client_stream_key(0xC11E, 3, 5, 1),
            ((3u64 << 20) ^ 5) ^ 0xC11E
        );
        assert_eq!(
            client_stream_key(0xFEDA, 3, 5, 1),
            ((3u64 << 20) ^ 5) ^ 0xFEDA
        );
    }

    #[test]
    fn later_attempts_get_distinct_keys() {
        assert_ne!(sample_key(7, 1), sample_key(7, 2));
        assert_ne!(sample_key(7, 2), sample_key(7, 3));
        assert_ne!(
            client_stream_key(0xC11E, 3, 5, 1),
            client_stream_key(0xC11E, 3, 5, 2)
        );
    }
}
