//! Binary checkpoints for model parameters + JSON config sidecar.
//!
//! Format: `FLCK` magic, version u32, tensor count u32, then per tensor:
//! name (u32 len + utf8), rank u32, dims u32..., f32 data (LE). Version
//! 2 appends a `completed_rounds` u64 trailer so `--resume` knows which
//! round the run should continue from; version-1 files (no trailer)
//! still load and resume from round 0. The config sidecar
//! (`<path>.config.json`) lets a run resume with the exact settings
//! that produced the checkpoint.

use std::fs::{self, File};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::config::RunConfig;
use crate::tensor::{Tensor, TensorList};
use crate::util::json;

const MAGIC: &[u8; 4] = b"FLCK";
const VERSION: u32 = 2;

/// Save client+server parameter lists plus the number of rounds already
/// committed (`0` for a final checkpoint nobody will resume).
pub fn save(
    path: impl AsRef<Path>,
    wc: &TensorList,
    ws: &TensorList,
    cfg: Option<&RunConfig>,
    completed_rounds: usize,
) -> anyhow::Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    for (label, tl) in [("client", wc), ("server", ws)] {
        w.write_all(&(tl.len() as u32).to_le_bytes())?;
        for (name, t) in tl.names.iter().zip(&tl.tensors) {
            let full = format!("{label}/{name}");
            w.write_all(&(full.len() as u32).to_le_bytes())?;
            w.write_all(full.as_bytes())?;
            w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &v in t.data() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
    }
    w.write_all(&(completed_rounds as u64).to_le_bytes())?;
    w.flush()?;
    if let Some(cfg) = cfg {
        fs::write(
            path.with_extension("config.json"),
            cfg.to_json().to_string_pretty(),
        )?;
    }
    Ok(())
}

/// Load client+server parameter lists (progress trailer discarded).
pub fn load(path: impl AsRef<Path>) -> anyhow::Result<(TensorList, TensorList)> {
    let (wc, ws, _) = load_resume(path)?;
    Ok((wc, ws))
}

/// Load client+server parameter lists plus the `completed_rounds`
/// trailer (`0` for version-1 checkpoints, which predate it).
pub fn load_resume(
    path: impl AsRef<Path>,
) -> anyhow::Result<(TensorList, TensorList, usize)> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a fedlite checkpoint");
    let version = read_u32(&mut r)?;
    anyhow::ensure!(
        (1..=VERSION).contains(&version),
        "unsupported checkpoint version {version}"
    );
    let mut sides = Vec::new();
    for label in ["client", "server"] {
        let n = read_u32(&mut r)? as usize;
        let mut names = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            let mut name_buf = vec![0u8; name_len];
            r.read_exact(&mut name_buf)?;
            let full = String::from_utf8(name_buf)?;
            let name = full
                .strip_prefix(&format!("{label}/"))
                .ok_or_else(|| anyhow::anyhow!("checkpoint side mismatch: {full}"))?
                .to_string();
            let rank = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u32(&mut r)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0f32; numel];
            let mut buf = vec![0u8; numel * 4];
            r.read_exact(&mut buf)?;
            for (v, c) in data.iter_mut().zip(buf.chunks_exact(4)) {
                *v = f32::from_le_bytes(c.try_into().unwrap());
            }
            names.push(name);
            tensors.push(Tensor::from_vec(&shape, data));
        }
        sides.push(TensorList::new(names, tensors));
    }
    let server = sides.pop().unwrap();
    let client = sides.pop().unwrap();
    let completed_rounds = if version >= 2 {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        u64::from_le_bytes(b) as usize
    } else {
        0
    };
    Ok((client, server, completed_rounds))
}

/// Load the config sidecar if present.
pub fn load_config(path: impl AsRef<Path>) -> anyhow::Result<Option<RunConfig>> {
    let p = path.as_ref().with_extension("config.json");
    if !p.exists() {
        return Ok(None);
    }
    let v = json::parse(&fs::read_to_string(p)?)?;
    Ok(Some(RunConfig::from_json(&v)?))
}

fn read_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_params() -> (TensorList, TensorList) {
        let mut rng = Rng::new(0);
        let wc = TensorList::new(
            vec!["conv_w".into(), "conv_b".into()],
            vec![
                Tensor::from_vec(&[2, 3], rng.normal_vec(6, 0.0, 1.0)),
                Tensor::from_vec(&[3], rng.normal_vec(3, 0.0, 1.0)),
            ],
        );
        let ws = TensorList::new(
            vec!["dense_w".into()],
            vec![Tensor::from_vec(&[3, 4], rng.normal_vec(12, 0.0, 1.0))],
        );
        (wc, ws)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fedlite-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let (wc, ws) = sample_params();
        let p = tmp("a.ckpt");
        save(&p, &wc, &ws, None, 0).unwrap();
        let (wc2, ws2) = load(&p).unwrap();
        assert_eq!(wc2.names, wc.names);
        for (a, b) in wc2.tensors.iter().zip(&wc.tensors) {
            assert_eq!(a.data(), b.data());
            assert_eq!(a.shape(), b.shape());
        }
        assert_eq!(ws2.tensors[0].data(), ws.tensors[0].data());
    }

    #[test]
    fn config_sidecar_roundtrip() {
        let (wc, ws) = sample_params();
        let p = tmp("b.ckpt");
        let mut cfg = RunConfig::preset("femnist").unwrap();
        cfg.rounds = 77;
        save(&p, &wc, &ws, Some(&cfg), 0).unwrap();
        let back = load_config(&p).unwrap().unwrap();
        assert_eq!(back.rounds, 77);
        assert_eq!(back.task, "femnist");
    }

    #[test]
    fn progress_trailer_roundtrips_and_v1_reads_as_zero() {
        let (wc, ws) = sample_params();
        let p = tmp("d.ckpt");
        save(&p, &wc, &ws, None, 42).unwrap();
        let (_, _, done) = load_resume(&p).unwrap();
        assert_eq!(done, 42);

        // a version-1 checkpoint is the same stream without the trailer;
        // rewrite the header version and strip the last 8 bytes
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        bytes.truncate(bytes.len() - 8);
        let p1 = tmp("d1.ckpt");
        std::fs::write(&p1, bytes).unwrap();
        let (wc1, _, done1) = load_resume(&p1).unwrap();
        assert_eq!(done1, 0, "v1 checkpoints predate the trailer");
        assert_eq!(wc1.names, wc.names);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("c.ckpt");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load(&p).is_err());
    }
}
