//! FedAvg baseline (McMahan et al., 2017) on the generic engine.
//!
//! Each selected client receives the whole model (downlink |w|), runs `H`
//! local SGD steps using the `full_grad` artifact, and uploads its model
//! delta (uplink |w|). The server applies the weighted-mean delta. This is
//! the comparison line of Table 1 and Figure 6: more client compute and
//! memory, |w| per round instead of activations.
//!
//! The round protocol itself — sampling, fault plans, fan-out, slot-order
//! reduction, byte accounting, resampling, degraded commits — is
//! [`crate::coordinator::engine::RoundEngine`]'s, shared verbatim with the
//! split trainer, so the cross-algorithm communication comparison is
//! apples-to-apples; this module only supplies the FedAvg payload hooks
//! ([`crate::coordinator::engine::RoundAlgorithm`]). FedAvg has no
//! activation upload, so every mid-round drop phase collapses to "died
//! before the delta upload" ([`DropPhase::BeforeGradUpload`]): the
//! broadcast downlink is metered, nothing comes back. Deadline-evicted
//! stragglers upload their delta (metered) but the aggregate ignores it.

use std::sync::Arc;

use crate::comm::accounting::RoundBytes;
use crate::comm::message::{self, Message};
use crate::comm::StarNetwork;
use crate::config::{ByzantineKind, RunConfig};
use crate::coordinator::aggregator::{clip_to_norm, ScalarAggregator, UpdateAggregator};
use crate::coordinator::client::{assemble, draw_masks, InputSources};
use crate::coordinator::engine::{
    open_logs, ClientOutput, RoundAlgorithm, RoundEngine, RoundEnv, MAX_SAMPLING_ATTEMPTS,
};
use crate::coordinator::faults::{self, DropPhase, FaultConfig, FaultPlan};
use crate::coordinator::sampler::ClientSampler;
use crate::coordinator::split::{arrays_to_tensors, scalar};
use crate::coordinator::Trainer;
use crate::data::FederatedDataset;
use crate::metrics::{RoundRecord, RunLog, TaskMetric};
use crate::models::ModelSpec;
use crate::optim::Optimizer;
use crate::runtime::native::EngineScratch;
use crate::runtime::{ArtifactMeta, Runtime};
use crate::tensor::TensorList;
use crate::util::logging::{CsvWriter, JsonlWriter};
use crate::util::rng::Rng;

pub struct FedAvgTrainer {
    cfg: RunConfig,
    rt: Arc<Runtime>,
    data: Arc<dyn FederatedDataset>,
    spec: ModelSpec,
    wc: TensorList,
    ws: TensorList,
    /// Server optimizer applied to the aggregated pseudo-gradient
    /// (delta); plain SGD with lr=1.0 recovers vanilla FedAvg.
    opt: Box<dyn Optimizer>,
    net: StarNetwork,
    sampler: ClientSampler,
    metric: TaskMetric,
    faults: FaultConfig,
    rng: Rng,
    csv: Option<CsvWriter>,
    jsonl: Option<JsonlWriter>,
    /// Warm engine buffers for the eval pass.
    eval_scratch: EngineScratch,
}

/// Per-cohort-slot reusable buffers for the FedAvg client step: the
/// native engine's forward/backward intermediates, reused across the H
/// local steps and across rounds (the model-sized delta tensors are the
/// payload and are not reusable).
#[derive(Default)]
pub struct FedAvgScratch {
    engine: EngineScratch,
}

/// Per-round state shared by the cohort: the artifact handle plus the
/// round's whole-model snapshot (handed back to `commit`, which steps it).
pub struct FedAvgPrep {
    variant: String,
    grad_meta: ArtifactMeta,
    global: TensorList,
    shapes: Vec<Vec<usize>>,
}

impl FedAvgTrainer {
    pub fn new(
        cfg: RunConfig,
        rt: Arc<Runtime>,
        data: Arc<dyn FederatedDataset>,
    ) -> anyhow::Result<Self> {
        let variant = cfg.variant();
        let spec = rt.manifest.variant(&variant)?.spec.clone();
        let rng = Rng::new(cfg.seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let (csv, jsonl) = open_logs(&cfg)?;
        Ok(FedAvgTrainer {
            sampler: ClientSampler::uniform(cfg.num_clients, cfg.clients_per_round),
            net: StarNetwork::with_defaults(cfg.num_clients),
            opt: crate::optim::build("sgd", 1.0)?,
            metric: TaskMetric::for_task(&cfg.task),
            faults: FaultConfig::from_run(&cfg),
            spec,
            wc,
            ws,
            rng,
            data,
            rt,
            cfg,
            csv,
            jsonl,
            eval_scratch: EngineScratch::new(),
        })
    }

    /// Concatenated (client+server) parameter list as one TensorList.
    fn full_params(&self) -> TensorList {
        let mut names = self.wc.names.clone();
        names.extend(self.ws.names.clone());
        let mut tensors = self.wc.tensors.clone();
        tensors.extend(self.ws.tensors.clone());
        TensorList::new(names, tensors)
    }

    fn split_back(&mut self, full: TensorList) {
        let nc = self.wc.len();
        let (ct, st) = full.tensors.split_at(nc);
        self.wc = TensorList::new(self.wc.names.clone(), ct.to_vec());
        self.ws = TensorList::new(self.ws.names.clone(), st.to_vec());
    }

    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        let variant = self.cfg.variant();
        let meta = self.rt.manifest.artifact(&variant, "full_eval")?.clone();
        let mut loss = ScalarAggregator::new();
        let mut sums = vec![0.0f64; self.spec.metrics.len()];
        let mut examples = 0.0f64;
        let mut rng = self.rng.fork(0xE7A1);
        for _ in 0..batches {
            let batch = self.data.eval_batch(self.spec.eval_batch, &mut rng);
            let src = InputSources {
                wc: Some(&self.wc),
                ws: Some(&self.ws),
                batch: Some(&batch),
                ..Default::default()
            };
            let inputs = assemble(&meta, &src)?;
            let outs = self
                .rt
                .run_scratch(&variant, "full_eval", &inputs, &mut self.eval_scratch)?;
            loss.add(scalar(&outs[0])? as f64, 1.0);
            for (k, s) in sums.iter_mut().enumerate() {
                *s += scalar(&outs[1 + k])? as f64;
            }
            examples += self.spec.eval_batch as f64;
        }
        Ok((loss.mean(), self.metric.value(&sums, examples)))
    }
}

impl RoundAlgorithm for FedAvgTrainer {
    type Prep = FedAvgPrep;
    /// Wire-decoded model delta (global − local after H steps).
    type Payload = TensorList;
    type Accum = UpdateAggregator;
    type Scratch = FedAvgScratch;

    fn stream_tag(&self) -> u64 {
        0xFEDA
    }

    fn env(&self) -> RoundEnv<'_> {
        RoundEnv {
            net: &self.net,
            sampler: &self.sampler,
            faults: &self.faults,
            rng: &self.rng,
            metric: self.metric,
            batch_examples: self.spec.batch as f64,
            nmetrics: self.spec.metrics.len(),
            clip_norm: self.cfg.clip_norm,
            workers: self.cfg.resolved_workers(),
            shards: self.cfg.shards,
            rounds: self.cfg.rounds,
            eval_every: self.cfg.eval_every,
            eval_batches: self.cfg.eval_batches,
            max_attempts: MAX_SAMPLING_ATTEMPTS,
        }
    }

    fn prepare(&self, _round: usize) -> anyhow::Result<FedAvgPrep> {
        let variant = self.cfg.variant();
        let global = self.full_params();
        let shapes: Vec<Vec<usize>> =
            global.tensors.iter().map(|t| t.shape().to_vec()).collect();
        Ok(FedAvgPrep {
            grad_meta: self.rt.manifest.artifact(&variant, "full_grad")?.clone(),
            variant,
            global,
            shapes,
        })
    }

    fn build_broadcast(&self, prep: &FedAvgPrep) -> Message {
        Message::ModelBroadcast { params: message::tensors_to_payload(&prep.global) }
    }

    fn client_step(
        &self,
        prep: &FedAvgPrep,
        broadcast: &Message,
        round: u32,
        ci: usize,
        crng: &mut Rng,
        plan: &FaultPlan,
        scratch: &mut FedAvgScratch,
    ) -> anyhow::Result<ClientOutput<TensorList>> {
        let nmetrics = self.spec.metrics.len();
        let mut up = 0usize;
        let mut down = 0usize;
        let weight = self.data.client_weight(ci).max(1e-12);
        let nc = self.wc.len();

        // broadcast whole model (downlink |w|)
        let (decoded, n) = self.net.download(ci, round, broadcast)?;
        down += n;
        if plan.drop_at.is_some() {
            // FedAvg's only uplink is the delta, so every mid-round drop
            // collapses to "died before the delta upload": the broadcast
            // is metered, nothing comes back
            return Ok(ClientOutput::failed(
                DropPhase::BeforeGradUpload,
                weight,
                RoundBytes::client(0, down, 0, 1),
                plan.delay_seconds,
            ));
        }
        let mut local = match decoded {
            Message::ModelBroadcast { params } => {
                message::payload_to_tensors(&params, &prep.shapes, &prep.global.names)
            }
            _ => anyhow::bail!("wrong broadcast"),
        };

        // H local SGD steps
        let mut loss = 0.0f64;
        let mut metric_sums = vec![0.0f64; nmetrics];
        for step in 0..self.cfg.local_steps {
            let mut batch = self.data.train_batch(ci, self.spec.batch, crng);
            if plan.byz == Some(ByzantineKind::LabelFlip) {
                // every local step trains on rotated labels (no RNG drawn)
                faults::poison_labels(&mut batch.y, self.spec.batch);
            }
            let masks = draw_masks(
                &[&prep.grad_meta],
                self.cfg.dropout_client,
                self.cfg.dropout_server,
                crng,
            );
            let (lc, ls) = local.tensors.split_at(nc);
            let lwc = TensorList::new(self.wc.names.to_vec(), lc.to_vec());
            let lws = TensorList::new(self.ws.names.to_vec(), ls.to_vec());
            let src = InputSources {
                wc: Some(&lwc),
                ws: Some(&lws),
                batch: Some(&batch),
                masks: Some(&masks),
                ..Default::default()
            };
            let outs = self.rt.run_scratch(
                &prep.variant,
                "full_grad",
                &assemble(&prep.grad_meta, &src)?,
                &mut scratch.engine,
            )?;
            if step == 0 {
                loss = scalar(&outs[0])? as f64;
                for (k, s) in metric_sums.iter_mut().enumerate() {
                    *s = scalar(&outs[1 + k])? as f64;
                }
            }
            let grads = arrays_to_tensors(&outs[1 + nmetrics..], &prep.global)?;
            local.axpy(-self.cfg.client_lr, &grads);
        }

        // upload model delta (uplink |w|)
        let mut delta = prep.global.clone();
        delta.axpy(-1.0, &local); // delta = global - local = lr * sum grads
        // byzantine payload attacks, applied before the wire upload so
        // socket replicas ship the same poisoned bits; sizes unchanged.
        // CorruptCodeword has no codeword channel here — FedAvg ships raw
        // deltas — so flagged clients behave honestly under it.
        match plan.byz {
            Some(ByzantineKind::GradScale) => delta.scale(faults::GRAD_SCALE),
            Some(ByzantineKind::SignFlip) => delta.scale(-1.0),
            Some(ByzantineKind::Replay) => delta.scale(0.0),
            _ => {}
        }
        let up_msg = Message::ClientGrads { grads: message::tensors_to_payload(&delta) };
        let (decoded, n) = self.net.upload(ci, round, &up_msg)?;
        up += n;
        let delta_wire = match decoded {
            Message::ClientGrads { grads } => {
                message::payload_to_tensors(&grads, &prep.shapes, &prep.global.names)
            }
            _ => anyhow::bail!("wrong upload"),
        };

        let bytes = RoundBytes::client(up, down, 1, 1);
        if plan.evicted {
            // straggler past the deadline: the delta arrived (and is
            // metered), but too late to join the aggregate
            return Ok(ClientOutput::failed(
                DropPhase::Deadline,
                weight,
                bytes,
                plan.delay_seconds,
            ));
        }
        Ok(ClientOutput {
            weight,
            loss,
            metric_sums,
            quant_rel_err: 0.0,
            surrogate_loss: 0.0,
            payload: Some(delta_wire),
            bytes,
            dropped: None,
            delay_seconds: plan.delay_seconds,
        })
    }

    fn new_accum(&self) -> UpdateAggregator {
        UpdateAggregator::new(self.cfg.aggregation)
    }

    fn accumulate(&self, acc: &mut UpdateAggregator, delta: TensorList, weight: f64) {
        acc.add(&delta, weight);
    }

    fn clip_payload(&self, delta: &mut TensorList, max_norm: f64) -> bool {
        clip_to_norm(&mut [delta], max_norm)
    }

    fn commit(
        &mut self,
        prep: FedAvgPrep,
        survivors: Option<UpdateAggregator>,
        round: usize,
    ) -> anyhow::Result<()> {
        // pseudo-gradient step: w <- w - 1.0 * mean(delta); skipped on a
        // degraded commit
        let mut full = prep.global;
        if let Some(agg) = survivors {
            if let Some(delta) = agg.finish() {
                self.opt.step(&mut full, &delta);
            }
        }
        anyhow::ensure!(full.is_finite(), "parameters diverged at round {round}");
        self.split_back(full);
        Ok(())
    }

    fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        FedAvgTrainer::evaluate(self, batches)
    }

    fn writers(&mut self) -> (&mut Option<CsvWriter>, &mut Option<JsonlWriter>) {
        (&mut self.csv, &mut self.jsonl)
    }

    fn log_round(&self, rec: &RoundRecord) {
        log::info!(
            "fedavg {} r{:>4}: loss={:.4} metric={:.4} upKB={:.1}",
            self.cfg.task,
            rec.round,
            rec.train_loss,
            rec.train_metric,
            rec.uplink_bytes as f64 / 1024.0,
        );
    }

    // -- remote-execution hooks: the FedAvg broadcast carries the whole
    // model, so there is no extra round state (the default empty
    // `round_state` applies); installing the broadcast fully syncs a
    // replica, whose `prepare` then rebuilds the same `global` snapshot.

    fn install_broadcast(&mut self, broadcast: &Message) -> anyhow::Result<()> {
        let params = match broadcast {
            Message::ModelBroadcast { params } => params,
            _ => anyhow::bail!("fedavg broadcast must be a ModelBroadcast"),
        };
        let full = self.full_params();
        anyhow::ensure!(
            params.len() == full.len(),
            "broadcast carries {} tensors, model has {}",
            params.len(),
            full.len()
        );
        let shapes: Vec<Vec<usize>> =
            full.tensors.iter().map(|t| t.shape().to_vec()).collect();
        let rebuilt = message::payload_to_tensors(params, &shapes, &full.names);
        self.split_back(rebuilt);
        Ok(())
    }

    fn payload_to_wire(&self, delta: TensorList) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(message::tensors_to_payload(&delta))
    }

    fn payload_from_wire(&self, wire: Vec<Vec<f32>>) -> anyhow::Result<TensorList> {
        let full = self.full_params();
        anyhow::ensure!(
            wire.len() == full.len(),
            "wire payload carries {} tensors, model has {}",
            wire.len(),
            full.len()
        );
        let shapes: Vec<Vec<usize>> =
            full.tensors.iter().map(|t| t.shape().to_vec()).collect();
        Ok(message::payload_to_tensors(&wire, &shapes, &full.names))
    }
}

impl Trainer for FedAvgTrainer {
    fn run(&mut self) -> anyhow::Result<RunLog> {
        RoundEngine::new(self).run()
    }
}
