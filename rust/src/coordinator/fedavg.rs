//! FedAvg baseline (McMahan et al., 2017) over the same substrate.
//!
//! Each selected client receives the whole model (downlink |w|), runs `H`
//! local SGD steps using the `full_grad` artifact, and uploads its model
//! delta (uplink |w|). The server applies the weighted-mean delta. This is
//! the comparison line of Table 1 and Figure 6: more client compute and
//! memory, |w| per round instead of activations.
//!
//! Like the split trainer, each round runs the tick-based phase machine
//! of [`crate::coordinator::engine`] (Sampling → Broadcast →
//! ClientCompute → Aggregate → Commit) with deterministic fault injection
//! from [`crate::coordinator::faults`]: the per-client work (broadcast →
//! H local steps → delta upload) is a self-contained unit fanned across
//! `cfg.workers` threads, with partials reduced at the barrier in
//! cohort-slot order — bit-identical at any worker count. FedAvg has no
//! activation upload, so every mid-round drop phase collapses to "died
//! before the delta upload" ([`DropPhase::BeforeGradUpload`]): the
//! broadcast downlink is metered, nothing comes back. Deadline-evicted
//! stragglers upload their delta (metered) but the aggregate ignores it.

use std::sync::Arc;
use std::time::Instant;

use crate::comm::accounting::RoundBytes;
use crate::comm::message::{self, Message};
use crate::comm::StarNetwork;
use crate::config::RunConfig;
use crate::coordinator::aggregator::{ScalarAggregator, SurvivorSet, WeightedAggregator};
use crate::coordinator::client::{assemble, draw_masks, InputSources};
use crate::coordinator::engine::{client_stream_key, sample_key, RoundDriver, RoundPhase};
use crate::coordinator::faults::{DropCounts, DropPhase, FaultConfig, FaultPlan};
use crate::coordinator::sampler::ClientSampler;
use crate::coordinator::split::{arrays_to_tensors, open_logs, scalar, write_round};
use crate::coordinator::Trainer;
use crate::data::FederatedDataset;
use crate::metrics::{RoundRecord, RunLog, TaskMetric};
use crate::models::ModelSpec;
use crate::optim::Optimizer;
use crate::runtime::{ArtifactMeta, Runtime};
use crate::tensor::TensorList;
use crate::util::logging::{CsvWriter, JsonlWriter};
use crate::util::pool::scoped_parallel_map;
use crate::util::rng::Rng;

pub struct FedAvgTrainer {
    cfg: RunConfig,
    rt: Arc<Runtime>,
    data: Arc<dyn FederatedDataset>,
    spec: ModelSpec,
    wc: TensorList,
    ws: TensorList,
    /// Server optimizer applied to the aggregated pseudo-gradient
    /// (delta); plain SGD with lr=1.0 recovers vanilla FedAvg.
    opt: Box<dyn Optimizer>,
    net: StarNetwork,
    sampler: ClientSampler,
    metric: TaskMetric,
    faults: FaultConfig,
    rng: Rng,
    csv: Option<CsvWriter>,
    jsonl: Option<JsonlWriter>,
}

/// One FedAvg client's round contribution (worker-thread product).
struct FedAvgClientOutput {
    weight: f64,
    loss: f64,
    metric_sums: Vec<f64>,
    /// Wire-decoded model delta (global − local after H steps).
    delta: TensorList,
    bytes: RoundBytes,
    /// Where the contribution was lost, if anywhere (see module docs).
    dropped: Option<DropPhase>,
    /// Simulated straggler compute delay.
    delay_seconds: f64,
}

/// Immutable round state shared by the cohort workers.
struct FedAvgStepCtx<'a> {
    rt: &'a Runtime,
    data: &'a dyn FederatedDataset,
    net: &'a StarNetwork,
    spec: &'a ModelSpec,
    variant: &'a str,
    grad_meta: &'a ArtifactMeta,
    global: &'a TensorList,
    /// The round's whole-model broadcast, built once and shared.
    broadcast: &'a Message,
    shapes: &'a [Vec<usize>],
    wc_names: &'a [String],
    ws_names: &'a [String],
    /// Number of client-side tensors (split point in `global`).
    nc: usize,
    local_steps: usize,
    client_lr: f32,
    dropout_client: f64,
    dropout_server: f64,
    round: u32,
}

fn fedavg_client_step(
    ctx: &FedAvgStepCtx<'_>,
    ci: usize,
    crng: &mut Rng,
    plan: &FaultPlan,
) -> anyhow::Result<FedAvgClientOutput> {
    let nmetrics = ctx.spec.metrics.len();
    let mut up = 0usize;
    let mut down = 0usize;
    let weight = ctx.data.client_weight(ci).max(1e-12);

    // broadcast whole model (downlink |w|)
    let (decoded, n) = ctx.net.download(ci, ctx.round, ctx.broadcast)?;
    down += n;
    if plan.drop_at.is_some() {
        // FedAvg's only uplink is the delta, so every mid-round drop
        // collapses to "died before the delta upload": the broadcast is
        // metered, nothing comes back
        return Ok(FedAvgClientOutput {
            weight,
            loss: 0.0,
            metric_sums: Vec::new(),
            delta: TensorList::new(Vec::new(), Vec::new()),
            bytes: RoundBytes::client(0, down, 0, 1),
            dropped: Some(DropPhase::BeforeGradUpload),
            delay_seconds: plan.delay_seconds,
        });
    }
    let mut local = match decoded {
        Message::ModelBroadcast { params } => {
            message::payload_to_tensors(&params, ctx.shapes, &ctx.global.names)
        }
        _ => anyhow::bail!("wrong broadcast"),
    };

    // H local SGD steps
    let mut loss = 0.0f64;
    let mut metric_sums = vec![0.0f64; nmetrics];
    for step in 0..ctx.local_steps {
        let batch = ctx.data.train_batch(ci, ctx.spec.batch, crng);
        let masks = draw_masks(
            &[ctx.grad_meta],
            ctx.dropout_client,
            ctx.dropout_server,
            crng,
        );
        let (lc, ls) = local.tensors.split_at(ctx.nc);
        let lwc = TensorList::new(ctx.wc_names.to_vec(), lc.to_vec());
        let lws = TensorList::new(ctx.ws_names.to_vec(), ls.to_vec());
        let src = InputSources {
            wc: Some(&lwc),
            ws: Some(&lws),
            batch: Some(&batch),
            masks: Some(&masks),
            ..Default::default()
        };
        let outs = ctx
            .rt
            .run(ctx.variant, "full_grad", &assemble(ctx.grad_meta, &src)?)?;
        if step == 0 {
            loss = scalar(&outs[0])? as f64;
            for (k, s) in metric_sums.iter_mut().enumerate() {
                *s = scalar(&outs[1 + k])? as f64;
            }
        }
        let grads = arrays_to_tensors(&outs[1 + nmetrics..], ctx.global)?;
        local.axpy(-ctx.client_lr, &grads);
    }

    // upload model delta (uplink |w|)
    let mut delta = ctx.global.clone();
    delta.axpy(-1.0, &local); // delta = global - local = lr * sum grads
    let up_msg = Message::ClientGrads { grads: message::tensors_to_payload(&delta) };
    let (decoded, n) = ctx.net.upload(ci, ctx.round, &up_msg)?;
    up += n;
    let delta_wire = match decoded {
        Message::ClientGrads { grads } => {
            message::payload_to_tensors(&grads, ctx.shapes, &ctx.global.names)
        }
        _ => anyhow::bail!("wrong upload"),
    };

    let bytes = RoundBytes::client(up, down, 1, 1);
    if plan.evicted {
        // straggler past the deadline: the delta arrived (and is
        // metered), but too late to join the aggregate
        return Ok(FedAvgClientOutput {
            weight,
            loss: 0.0,
            metric_sums: Vec::new(),
            delta: TensorList::new(Vec::new(), Vec::new()),
            bytes,
            dropped: Some(DropPhase::Deadline),
            delay_seconds: plan.delay_seconds,
        });
    }
    Ok(FedAvgClientOutput {
        weight,
        loss,
        metric_sums,
        delta: delta_wire,
        bytes,
        dropped: None,
        delay_seconds: plan.delay_seconds,
    })
}

impl FedAvgTrainer {
    pub fn new(
        cfg: RunConfig,
        rt: Arc<Runtime>,
        data: Arc<dyn FederatedDataset>,
    ) -> anyhow::Result<Self> {
        let variant = cfg.variant();
        let spec = rt.manifest.variant(&variant)?.spec.clone();
        let rng = Rng::new(cfg.seed);
        let wc = spec.client.init_tensors(&mut rng.fork(1));
        let ws = spec.server.init_tensors(&mut rng.fork(2));
        let (csv, jsonl) = open_logs(&cfg)?;
        Ok(FedAvgTrainer {
            sampler: ClientSampler::uniform(cfg.num_clients, cfg.clients_per_round),
            net: StarNetwork::with_defaults(cfg.num_clients),
            opt: crate::optim::build("sgd", 1.0)?,
            metric: TaskMetric::for_task(&cfg.task),
            faults: FaultConfig::from_run(&cfg),
            spec,
            wc,
            ws,
            rng,
            data,
            rt,
            cfg,
            csv,
            jsonl,
        })
    }

    /// Concatenated (client+server) parameter list as one TensorList.
    fn full_params(&self) -> TensorList {
        let mut names = self.wc.names.clone();
        names.extend(self.ws.names.clone());
        let mut tensors = self.wc.tensors.clone();
        tensors.extend(self.ws.tensors.clone());
        TensorList::new(names, tensors)
    }

    fn split_back(&mut self, full: TensorList) {
        let nc = self.wc.len();
        let (ct, st) = full.tensors.split_at(nc);
        self.wc = TensorList::new(self.wc.names.clone(), ct.to_vec());
        self.ws = TensorList::new(self.ws.names.clone(), st.to_vec());
    }

    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<(f64, f64)> {
        let variant = self.cfg.variant();
        let meta = self.rt.manifest.artifact(&variant, "full_eval")?.clone();
        let mut loss = ScalarAggregator::new();
        let mut sums = vec![0.0f64; self.spec.metrics.len()];
        let mut examples = 0.0f64;
        let mut rng = self.rng.fork(0xE7A1);
        for _ in 0..batches {
            let batch = self.data.eval_batch(self.spec.eval_batch, &mut rng);
            let src = InputSources {
                wc: Some(&self.wc),
                ws: Some(&self.ws),
                batch: Some(&batch),
                ..Default::default()
            };
            let outs = self.rt.run(&variant, "full_eval", &assemble(&meta, &src)?)?;
            loss.add(scalar(&outs[0])? as f64, 1.0);
            for (k, s) in sums.iter_mut().enumerate() {
                *s += scalar(&outs[1 + k])? as f64;
            }
            examples += self.spec.eval_batch as f64;
        }
        Ok((loss.mean(), self.metric.value(&sums, examples)))
    }

    /// One full round through the tick-based phase machine (see
    /// `split.rs` module docs); returns the committed round record.
    fn round(&mut self, round: usize) -> anyhow::Result<RoundRecord> {
        let t0 = Instant::now();
        let variant = self.cfg.variant();
        let grad_meta = self.rt.manifest.artifact(&variant, "full_grad")?.clone();
        let nmetrics = self.spec.metrics.len();

        self.net.begin_round();
        let global = self.full_params();
        let shapes: Vec<Vec<usize>> =
            global.tensors.iter().map(|t| t.shape().to_vec()).collect();
        let mut driver = RoundDriver::new();
        // carried across phases within one attempt
        let mut cohort: Vec<usize> = Vec::new();
        let mut plans: Vec<FaultPlan> = Vec::new();
        let mut broadcast: Option<Message> = None;
        let mut results: Vec<anyhow::Result<FedAvgClientOutput>> = Vec::new();
        // carried across attempts (aborted attempts used the wire)
        let mut round_bytes = RoundBytes::default();
        let mut sim_seconds = 0.0f64;
        // survivor aggregates of the attempt that commits
        let mut delta_agg = WeightedAggregator::new();
        let mut loss_agg = ScalarAggregator::new();
        let mut metric_sums = vec![0.0f64; nmetrics];
        let mut examples = 0.0f64;
        let mut survivors = SurvivorSet::new();
        let mut drops = DropCounts::default();

        loop {
            match driver.phase() {
                RoundPhase::Sampling => {
                    let attempt = driver.attempt();
                    cohort = self.sampler.sample(
                        &mut self.rng.fork(sample_key(round as u64, attempt)),
                        &[],
                    );
                    plans = cohort
                        .iter()
                        .map(|&ci| {
                            self.faults.plan(&self.rng, round as u64, attempt, ci)
                        })
                        .collect();
                    driver.advance();
                }
                RoundPhase::Broadcast => {
                    // parameters can't change between attempts (aborts
                    // never touch the optimizers), so the payload is
                    // built once and re-sent on resampled attempts
                    if broadcast.is_none() {
                        broadcast = Some(Message::ModelBroadcast {
                            params: message::tensors_to_payload(&global),
                        });
                    }
                    driver.advance();
                }
                RoundPhase::ClientCompute => {
                    let attempt = driver.attempt();
                    let tasks: Vec<(usize, Rng, FaultPlan)> = cohort
                        .iter()
                        .zip(&plans)
                        .map(|(&ci, &plan)| {
                            let key =
                                client_stream_key(0xFEDA, round as u64, ci, attempt);
                            (ci, self.rng.fork(key), plan)
                        })
                        .collect();
                    let ctx = FedAvgStepCtx {
                        rt: &*self.rt,
                        data: self.data.as_ref(),
                        net: &self.net,
                        spec: &self.spec,
                        variant: &variant,
                        grad_meta: &grad_meta,
                        global: &global,
                        broadcast: broadcast.as_ref().expect("broadcast built"),
                        shapes: &shapes,
                        wc_names: &self.wc.names,
                        ws_names: &self.ws.names,
                        nc: self.wc.len(),
                        local_steps: self.cfg.local_steps,
                        client_lr: self.cfg.client_lr,
                        dropout_client: self.cfg.dropout_client,
                        dropout_server: self.cfg.dropout_server,
                        round: round as u32,
                    };
                    results = scoped_parallel_map(
                        self.cfg.resolved_workers(),
                        tasks,
                        |_slot, (ci, mut crng, plan)| {
                            fedavg_client_step(&ctx, ci, &mut crng, &plan)
                        },
                    );
                    driver.advance();
                }
                RoundPhase::Aggregate => {
                    // slot-order reduction (see split.rs: bit-identical
                    // at any worker count)
                    delta_agg = WeightedAggregator::new();
                    loss_agg = ScalarAggregator::new();
                    metric_sums = vec![0.0f64; nmetrics];
                    examples = 0.0;
                    survivors = SurvivorSet::new();
                    drops = DropCounts::default();
                    let mut per_client: Vec<(usize, usize, f64)> =
                        Vec::with_capacity(cohort.len());
                    for result in std::mem::take(&mut results) {
                        let out = result?;
                        per_client.push((
                            out.bytes.up as usize,
                            out.bytes.down as usize,
                            out.delay_seconds,
                        ));
                        round_bytes.merge(&out.bytes);
                        match out.dropped {
                            Some(phase) => {
                                drops.add(phase);
                                survivors.dropped();
                            }
                            None => {
                                survivors.survivor(out.weight);
                                loss_agg.add(out.loss, out.weight);
                                for (k, s) in metric_sums.iter_mut().enumerate() {
                                    *s += out.metric_sums[k];
                                }
                                examples += self.spec.batch as f64;
                                delta_agg.add(&out.delta, out.weight);
                            }
                        }
                    }
                    sim_seconds += self.net.estimate_round_time_with_delays(
                        &per_client,
                        self.faults.round_deadline,
                    );
                    // survivor weights renormalize to a convex combination
                    // (kept in lockstep with split.rs)
                    debug_assert!(
                        survivors.survived() == 0
                            || (survivors.normalized().iter().sum::<f64>() - 1.0).abs()
                                < 1e-9,
                        "survivor weights must renormalize to 1"
                    );
                    if self.faults.min_survivors > 0
                        && survivors.survived() < self.faults.min_survivors
                        && driver.resample()
                    {
                        continue;
                    }
                    driver.advance();
                }
                RoundPhase::Commit => break,
            }
        }

        // pseudo-gradient step: w <- w - 1.0 * mean(delta); skipped when
        // nobody survived (degraded commit)
        let mut full = global;
        if let Some(delta) = delta_agg.finish() {
            self.opt.step(&mut full, &delta);
        }
        anyhow::ensure!(full.is_finite(), "parameters diverged at round {round}");
        self.split_back(full);

        let meter_delta = self.net.end_round();
        debug_assert_eq!(meter_delta, round_bytes, "meter vs merged partials");
        let mut rec = RoundRecord {
            round,
            train_loss: loss_agg.mean(),
            train_metric: self.metric.value(&metric_sums, examples),
            quant_error: 0.0,
            uplink_bytes: round_bytes.up,
            downlink_bytes: round_bytes.down,
            cumulative_uplink: self.net.totals().up,
            wall_seconds: t0.elapsed().as_secs_f64(),
            sim_comm_seconds: sim_seconds,
            cohort_sampled: cohort.len(),
            cohort_survived: survivors.survived(),
            dropped: drops,
            attempts: driver.attempt(),
            ..Default::default()
        };
        if self.cfg.eval_every > 0
            && (round % self.cfg.eval_every == self.cfg.eval_every - 1 || round == 0)
        {
            let (el, em) = self.evaluate(self.cfg.eval_batches)?;
            rec.eval_loss = Some(el);
            rec.eval_metric = Some(em);
        }
        Ok(rec)
    }
}

impl Trainer for FedAvgTrainer {
    fn run(&mut self) -> anyhow::Result<RunLog> {
        let mut log = RunLog::default();
        for round in 0..self.cfg.rounds {
            let rec = self.round(round)?;
            if round == 0 || (round + 1) % 10 == 0 {
                log::info!(
                    "fedavg {} r{:>4}: loss={:.4} metric={:.4} upKB={:.1}",
                    self.cfg.task,
                    round,
                    rec.train_loss,
                    rec.train_metric,
                    rec.uplink_bytes as f64 / 1024.0,
                );
            }
            write_round(&mut self.csv, &mut self.jsonl, &rec)?;
            log.push(rec);
        }
        if let Some(c) = &mut self.csv {
            c.flush()?;
        }
        if let Some(j) = &mut self.jsonl {
            j.flush()?;
        }
        Ok(log)
    }
}
