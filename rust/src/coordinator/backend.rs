//! Client fan-out backends: where `client_step` actually runs.
//!
//! The round engine's phase machine decides *what* to compute each round
//! — cohort, fault plans, broadcast, reduction order — but is agnostic to
//! *where* the per-client work executes. A [`ClientBackend`] owns that
//! placement: the engine hands it one shard's `(client, plan)` set plus
//! the broadcast, and gets back slot-ordered [`ClientOutput`]s it folds
//! exactly as before.
//!
//! Two placements exist:
//!
//! * [`InProcessBackend`] — the scoped-thread fan-out the engine always
//!   had, extracted verbatim. This is the default; every golden, the
//!   worker/shard-invariance suite, and the zero-allocation contracts run
//!   through it unchanged.
//! * [`SocketBackend`] — real TCP loopback. Each shard's assignments are
//!   framed over per-member connections to standalone `fedlite-client`
//!   processes ([`crate::coordinator::worker`]), which run the *same*
//!   `client_step` against a replica trainer and stream results back.
//!   Fault plans travel with the assignments and all RNG keys stay pure
//!   in `(round, attempt, client)`, so a socket run's records are
//!   byte-identical to the in-process run of the same config (CI diffs
//!   them). Collection is a poll/deadline loop, not a blocking read in
//!   slot order: each member's oldest outstanding slot carries a
//!   real-time deadline (`max(round_deadline, --socket-deadline-floor)`),
//!   and because a slot's `StepResult` is a pure function of
//!   `(round, attempt, client)` + plan, a straggling or failed member's
//!   unfinished slots are speculatively *reassigned* to healthy members
//!   and produce byte-identical results. A straggler past its deadline
//!   is quarantined (a strike on its health score, connection severed);
//!   a member that dies mid-shard — malformed frame, wrong client,
//!   undecodable payload, dead socket — is reaped as a peer failure.
//!   Either way the worker's reconnect/backoff loop may rejoin between
//!   rounds. Slots degrade to [`DropPhase::PeerFailure`] drops only when
//!   no healthy member remains (a degraded commit, never a deadlock or
//!   round abort). A deterministic transport-chaos layer (`--chaos-*`,
//!   keyed per `(round, member, frame)` off the fault module's
//!   [`crate::coordinator::faults::chaos_key`]) can lose assignments in
//!   flight to drive all of this in tests without changing one recorded
//!   bit.
//!
//! Membership is a small state machine on the coordinator side:
//!
//! ```text
//! WaitingForMembers ──(roster ≥ min_clients)──▶ Warmup ──▶ Training
//!         ▲                                                   │
//!         └── roster shrank below the floor between rounds ◀──┘
//! ```
//!
//! Joins are admitted and leaves reaped only *between* rounds (before the
//! next round's roster is fixed), so a round's membership is stable for
//! its whole duration and slot→member assignment stays deterministic.
//! After each `RoundEnd` every member replies `Ready` (staying) or
//! `Leave` (departing), so graceful departures are observed
//! synchronously; the nonblocking sweep before each round additionally
//! reaps crashed connections and pre-first-round leaves.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::accounting::RoundBytes;
use crate::comm::message::Message;
use crate::comm::transport::{self, Frame, PROTOCOL_VERSION};
use crate::config::RunConfig;
use crate::coordinator::engine::{client_stream_key, ClientOutput, RoundAlgorithm};
use crate::coordinator::faults::{ChaosConfig, DropPhase, FaultPlan};
use crate::util::pool::scoped_parallel_map;
use crate::util::rng::Rng;

/// Where one shard's client steps execute. The engine calls
/// [`ClientBackend::run_shard`] once per shard per attempt and folds the
/// returned outputs in slot order; everything about *what* to run (keys,
/// plans, broadcast) is decided by the engine, everything about *where*
/// by the backend.
pub trait ClientBackend<A: RoundAlgorithm> {
    /// Execute `client_step` for every client in `shard` (paired with
    /// `plans`, same length) and return their outputs in shard-slot
    /// order. `scratches` is the engine's warm per-slot pool: in-process
    /// backends lend from it and must return every borrowed scratch;
    /// remote backends leave it untouched.
    #[allow(clippy::too_many_arguments)]
    fn run_shard(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
        scratches: &mut Vec<A::Scratch>,
    ) -> Vec<anyhow::Result<ClientOutput<A::Payload>>>;

    /// The round committed. Socket backends notify members here (the
    /// window in which clients may leave); in-process backends need not
    /// do anything.
    fn round_complete(&mut self, _round: usize) -> anyhow::Result<()> {
        Ok(())
    }

    /// Drain the transport-robustness telemetry accumulated since the
    /// last call; the engine folds it into the round record
    /// (`reassigned_steps` / `quarantined_members` columns). In-process
    /// backends have no transport, so the default is all-zero.
    fn take_telemetry(&mut self) -> BackendTelemetry {
        BackendTelemetry::default()
    }
}

/// One round's transport-robustness tally, drained by the engine via
/// [`ClientBackend::take_telemetry`]. Transport bookkeeping only — a
/// reassigned slot re-executes the same pure `(round, attempt, client)`
/// work, so no other record column moves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendTelemetry {
    /// `StepAssign`s re-sent to another member after a chaos loss,
    /// straggler timeout, or peer failure.
    pub reassigned_steps: usize,
    /// Members quarantined (straggler past the slot deadline) or reaped
    /// (dead socket / protocol violation) mid-round.
    pub quarantined_members: usize,
}

/// Cumulative transport counters for a whole socket run. Shared out as
/// an [`Arc`] via [`SocketBackend::stats`] before the backend is boxed
/// into the engine, so tests and operators can assert on reassignment,
/// quarantine, and peer-failure behavior after the run.
#[derive(Debug, Default)]
pub struct TransportStats {
    reassigned_steps: AtomicUsize,
    quarantined_members: AtomicUsize,
    peer_failures: AtomicUsize,
}

impl TransportStats {
    /// Total `StepAssign`s re-sent to a different member.
    pub fn reassigned_steps(&self) -> usize {
        self.reassigned_steps.load(Ordering::Relaxed)
    }

    /// Total members quarantined or reaped mid-round.
    pub fn quarantined_members(&self) -> usize {
        self.quarantined_members.load(Ordering::Relaxed)
    }

    /// Members reaped for hard failures (dead socket, malformed frame,
    /// wrong client, undecodable payload) — the `peer_failure` meter.
    pub fn peer_failures(&self) -> usize {
        self.peer_failures.load(Ordering::Relaxed)
    }
}

/// The scoped-thread fan-out the engine always used, now behind the
/// backend seam. Behavior-preserving by construction: same
/// `client_stream_key` forks, same `scoped_parallel_map` slot order, same
/// scratch lend/recover discipline.
pub struct InProcessBackend;

impl<A: RoundAlgorithm> ClientBackend<A> for InProcessBackend {
    fn run_shard(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
        scratches: &mut Vec<A::Scratch>,
    ) -> Vec<anyhow::Result<ClientOutput<A::Payload>>> {
        debug_assert_eq!(shard.len(), plans.len(), "one plan per shard client");
        let env = algo.env();
        // lend one warm scratch per shard slot (the pool grows to the
        // largest shard slice once, then persists across shards and
        // rounds)
        while scratches.len() < shard.len() {
            scratches.push(A::Scratch::default());
        }
        let mut lent = std::mem::take(scratches);
        let spare = lent.split_off(shard.len());
        let tasks: Vec<(usize, Rng, FaultPlan, A::Scratch)> = shard
            .iter()
            .zip(plans)
            .zip(lent)
            .map(|((&ci, &plan), scratch)| {
                let key = client_stream_key(algo.stream_tag(), round as u64, ci, attempt);
                (ci, env.rng.fork(key), plan, scratch)
            })
            .collect();
        // fan the shard across the worker threads; collection is the
        // shard barrier
        let pairs = scoped_parallel_map(
            env.workers,
            tasks,
            |_slot, (ci, mut crng, plan, mut scratch)| {
                let out = algo.client_step(
                    prep, broadcast, round as u32, ci, &mut crng, &plan, &mut scratch,
                );
                (out, scratch)
            },
        );
        // recover the scratches in slot order
        let mut outs = Vec::with_capacity(shard.len());
        for (out, scratch) in pairs {
            outs.push(out);
            scratches.push(scratch);
        }
        scratches.extend(spare);
        outs
    }
}

/// Coordinator-side membership phase (see the module diagram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServicePhase {
    /// Blocking on `accept` until the roster reaches `min_clients`.
    WaitingForMembers,
    /// Roster is full but the first round hasn't started yet.
    Warmup,
    /// Rounds are running against a fixed roster.
    Training,
}

/// One admitted member connection, with its health score: `completed`
/// counts steps served this session, `strikes` counts straggler
/// timeouts. Any strike or hard failure removes the member (FIFO frame
/// order cannot be trusted past an abandoned assignment); health resets
/// on rejoin, so quarantine is an eviction, not a ban.
struct Member {
    stream: TcpStream,
    peer: SocketAddr,
    completed: u64,
    strikes: u32,
}

/// The coordinator's listening socket plus its admitted members — the
/// membership state machine that [`SocketBackend`] drives between rounds.
pub struct CoordinatorService {
    listener: TcpListener,
    members: Vec<Member>,
    min_clients: usize,
    /// The run config shipped to joiners in the `Welcome` frame; workers
    /// rebuild a bit-identical replica trainer from it.
    config_json: String,
    /// Per-connection read deadline and the poll loop's per-slot
    /// deadline (reuses the fault layer's `round_deadline` semantics
    /// floored by `--socket-deadline-floor`, see
    /// [`transport::socket_deadline`]).
    read_timeout: Duration,
    /// Deterministic transport-chaos knobs (`--chaos-*`), shipped to
    /// members inside `config_json` so both link ends draw the same
    /// schedules.
    chaos: ChaosConfig,
    /// Root for per-frame chaos forks (`chaos_key(round, member, frame)`);
    /// never advanced, so chaos draws stay pure in their keys.
    chaos_root: Rng,
    phase: ServicePhase,
}

impl CoordinatorService {
    /// Bind the serve socket. `min_clients` is clamped to at least 1 —
    /// a roster floor of zero would assign work to nobody.
    pub fn bind(addr: &str, min_clients: usize, cfg: &RunConfig) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        Ok(CoordinatorService {
            listener,
            members: Vec::new(),
            min_clients: min_clients.max(1),
            config_json: cfg.to_json().to_string_pretty(),
            read_timeout: transport::socket_deadline(
                cfg.round_deadline,
                cfg.socket_deadline_floor,
            ),
            chaos: ChaosConfig::from_run(cfg),
            chaos_root: Rng::new(cfg.seed),
            phase: ServicePhase::WaitingForMembers,
        })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn num_members(&self) -> usize {
        self.members.len()
    }

    pub fn phase(&self) -> ServicePhase {
        self.phase
    }

    /// Run the join handshake on a fresh connection and admit it:
    /// `Join{version}` → `Welcome{config}` → `Ready`.
    fn admit(&mut self, stream: TcpStream, peer: SocketAddr) -> anyhow::Result<()> {
        stream.set_nonblocking(false)?;
        transport::configure_stream(&stream, Some(self.read_timeout))?;
        let mut stream = stream;
        match Frame::read_from(&mut stream)? {
            Frame::Join { version } => {
                anyhow::ensure!(
                    version == PROTOCOL_VERSION,
                    "member {peer} speaks protocol v{version}, need v{PROTOCOL_VERSION}"
                );
            }
            other => anyhow::bail!("expected Join from {peer}, got {}", other.name()),
        }
        Frame::Welcome { config_json: self.config_json.clone() }.write_to(&mut stream)?;
        match Frame::read_from(&mut stream)? {
            Frame::Ready => {}
            other => anyhow::bail!("expected Ready from {peer}, got {}", other.name()),
        }
        log::info!("member joined from {peer} ({} total)", self.members.len() + 1);
        self.members.push(Member { stream, peer, completed: 0, strikes: 0 });
        Ok(())
    }

    /// Accept every connection already queued on the listener without
    /// blocking. A failed handshake drops that connection only.
    fn sweep_joins(&mut self) -> anyhow::Result<()> {
        self.listener.set_nonblocking(true)?;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if let Err(e) = self.admit(stream, peer) {
                        log::warn!("rejecting join from {peer}: {e:#}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    self.listener.set_nonblocking(false)?;
                    return Err(e.into());
                }
            }
        }
        self.listener.set_nonblocking(false)?;
        Ok(())
    }

    /// Reap members that left since the last round: a queued `Leave`
    /// frame or a closed connection. Anything else queued between rounds
    /// is a protocol violation and drops the member.
    fn sweep_leaves(&mut self) {
        let mut keep = Vec::with_capacity(self.members.len());
        for mut m in self.members.drain(..) {
            let mut probe = [0u8; 1];
            if m.stream.set_nonblocking(true).is_err() {
                log::warn!("member {} unreachable, dropping", m.peer);
                continue;
            }
            let verdict = match m.stream.peek(&mut probe) {
                Ok(0) => Err("connection closed".to_string()),
                Ok(_) => {
                    // a frame is queued; read it blocking — only Leave is
                    // legal between rounds
                    if m.stream.set_nonblocking(false).is_err() {
                        Err("socket error".to_string())
                    } else {
                        match Frame::read_from(&mut m.stream) {
                            Ok(Frame::Leave) => Err("left".to_string()),
                            Ok(other) => Err(format!(
                                "unexpected {} between rounds",
                                other.name()
                            )),
                            Err(e) => Err(format!("read error: {e:#}")),
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(()),
                Err(e) => Err(format!("socket error: {e}")),
            };
            match verdict {
                Ok(()) if m.stream.set_nonblocking(false).is_ok() => keep.push(m),
                Ok(()) => log::warn!("member {} unreachable, dropping", m.peer),
                Err(why) => {
                    log::info!("member {} departed ({why})", m.peer);
                }
            }
        }
        self.members = keep;
    }

    /// Fix the roster for the next round: reap leaves, admit queued
    /// joins, then block for new members until the floor is met.
    pub fn ensure_members(&mut self) -> anyhow::Result<()> {
        self.sweep_leaves();
        self.sweep_joins()?;
        while self.members.len() < self.min_clients {
            self.phase = ServicePhase::WaitingForMembers;
            log::info!(
                "waiting for members: {}/{}",
                self.members.len(),
                self.min_clients
            );
            let (stream, peer) = self.listener.accept()?;
            if let Err(e) = self.admit(stream, peer) {
                log::warn!("rejecting join from {peer}: {e:#}");
            }
        }
        if self.phase == ServicePhase::WaitingForMembers {
            self.phase = ServicePhase::Warmup;
        }
        Ok(())
    }

    /// Send one frame to every member.
    pub fn send_all(&mut self, frame: &Frame) -> anyhow::Result<()> {
        for m in &mut self.members {
            frame
                .write_to(&mut m.stream)
                .map_err(|e| anyhow::anyhow!("send {} to {}: {e:#}", frame.name(), m.peer))?;
        }
        Ok(())
    }

    fn send_to(&mut self, idx: usize, frame: &Frame) -> anyhow::Result<()> {
        let m = &mut self.members[idx];
        frame
            .write_to(&mut m.stream)
            .map_err(|e| anyhow::anyhow!("send {} to {}: {e:#}", frame.name(), m.peer))
    }

    /// After `RoundEnd`, every member declares its intent for the next
    /// round: `Ready` to stay, `Leave` to depart. Reading exactly one
    /// reply per member closes the membership race — a graceful leave is
    /// always observed here, never discovered later as a dead socket in
    /// the middle of the next round's state sync. A member that answers
    /// anything else (or whose connection fails) is dropped.
    fn collect_round_acks(&mut self) {
        let mut keep = Vec::with_capacity(self.members.len());
        for mut m in std::mem::take(&mut self.members) {
            match Frame::read_from(&mut m.stream) {
                Ok(Frame::Ready) => keep.push(m),
                Ok(Frame::Leave) => {
                    log::info!("member {} left after the round", m.peer);
                }
                Ok(other) => log::warn!(
                    "member {}: unexpected {} after RoundEnd, dropping",
                    m.peer,
                    other.name()
                ),
                Err(e) => log::warn!("member {} lost after RoundEnd ({e:#})", m.peer),
            }
        }
        self.members = keep;
    }

    /// Drop the members flagged `true` in `dead` (parallel to the member
    /// list): their connections are severed and they leave the roster.
    /// Called after a shard completes so slot→member assignment stays
    /// fixed for the shard's whole duration.
    fn reap(&mut self, dead: &[bool]) {
        debug_assert_eq!(dead.len(), self.members.len());
        let mut idx = 0usize;
        self.members.retain(|m| {
            let keep = !dead[idx];
            if !keep {
                log::warn!("reaping member {} after mid-round failure", m.peer);
            }
            idx += 1;
            keep
        });
    }

    /// Best-effort shutdown: tell every member the run is over.
    pub fn shutdown(&mut self) {
        for m in &mut self.members {
            let _ = Frame::Shutdown.write_to(&mut m.stream);
        }
        self.members.clear();
    }
}

/// Cap on chaos-driven redeliveries per slot: past this many simulated
/// in-flight losses the assignment is force-delivered, so even
/// `--chaos-drop 1.0` degrades deterministically instead of livelocking
/// the dispatch loop.
const MAX_CHAOS_REDELIVERIES: u32 = 8;

/// Idle sleep between poll sweeps when no member has a frame queued and
/// nothing is pending dispatch. Small enough to keep loopback latency
/// negligible, large enough not to spin a core.
const POLL_QUANTUM: Duration = Duration::from_millis(2);

/// The TCP loopback backend: assignments fan out over member connections
/// (initial layout slot `i` → member `i mod W`), results stream back
/// over the same FIFO connections, and collection is a poll/deadline
/// loop: a member's oldest outstanding slot must make progress within
/// the read deadline or the member is quarantined and its slots are
/// speculatively reassigned to healthy members. Because each slot is a
/// pure function of `(round, attempt, client)` + plan, the reassigned
/// execution is byte-identical to the one the straggler abandoned.
pub struct SocketBackend {
    service: CoordinatorService,
    /// Round whose state/broadcast the members already hold; re-synced
    /// once per round (not per shard or attempt).
    synced_round: Option<usize>,
    /// Cumulative run-level counters, shared with tests/operators.
    stats: Arc<TransportStats>,
    /// Since-last-drain tally the engine folds into the round record.
    telemetry: BackendTelemetry,
}

impl SocketBackend {
    pub fn new(service: CoordinatorService) -> Self {
        SocketBackend {
            service,
            synced_round: None,
            stats: Arc::new(TransportStats::default()),
            telemetry: BackendTelemetry::default(),
        }
    }

    pub fn service(&self) -> &CoordinatorService {
        &self.service
    }

    /// Clone the shared counter handle. Grab this before boxing the
    /// backend into the engine; the run mutates the same atomics.
    pub fn stats(&self) -> Arc<TransportStats> {
        Arc::clone(&self.stats)
    }

    fn run_shard_inner<A: RoundAlgorithm>(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
    ) -> anyhow::Result<Vec<anyhow::Result<ClientOutput<A::Payload>>>> {
        debug_assert_eq!(shard.len(), plans.len(), "one plan per shard client");
        if shard.is_empty() {
            return Ok(Vec::new());
        }
        // fix the roster and ship the round's state + broadcast once per
        // round; later shards and resampled attempts reuse them (the
        // broadcast can't change between attempts). Sync is per-member
        // best-effort: a member that dies here is reaped as a peer
        // failure instead of aborting the round for everyone else.
        if self.synced_round != Some(round) {
            self.service.ensure_members()?;
            self.service.phase = ServicePhase::Training;
            let state =
                Frame::RoundState { round: round as u32, tensors: algo.round_state(prep) };
            let bcast = Frame::Broadcast {
                round: round as u32,
                message: broadcast.encode(round as u32, 0),
            };
            let mut dead = vec![false; self.service.num_members()];
            for m in 0..self.service.num_members() {
                let mut sync = self.service.send_to(m, &state);
                if sync.is_ok() {
                    sync = self.service.send_to(m, &bcast);
                }
                if let Err(e) = sync {
                    log::warn!("round-state sync failed, reaping member: {e:#}");
                    dead[m] = true;
                    self.telemetry.quarantined_members += 1;
                    self.stats.quarantined_members.fetch_add(1, Ordering::Relaxed);
                    self.stats.peer_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
            self.service.reap(&dead);
            self.synced_round = Some(round);
        }
        let failed = || {
            Ok(ClientOutput::failed(
                DropPhase::PeerFailure,
                0.0,
                RoundBytes::default(),
                0.0,
            ))
        };
        let w = self.service.num_members();
        if w == 0 {
            // every member died during sync: commit a degraded round
            // (all slots peer-failure drops) rather than deadlock; the
            // next round's `ensure_members` blocks for rejoins
            log::warn!("no healthy members for round {round}; degraded commit");
            return Ok(shard.iter().map(|_| failed()).collect());
        }

        // Evict a member mid-shard: sever it from the dispatch rotation
        // and requeue its outstanding slots for reassignment. `hard`
        // marks protocol/socket failures (metered as peer failures) as
        // opposed to straggler quarantines. Either way the frames FIFO
        // can no longer be trusted, so the connection is reaped after
        // the shard; the worker's reconnect loop may rejoin later.
        fn evict(
            m: usize,
            hard: bool,
            why: &str,
            peer: SocketAddr,
            queues: &mut [VecDeque<usize>],
            pending: &mut VecDeque<usize>,
            gone: &mut [bool],
            stats: &TransportStats,
            telemetry: &mut BackendTelemetry,
        ) {
            log::warn!("evicting member {peer} mid-shard ({why})");
            gone[m] = true;
            while let Some(slot) = queues[m].pop_front() {
                pending.push_back(slot);
            }
            telemetry.quarantined_members += 1;
            stats.quarantined_members.fetch_add(1, Ordering::Relaxed);
            if hard {
                stats.peer_failures.fetch_add(1, Ordering::Relaxed);
            }
        }

        enum Polled {
            /// No frame queued on the connection.
            Idle,
            /// Connection unusable (closed, reset, unreadable frame).
            Dead(String),
            /// One whole frame read.
            Got(Frame),
        }

        let deadline = self.service.read_timeout;
        let mut outs: Vec<Option<anyhow::Result<ClientOutput<A::Payload>>>> =
            (0..shard.len()).map(|_| None).collect();
        let mut pending: VecDeque<usize> = (0..shard.len()).collect();
        let mut queues: Vec<VecDeque<usize>> = (0..w).map(|_| VecDeque::new()).collect();
        let mut gone = vec![false; w];
        // per-slot delivery counters: `sent` drives the reassignment
        // meter (any dispatch after the first is a redelivery, whether
        // the first was chaos-eaten or abandoned by an evicted member),
        // `chaos_losses` bounds the chaos retry tail
        let mut sent = vec![0u32; shard.len()];
        let mut chaos_losses = vec![0u32; shard.len()];
        // per-member chaos frame counters for `chaos_key(round, m, frame)`
        let mut frames = vec![0u64; w];
        let mut last_progress: Vec<Instant> = vec![Instant::now(); w];
        let mut cursor = 0usize;

        loop {
            // ---- dispatch every pending assignment ----
            'dispatch: while let Some(slot) = pending.pop_front() {
                let mut target = None;
                for _ in 0..w {
                    let c = cursor % w;
                    cursor += 1;
                    if !gone[c] {
                        target = Some(c);
                        break;
                    }
                }
                let Some(m) = target else {
                    // no healthy member remains: degraded commit for
                    // this slot, never a deadlock or round abort
                    outs[slot] = Some(failed());
                    continue 'dispatch;
                };
                if sent[slot] > 0 {
                    self.telemetry.reassigned_steps += 1;
                    self.stats.reassigned_steps.fetch_add(1, Ordering::Relaxed);
                }
                let cf = self.service.chaos.frame(
                    &self.service.chaos_root,
                    round as u64,
                    m as u64,
                    frames[m],
                );
                frames[m] += 1;
                if cf.drop && chaos_losses[slot] < MAX_CHAOS_REDELIVERIES {
                    // deterministic chaos ate the assignment in flight;
                    // requeue for redelivery (counted above once a
                    // prior send exists)
                    chaos_losses[slot] += 1;
                    sent[slot] += 1;
                    pending.push_back(slot);
                    continue 'dispatch;
                }
                let assign = Frame::StepAssign {
                    round: round as u32,
                    attempt,
                    client: shard[slot] as u64,
                    plan: plans[slot],
                };
                match self.service.send_to(m, &assign) {
                    Ok(()) => {
                        sent[slot] += 1;
                        if queues[m].is_empty() {
                            last_progress[m] = Instant::now();
                        }
                        queues[m].push_back(slot);
                    }
                    Err(e) => {
                        let peer = self.service.members[m].peer;
                        evict(
                            m,
                            true,
                            &format!("assign failed: {e:#}"),
                            peer,
                            &mut queues,
                            &mut pending,
                            &mut gone,
                            &self.stats,
                            &mut self.telemetry,
                        );
                        pending.push_back(slot);
                    }
                }
            }
            if outs.iter().all(|o| o.is_some()) {
                break;
            }

            // ---- poll every member with outstanding work ----
            let mut progressed = false;
            for m in 0..w {
                if gone[m] || queues[m].is_empty() {
                    continue;
                }
                let polled = {
                    let stream = &mut self.service.members[m].stream;
                    let mut probe = [0u8; 1];
                    if stream.set_nonblocking(true).is_err() {
                        Polled::Dead("socket error".to_string())
                    } else {
                        match stream.peek(&mut probe) {
                            Ok(0) => Polled::Dead("connection closed".to_string()),
                            Ok(_) => {
                                if stream.set_nonblocking(false).is_err() {
                                    Polled::Dead("socket error".to_string())
                                } else {
                                    // the blocking read still carries the
                                    // connection's read deadline, so a
                                    // half-written frame cannot wedge the
                                    // loop
                                    match Frame::read_from(stream) {
                                        Ok(f) => Polled::Got(f),
                                        Err(e) => {
                                            Polled::Dead(format!("read error: {e:#}"))
                                        }
                                    }
                                }
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                let _ = stream.set_nonblocking(false);
                                Polled::Idle
                            }
                            Err(e) => Polled::Dead(format!("socket error: {e}")),
                        }
                    }
                };
                let peer = self.service.members[m].peer;
                match polled {
                    Polled::Idle => {
                        if last_progress[m].elapsed() > deadline {
                            progressed = true;
                            let member = &mut self.service.members[m];
                            member.strikes += 1;
                            let why = format!(
                                "straggler: no reply in {:.1}s with {} slots \
                                 outstanding (strike {}, {} steps served)",
                                deadline.as_secs_f64(),
                                queues[m].len(),
                                member.strikes,
                                member.completed,
                            );
                            evict(
                                m,
                                false,
                                &why,
                                peer,
                                &mut queues,
                                &mut pending,
                                &mut gone,
                                &self.stats,
                                &mut self.telemetry,
                            );
                        }
                    }
                    Polled::Dead(why) => {
                        progressed = true;
                        evict(
                            m,
                            true,
                            &why,
                            peer,
                            &mut queues,
                            &mut pending,
                            &mut gone,
                            &self.stats,
                            &mut self.telemetry,
                        );
                    }
                    Polled::Got(frame) => {
                        progressed = true;
                        match frame {
                            Frame::StepResult(r) => {
                                let &slot =
                                    queues[m].front().expect("polled member has a queue");
                                let ci = shard[slot];
                                if r.client != ci as u64 {
                                    evict(
                                        m,
                                        true,
                                        &format!(
                                            "answered client {} for assigned client {ci}",
                                            r.client
                                        ),
                                        peer,
                                        &mut queues,
                                        &mut pending,
                                        &mut gone,
                                        &self.stats,
                                        &mut self.telemetry,
                                    );
                                    continue;
                                }
                                let payload =
                                    match r.payload.map(|p| algo.payload_from_wire(p)) {
                                        Some(Ok(p)) => Some(p),
                                        Some(Err(e)) => {
                                            evict(
                                                m,
                                                true,
                                                &format!(
                                                    "undecodable payload for client \
                                                     {ci}: {e:#}"
                                                ),
                                                peer,
                                                &mut queues,
                                                &mut pending,
                                                &mut gone,
                                                &self.stats,
                                                &mut self.telemetry,
                                            );
                                            continue;
                                        }
                                        None => None,
                                    };
                                queues[m].pop_front();
                                // the worker metered its own transfers;
                                // replay them into the coordinator's meter
                                // exactly once per resolved slot (an
                                // evicted member's abandoned work is never
                                // read), so per-round deltas and the
                                // engine's meter-vs-partials assertion
                                // match the in-process run exactly
                                algo.env().net.absorb(&r.bytes);
                                outs[slot] = Some(Ok(ClientOutput {
                                    weight: r.weight,
                                    loss: r.loss,
                                    metric_sums: r.metric_sums,
                                    quant_rel_err: r.quant_rel_err,
                                    surrogate_loss: r.surrogate_loss,
                                    payload,
                                    bytes: r.bytes,
                                    dropped: r.dropped,
                                    delay_seconds: r.delay_seconds,
                                }));
                                last_progress[m] = Instant::now();
                                self.service.members[m].completed += 1;
                            }
                            Frame::StepError { client, error } => {
                                let &slot =
                                    queues[m].front().expect("polled member has a queue");
                                if client != shard[slot] as u64 {
                                    evict(
                                        m,
                                        true,
                                        &format!(
                                            "StepError for client {client}, expected {}",
                                            shard[slot]
                                        ),
                                        peer,
                                        &mut queues,
                                        &mut pending,
                                        &mut gone,
                                        &self.stats,
                                        &mut self.telemetry,
                                    );
                                    continue;
                                }
                                // the worker failed this step but the
                                // frame protocol is intact (exactly one
                                // reply per assignment), so the member
                                // stays; only the client drops
                                log::warn!(
                                    "remote client {client} failed, metering as a drop: \
                                     {error}"
                                );
                                queues[m].pop_front();
                                outs[slot] = Some(failed());
                                last_progress[m] = Instant::now();
                            }
                            other => {
                                evict(
                                    m,
                                    true,
                                    &format!("unexpected {} mid-shard", other.name()),
                                    peer,
                                    &mut queues,
                                    &mut pending,
                                    &mut gone,
                                    &self.stats,
                                    &mut self.telemetry,
                                );
                            }
                        }
                    }
                }
            }
            if !progressed && pending.is_empty() {
                std::thread::sleep(POLL_QUANTUM);
            }
        }
        self.service.reap(&gone);
        let outs = outs
            .into_iter()
            .map(|o| o.expect("every shard slot resolved"))
            .collect();
        Ok(outs)
    }
}

impl<A: RoundAlgorithm> ClientBackend<A> for SocketBackend {
    fn run_shard(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
        _scratches: &mut Vec<A::Scratch>,
    ) -> Vec<anyhow::Result<ClientOutput<A::Payload>>> {
        match self.run_shard_inner(algo, prep, broadcast, round, attempt, shard, plans) {
            Ok(outs) => outs,
            // a transport-level failure aborts the round (the engine's
            // `?` in Aggregate surfaces it); the byte meter still closes
            Err(e) => vec![Err(e)],
        }
    }

    fn round_complete(&mut self, round: usize) -> anyhow::Result<()> {
        // per-member best-effort: a member that died since the shard
        // barrier is reaped here instead of aborting the committed round
        let end = Frame::RoundEnd { round: round as u32 };
        let mut dead = vec![false; self.service.num_members()];
        for m in 0..self.service.num_members() {
            if let Err(e) = self.service.send_to(m, &end) {
                log::warn!("RoundEnd send failed, reaping member: {e:#}");
                dead[m] = true;
            }
        }
        self.service.reap(&dead);
        self.service.collect_round_acks();
        Ok(())
    }

    fn take_telemetry(&mut self) -> BackendTelemetry {
        std::mem::take(&mut self.telemetry)
    }
}

impl Drop for SocketBackend {
    fn drop(&mut self) {
        self.service.shutdown();
    }
}
