//! Client fan-out backends: where `client_step` actually runs.
//!
//! The round engine's phase machine decides *what* to compute each round
//! — cohort, fault plans, broadcast, reduction order — but is agnostic to
//! *where* the per-client work executes. A [`ClientBackend`] owns that
//! placement: the engine hands it one shard's `(client, plan)` set plus
//! the broadcast, and gets back slot-ordered [`ClientOutput`]s it folds
//! exactly as before.
//!
//! Two placements exist:
//!
//! * [`InProcessBackend`] — the scoped-thread fan-out the engine always
//!   had, extracted verbatim. This is the default; every golden, the
//!   worker/shard-invariance suite, and the zero-allocation contracts run
//!   through it unchanged.
//! * [`SocketBackend`] — real TCP loopback. Each shard's assignments are
//!   framed over per-member connections to standalone `fedlite-client`
//!   processes ([`crate::coordinator::worker`]), which run the *same*
//!   `client_step` against a replica trainer and stream results back.
//!   Fault plans travel with the assignments and all RNG keys stay pure
//!   in `(round, attempt, client)`, so a socket run's records are
//!   byte-identical to the in-process run of the same config (CI diffs
//!   them). A member that misbehaves mid-shard (malformed frame, wrong
//!   client, undecodable payload, dead socket) is reaped rather than
//!   trusted to abort the round: its slots become
//!   [`DropPhase::PeerFailure`] drops and training continues on the
//!   surviving roster.
//!
//! Membership is a small state machine on the coordinator side:
//!
//! ```text
//! WaitingForMembers ──(roster ≥ min_clients)──▶ Warmup ──▶ Training
//!         ▲                                                   │
//!         └── roster shrank below the floor between rounds ◀──┘
//! ```
//!
//! Joins are admitted and leaves reaped only *between* rounds (before the
//! next round's roster is fixed), so a round's membership is stable for
//! its whole duration and slot→member assignment stays deterministic.
//! After each `RoundEnd` every member replies `Ready` (staying) or
//! `Leave` (departing), so graceful departures are observed
//! synchronously; the nonblocking sweep before each round additionally
//! reaps crashed connections and pre-first-round leaves.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use crate::comm::accounting::RoundBytes;
use crate::comm::message::Message;
use crate::comm::transport::{self, Frame, PROTOCOL_VERSION};
use crate::config::RunConfig;
use crate::coordinator::engine::{client_stream_key, ClientOutput, RoundAlgorithm};
use crate::coordinator::faults::{DropPhase, FaultPlan};
use crate::util::pool::scoped_parallel_map;
use crate::util::rng::Rng;

/// Where one shard's client steps execute. The engine calls
/// [`ClientBackend::run_shard`] once per shard per attempt and folds the
/// returned outputs in slot order; everything about *what* to run (keys,
/// plans, broadcast) is decided by the engine, everything about *where*
/// by the backend.
pub trait ClientBackend<A: RoundAlgorithm> {
    /// Execute `client_step` for every client in `shard` (paired with
    /// `plans`, same length) and return their outputs in shard-slot
    /// order. `scratches` is the engine's warm per-slot pool: in-process
    /// backends lend from it and must return every borrowed scratch;
    /// remote backends leave it untouched.
    #[allow(clippy::too_many_arguments)]
    fn run_shard(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
        scratches: &mut Vec<A::Scratch>,
    ) -> Vec<anyhow::Result<ClientOutput<A::Payload>>>;

    /// The round committed. Socket backends notify members here (the
    /// window in which clients may leave); in-process backends need not
    /// do anything.
    fn round_complete(&mut self, _round: usize) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The scoped-thread fan-out the engine always used, now behind the
/// backend seam. Behavior-preserving by construction: same
/// `client_stream_key` forks, same `scoped_parallel_map` slot order, same
/// scratch lend/recover discipline.
pub struct InProcessBackend;

impl<A: RoundAlgorithm> ClientBackend<A> for InProcessBackend {
    fn run_shard(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
        scratches: &mut Vec<A::Scratch>,
    ) -> Vec<anyhow::Result<ClientOutput<A::Payload>>> {
        debug_assert_eq!(shard.len(), plans.len(), "one plan per shard client");
        let env = algo.env();
        // lend one warm scratch per shard slot (the pool grows to the
        // largest shard slice once, then persists across shards and
        // rounds)
        while scratches.len() < shard.len() {
            scratches.push(A::Scratch::default());
        }
        let mut lent = std::mem::take(scratches);
        let spare = lent.split_off(shard.len());
        let tasks: Vec<(usize, Rng, FaultPlan, A::Scratch)> = shard
            .iter()
            .zip(plans)
            .zip(lent)
            .map(|((&ci, &plan), scratch)| {
                let key = client_stream_key(algo.stream_tag(), round as u64, ci, attempt);
                (ci, env.rng.fork(key), plan, scratch)
            })
            .collect();
        // fan the shard across the worker threads; collection is the
        // shard barrier
        let pairs = scoped_parallel_map(
            env.workers,
            tasks,
            |_slot, (ci, mut crng, plan, mut scratch)| {
                let out = algo.client_step(
                    prep, broadcast, round as u32, ci, &mut crng, &plan, &mut scratch,
                );
                (out, scratch)
            },
        );
        // recover the scratches in slot order
        let mut outs = Vec::with_capacity(shard.len());
        for (out, scratch) in pairs {
            outs.push(out);
            scratches.push(scratch);
        }
        scratches.extend(spare);
        outs
    }
}

/// Coordinator-side membership phase (see the module diagram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServicePhase {
    /// Blocking on `accept` until the roster reaches `min_clients`.
    WaitingForMembers,
    /// Roster is full but the first round hasn't started yet.
    Warmup,
    /// Rounds are running against a fixed roster.
    Training,
}

/// One admitted member connection.
struct Member {
    stream: TcpStream,
    peer: SocketAddr,
}

/// The coordinator's listening socket plus its admitted members — the
/// membership state machine that [`SocketBackend`] drives between rounds.
pub struct CoordinatorService {
    listener: TcpListener,
    members: Vec<Member>,
    min_clients: usize,
    /// The run config shipped to joiners in the `Welcome` frame; workers
    /// rebuild a bit-identical replica trainer from it.
    config_json: String,
    /// Per-connection read deadline (reuses the fault layer's
    /// `round_deadline` semantics, see [`transport::socket_deadline`]).
    read_timeout: Duration,
    phase: ServicePhase,
}

impl CoordinatorService {
    /// Bind the serve socket. `min_clients` is clamped to at least 1 —
    /// a roster floor of zero would assign work to nobody.
    pub fn bind(addr: &str, min_clients: usize, cfg: &RunConfig) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        Ok(CoordinatorService {
            listener,
            members: Vec::new(),
            min_clients: min_clients.max(1),
            config_json: cfg.to_json().to_string_pretty(),
            read_timeout: transport::socket_deadline(cfg.round_deadline),
            phase: ServicePhase::WaitingForMembers,
        })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn num_members(&self) -> usize {
        self.members.len()
    }

    pub fn phase(&self) -> ServicePhase {
        self.phase
    }

    /// Run the join handshake on a fresh connection and admit it:
    /// `Join{version}` → `Welcome{config}` → `Ready`.
    fn admit(&mut self, stream: TcpStream, peer: SocketAddr) -> anyhow::Result<()> {
        stream.set_nonblocking(false)?;
        transport::configure_stream(&stream, Some(self.read_timeout))?;
        let mut stream = stream;
        match Frame::read_from(&mut stream)? {
            Frame::Join { version } => {
                anyhow::ensure!(
                    version == PROTOCOL_VERSION,
                    "member {peer} speaks protocol v{version}, need v{PROTOCOL_VERSION}"
                );
            }
            other => anyhow::bail!("expected Join from {peer}, got {}", other.name()),
        }
        Frame::Welcome { config_json: self.config_json.clone() }.write_to(&mut stream)?;
        match Frame::read_from(&mut stream)? {
            Frame::Ready => {}
            other => anyhow::bail!("expected Ready from {peer}, got {}", other.name()),
        }
        log::info!("member joined from {peer} ({} total)", self.members.len() + 1);
        self.members.push(Member { stream, peer });
        Ok(())
    }

    /// Accept every connection already queued on the listener without
    /// blocking. A failed handshake drops that connection only.
    fn sweep_joins(&mut self) -> anyhow::Result<()> {
        self.listener.set_nonblocking(true)?;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if let Err(e) = self.admit(stream, peer) {
                        log::warn!("rejecting join from {peer}: {e:#}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    self.listener.set_nonblocking(false)?;
                    return Err(e.into());
                }
            }
        }
        self.listener.set_nonblocking(false)?;
        Ok(())
    }

    /// Reap members that left since the last round: a queued `Leave`
    /// frame or a closed connection. Anything else queued between rounds
    /// is a protocol violation and drops the member.
    fn sweep_leaves(&mut self) {
        let mut keep = Vec::with_capacity(self.members.len());
        for mut m in self.members.drain(..) {
            let mut probe = [0u8; 1];
            if m.stream.set_nonblocking(true).is_err() {
                log::warn!("member {} unreachable, dropping", m.peer);
                continue;
            }
            let verdict = match m.stream.peek(&mut probe) {
                Ok(0) => Err("connection closed".to_string()),
                Ok(_) => {
                    // a frame is queued; read it blocking — only Leave is
                    // legal between rounds
                    if m.stream.set_nonblocking(false).is_err() {
                        Err("socket error".to_string())
                    } else {
                        match Frame::read_from(&mut m.stream) {
                            Ok(Frame::Leave) => Err("left".to_string()),
                            Ok(other) => Err(format!(
                                "unexpected {} between rounds",
                                other.name()
                            )),
                            Err(e) => Err(format!("read error: {e:#}")),
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(()),
                Err(e) => Err(format!("socket error: {e}")),
            };
            match verdict {
                Ok(()) if m.stream.set_nonblocking(false).is_ok() => keep.push(m),
                Ok(()) => log::warn!("member {} unreachable, dropping", m.peer),
                Err(why) => {
                    log::info!("member {} departed ({why})", m.peer);
                }
            }
        }
        self.members = keep;
    }

    /// Fix the roster for the next round: reap leaves, admit queued
    /// joins, then block for new members until the floor is met.
    pub fn ensure_members(&mut self) -> anyhow::Result<()> {
        self.sweep_leaves();
        self.sweep_joins()?;
        while self.members.len() < self.min_clients {
            self.phase = ServicePhase::WaitingForMembers;
            log::info!(
                "waiting for members: {}/{}",
                self.members.len(),
                self.min_clients
            );
            let (stream, peer) = self.listener.accept()?;
            if let Err(e) = self.admit(stream, peer) {
                log::warn!("rejecting join from {peer}: {e:#}");
            }
        }
        if self.phase == ServicePhase::WaitingForMembers {
            self.phase = ServicePhase::Warmup;
        }
        Ok(())
    }

    /// Send one frame to every member.
    pub fn send_all(&mut self, frame: &Frame) -> anyhow::Result<()> {
        for m in &mut self.members {
            frame
                .write_to(&mut m.stream)
                .map_err(|e| anyhow::anyhow!("send {} to {}: {e:#}", frame.name(), m.peer))?;
        }
        Ok(())
    }

    fn send_to(&mut self, idx: usize, frame: &Frame) -> anyhow::Result<()> {
        let m = &mut self.members[idx];
        frame
            .write_to(&mut m.stream)
            .map_err(|e| anyhow::anyhow!("send {} to {}: {e:#}", frame.name(), m.peer))
    }

    fn read_from(&mut self, idx: usize) -> anyhow::Result<Frame> {
        let m = &mut self.members[idx];
        Frame::read_from(&mut m.stream)
            .map_err(|e| anyhow::anyhow!("read from {}: {e:#}", m.peer))
    }

    /// After `RoundEnd`, every member declares its intent for the next
    /// round: `Ready` to stay, `Leave` to depart. Reading exactly one
    /// reply per member closes the membership race — a graceful leave is
    /// always observed here, never discovered later as a dead socket in
    /// the middle of the next round's state sync. A member that answers
    /// anything else (or whose connection fails) is dropped.
    fn collect_round_acks(&mut self) {
        let mut keep = Vec::with_capacity(self.members.len());
        for mut m in std::mem::take(&mut self.members) {
            match Frame::read_from(&mut m.stream) {
                Ok(Frame::Ready) => keep.push(m),
                Ok(Frame::Leave) => {
                    log::info!("member {} left after the round", m.peer);
                }
                Ok(other) => log::warn!(
                    "member {}: unexpected {} after RoundEnd, dropping",
                    m.peer,
                    other.name()
                ),
                Err(e) => log::warn!("member {} lost after RoundEnd ({e:#})", m.peer),
            }
        }
        self.members = keep;
    }

    /// Drop the members flagged `true` in `dead` (parallel to the member
    /// list): their connections are severed and they leave the roster.
    /// Called after a shard completes so slot→member assignment stays
    /// fixed for the shard's whole duration.
    fn reap(&mut self, dead: &[bool]) {
        debug_assert_eq!(dead.len(), self.members.len());
        let mut idx = 0usize;
        self.members.retain(|m| {
            let keep = !dead[idx];
            if !keep {
                log::warn!("reaping member {} after mid-round failure", m.peer);
            }
            idx += 1;
            keep
        });
    }

    /// Best-effort shutdown: tell every member the run is over.
    pub fn shutdown(&mut self) {
        for m in &mut self.members {
            let _ = Frame::Shutdown.write_to(&mut m.stream);
        }
        self.members.clear();
    }
}

/// The TCP loopback backend: assignments fan out over member connections
/// in slot order (slot `i` → member `i mod W`), results stream back over
/// the same FIFO connections, so reading per slot in order cannot
/// deadlock (every member's frames arrive in its assignment order).
pub struct SocketBackend {
    service: CoordinatorService,
    /// Round whose state/broadcast the members already hold; re-synced
    /// once per round (not per shard or attempt).
    synced_round: Option<usize>,
}

impl SocketBackend {
    pub fn new(service: CoordinatorService) -> Self {
        SocketBackend { service, synced_round: None }
    }

    pub fn service(&self) -> &CoordinatorService {
        &self.service
    }

    fn run_shard_inner<A: RoundAlgorithm>(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
    ) -> anyhow::Result<Vec<anyhow::Result<ClientOutput<A::Payload>>>> {
        debug_assert_eq!(shard.len(), plans.len(), "one plan per shard client");
        if shard.is_empty() {
            return Ok(Vec::new());
        }
        // fix the roster and ship the round's state + broadcast once per
        // round; later shards and resampled attempts reuse them (the
        // broadcast can't change between attempts)
        if self.synced_round != Some(round) {
            self.service.ensure_members()?;
            self.service.phase = ServicePhase::Training;
            let tensors = algo.round_state(prep);
            self.service
                .send_all(&Frame::RoundState { round: round as u32, tensors })?;
            self.service.send_all(&Frame::Broadcast {
                round: round as u32,
                message: broadcast.encode(round as u32, 0),
            })?;
            self.synced_round = Some(round);
        }
        let w = self.service.num_members();
        anyhow::ensure!(w > 0, "no members to run round {round} on");
        // write every assignment first, then collect results in slot
        // order: per-connection FIFO makes this deadlock-free. A member
        // that misbehaves mid-shard — malformed frame, wrong client,
        // undecodable payload, dead socket — is marked dead: its slots
        // become `PeerFailure` drops (metered through `DropCounts` like
        // any other drop, zero bytes both in the meter and the partial,
        // so the engine's meter-vs-partials assertion still holds) and
        // the connection is reaped after the shard. A byzantine socket
        // peer therefore cannot abort the coordinator's round.
        let mut dead = vec![false; w];
        for (slot, (&ci, &plan)) in shard.iter().zip(plans).enumerate() {
            let m = slot % w;
            if dead[m] {
                continue;
            }
            let assign = Frame::StepAssign {
                round: round as u32,
                attempt,
                client: ci as u64,
                plan,
            };
            if let Err(e) = self.service.send_to(m, &assign) {
                log::warn!("assign for client {ci} failed, marking member dead: {e:#}");
                dead[m] = true;
            }
        }
        let failed = || {
            Ok(ClientOutput::failed(
                DropPhase::PeerFailure,
                0.0,
                RoundBytes::default(),
                0.0,
            ))
        };
        let mut outs = Vec::with_capacity(shard.len());
        for (slot, &ci) in shard.iter().enumerate() {
            let m = slot % w;
            if dead[m] {
                outs.push(failed());
                continue;
            }
            match self.read_from(m) {
                Ok(Frame::StepResult(r)) => {
                    if r.client != ci as u64 {
                        log::warn!(
                            "member answered client {} for assigned client {ci}, \
                             marking dead",
                            r.client
                        );
                        dead[m] = true;
                        outs.push(failed());
                        continue;
                    }
                    let payload = match r.payload.map(|p| algo.payload_from_wire(p)) {
                        Some(Ok(p)) => Some(p),
                        Some(Err(e)) => {
                            log::warn!(
                                "undecodable payload from client {ci}'s member, \
                                 marking dead: {e:#}"
                            );
                            dead[m] = true;
                            outs.push(failed());
                            continue;
                        }
                        None => None,
                    };
                    // the worker metered its own transfers; replay them
                    // into the coordinator's meter so per-round deltas,
                    // cumulative totals, and the engine's meter-vs-partials
                    // assertion match the in-process run exactly
                    algo.env().net.absorb(&r.bytes);
                    outs.push(Ok(ClientOutput {
                        weight: r.weight,
                        loss: r.loss,
                        metric_sums: r.metric_sums,
                        quant_rel_err: r.quant_rel_err,
                        surrogate_loss: r.surrogate_loss,
                        payload,
                        bytes: r.bytes,
                        dropped: r.dropped,
                        delay_seconds: r.delay_seconds,
                    }));
                }
                Ok(Frame::StepError { client, error }) => {
                    // the worker failed this step but the frame protocol
                    // is intact (exactly one reply per assignment), so
                    // the member stays; only the client drops
                    log::warn!("remote client {client} failed, metering as a drop: {error}");
                    outs.push(failed());
                }
                Ok(other) => {
                    log::warn!(
                        "expected StepResult for client {ci}, got {}; marking member dead",
                        other.name()
                    );
                    dead[m] = true;
                    outs.push(failed());
                }
                Err(e) => {
                    log::warn!(
                        "read for client {ci} failed, marking member dead: {e:#}"
                    );
                    dead[m] = true;
                    outs.push(failed());
                }
            }
        }
        self.service.reap(&dead);
        Ok(outs)
    }

    fn read_from(&mut self, idx: usize) -> anyhow::Result<Frame> {
        self.service.read_from(idx)
    }
}

impl<A: RoundAlgorithm> ClientBackend<A> for SocketBackend {
    fn run_shard(
        &mut self,
        algo: &A,
        prep: &A::Prep,
        broadcast: &Message,
        round: usize,
        attempt: u32,
        shard: &[usize],
        plans: &[FaultPlan],
        _scratches: &mut Vec<A::Scratch>,
    ) -> Vec<anyhow::Result<ClientOutput<A::Payload>>> {
        match self.run_shard_inner(algo, prep, broadcast, round, attempt, shard, plans) {
            Ok(outs) => outs,
            // a transport-level failure aborts the round (the engine's
            // `?` in Aggregate surfaces it); the byte meter still closes
            Err(e) => vec![Err(e)],
        }
    }

    fn round_complete(&mut self, round: usize) -> anyhow::Result<()> {
        self.service.send_all(&Frame::RoundEnd { round: round as u32 })?;
        self.service.collect_round_acks();
        Ok(())
    }
}

impl Drop for SocketBackend {
    fn drop(&mut self) {
        self.service.shutdown();
    }
}
