//! # FedLite — communication-efficient split federated learning
//!
//! Rust + JAX + Pallas reproduction of *"FedLite: A Scalable Approach for
//! Federated Learning on Resource-constrained Clients"* (Wang et al., 2022).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1 (Pallas)** — the grouped product-quantizer kernels
//!   (`python/compile/kernels/pq.py`), lowered inside the L2 graphs.
//! * **L2 (JAX)** — the split models (`client_fwd`, `server_step`,
//!   `client_bwd`, …) AOT-lowered to HLO text under `artifacts/`.
//! * **L3 (this crate)** — federated orchestration: client sampling, the
//!   SplitFed/FedLite/FedAvg round state machines, the PQ compression
//!   engine, byte-accurate communication accounting, optimizers, metrics,
//!   and the experiment drivers that regenerate every table and figure of
//!   the paper.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! models once; afterwards the `fedlite` binary is self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | substrates built in-tree (PRNG, JSON, CLI, thread pool, logging) |
//! | [`tensor`] | small row-major f32 tensor + the tiled deterministic GEMM kernels |
//! | [`quantizer`] | native grouped-PQ engine + bit-packing + cost model |
//! | [`runtime`] | PJRT artifact loading/execution (the `xla` crate) |
//! | [`optim`] | SGD / Adam / AdaGrad (paper §C.2 per-task optimizers) |
//! | [`data`] | synthetic federated datasets (FEMNIST / SO Tag / SO NWP) |
//! | [`comm`] | wire format, simulated links, byte accounting |
//! | [`models`] | split-model metadata + Table-1 cost analytics |
//! | [`coordinator`] | FedLite / SplitFed / FedAvg round loops |
//! | [`config`] | typed run configuration + presets |
//! | [`metrics`] | accuracy/recall/loss aggregation and run logs |
//! | [`experiments`] | drivers for Table 1 and Figures 3–6 |

// Style lints the numeric code intentionally trades away: indexed loops
// over flat buffers mirror the math notation, config presets assign onto
// a Default base, and `Tensor::add` follows the BLAS-ish naming of its
// siblings (`axpy`, `scale`) rather than `std::ops::Add`.
#![allow(
    clippy::needless_range_loop,
    clippy::field_reassign_with_default,
    clippy::should_implement_trait,
    clippy::len_without_is_empty,
    clippy::new_without_default,
    clippy::manual_range_contains,
    clippy::too_many_arguments
)]

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod optim;
pub mod quantizer;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use config::RunConfig;
