//! `fedlite` — the Layer-3 leader binary.
//!
//! Subcommands: `train` (one configured run), `exp` (regenerate a paper
//! table/figure), `inspect` (artifact manifest), `quantize` (PQ demo on
//! artifact activations). Run `fedlite <cmd> --help` for flags.

use std::sync::Arc;

use fedlite::config::{
    AggregationRule, Algorithm, ByzantineKind, QuantizerEngine, RunConfig,
};
use fedlite::coordinator::{build_trainer, Trainer};
use fedlite::experiments::{fig3, fig4, fig5, fig6, table1};
use fedlite::quantizer::pq::PqConfig;
use fedlite::runtime::Runtime;
use fedlite::util::cli::{Cli, Command, Flag};
use fedlite::util::logging;

/// Flags shared by `train` and `serve` (a serve is a train whose client
/// fan-out runs on socket members instead of in-process threads).
fn train_flags() -> Vec<Flag> {
    vec![
        Flag::opt("task", "femnist", "femnist | so_tag | so_nwp"),
        Flag::opt(
            "preset",
            "",
            "'' = task default (PJRT artifacts); tiny | small | \
             stress = built-in native <task>_<preset> variants \
             (no artifacts needed; stress is femnist-only, at \
             the paper-scale cut)",
        ),
        Flag::opt("algorithm", "fedlite", "fedlite | splitfed | fedavg"),
        Flag::opt(
            "workers",
            "0",
            "cohort worker threads; 0 = one per core, 1 = serial \
             (results are bit-identical at any value)",
        ),
        Flag::opt(
            "shards",
            "1",
            "independent cohort shards per round, each with its \
             own fault plans and worker fan-out (results are \
             bit-identical at any value)",
        ),
        Flag::opt("rounds", "100", "number of federated rounds"),
        Flag::opt("clients", "100", "population size M"),
        Flag::opt("clients-per-round", "0", "cohort size S (0 = preset)"),
        Flag::opt("local-steps", "1", "FedAvg local steps H"),
        Flag::opt("q", "0", "subvectors per activation (0 = preset)"),
        Flag::opt("l", "0", "centroids per group (0 = preset)"),
        Flag::opt("r", "1", "groups sharing a codebook"),
        Flag::opt("kmeans-iters", "0", "Lloyd iterations (0 = preset)"),
        Flag::opt("lambda", "-1", "gradient-correction strength (-1 = preset)"),
        Flag::opt("quantizer", "native", "native | pjrt (Pallas artifact)"),
        Flag::opt("lr", "0", "learning rate override (0 = preset)"),
        Flag::opt("alpha", "0.3", "Dirichlet non-IID concentration"),
        Flag::opt(
            "drop-prob",
            "0",
            "per-client probability of mid-round failure \
             (after fwd / after upload / before grad upload)",
        ),
        Flag::opt(
            "straggler-frac",
            "0",
            "fraction of clients that straggle each round",
        ),
        Flag::opt(
            "round-deadline",
            "0",
            "simulated round deadline in seconds; stragglers \
             past it are evicted (0 = no deadline)",
        ),
        Flag::opt(
            "min-survivors",
            "0",
            "abort + resample the round when fewer clients \
             survive (0 = never abort)",
        ),
        Flag::opt(
            "byzantine-frac",
            "0",
            "per-client probability of byzantine behavior each round \
             (0 = all honest)",
        ),
        Flag::opt(
            "byzantine-kind",
            "sign_flip",
            "attack model: grad_scale | sign_flip | label_flip | \
             corrupt_codeword | replay",
        ),
        Flag::opt(
            "clip-norm",
            "0",
            "L2-clip each surviving update to this norm before \
             aggregation (0 = no clipping)",
        ),
        Flag::opt(
            "aggregation",
            "mean",
            "server aggregation rule: mean | trimmed | median",
        ),
        Flag::opt("seed", "17", "root RNG seed"),
        Flag::opt("eval-every", "10", "eval period in rounds (0 = never)"),
        Flag::opt("artifacts", "artifacts", "artifacts directory"),
        Flag::opt("out-dir", "", "write per-round CSV/JSONL here"),
        Flag::opt("save", "", "write final model checkpoint here"),
        Flag::opt(
            "checkpoint-every",
            "0",
            "also write the --save checkpoint every N committed rounds \
             (0 = only at the end); a later --resume continues \
             bit-identically",
        ),
        Flag::opt(
            "resume",
            "",
            "resume a split-family run from a checkpoint written with \
             --save (continues at its recorded round, bit-identical to \
             the uninterrupted run)",
        ),
        Flag::opt(
            "backend",
            "inprocess",
            "inprocess | socket (socket = serve client steps to \
             fedlite-client processes; records are bit-identical)",
        ),
        Flag::opt("listen", "127.0.0.1:7878", "socket backend: listen address"),
        Flag::opt(
            "min-clients",
            "1",
            "socket backend: block until this many members joined \
             before each round",
        ),
        Flag::opt(
            "socket-deadline-floor",
            "30",
            "socket backend: floor in seconds under the per-slot \
             progress deadline max(--round-deadline, floor); lower it \
             to quarantine stragglers faster",
        ),
        Flag::opt(
            "chaos-drop",
            "0",
            "socket chaos: probability a StepAssign is lost in flight \
             (deterministic per (round, member, frame); the lost slot \
             is redelivered as a reassignment)",
        ),
        Flag::opt(
            "chaos-delay-ms",
            "0",
            "socket chaos: workers delay each reply by a deterministic \
             uniform(0, this) milliseconds",
        ),
        Flag::opt(
            "chaos-truncate",
            "0",
            "socket chaos: probability a worker truncates a reply \
             mid-frame and severs its connection (it reconnects with \
             backoff; the slot is reassigned)",
        ),
        Flag::opt("log", "info", "log level"),
    ]
}

fn cli() -> Cli {
    Cli {
        bin: "fedlite",
        about: "communication-efficient split federated learning (FedLite reproduction)",
        commands: vec![
            Command {
                name: "train",
                about: "run one federated training job",
                flags: train_flags(),
            },
            Command {
                name: "serve",
                about: "run one training job serving client steps over TCP \
                        (train with --backend socket)",
                flags: train_flags(),
            },
            Command {
                name: "join",
                about: "join a serving coordinator as a replica worker \
                        (standalone binary: fedlite-client)",
                flags: vec![
                    Flag::opt("connect", "127.0.0.1:7878", "coordinator address"),
                    Flag::opt(
                        "max-rounds",
                        "0",
                        "leave gracefully after serving this many rounds \
                         (0 = serve until shutdown)",
                    ),
                    Flag::opt(
                        "reconnect-tries",
                        "5",
                        "consecutive failed connects tolerated before \
                         giving up (budget refills after each successful \
                         handshake)",
                    ),
                    Flag::opt(
                        "backoff-ms",
                        "100",
                        "base reconnect delay; doubles per consecutive \
                         failure, capped at 10s",
                    ),
                    Flag::opt(
                        "straggle-ms",
                        "0",
                        "debug: sleep this long before every reply, making \
                         this worker a deterministic straggler",
                    ),
                    Flag::opt("log", "info", "log level"),
                ],
            },
            Command {
                name: "exp",
                about: "regenerate a paper table/figure: table1|fig3|fig4|fig5ab|fig5c|fig6",
                flags: vec![
                    Flag::opt("rounds", "0", "training rounds per point (0 = default)"),
                    Flag::opt("task", "femnist", "task for fig4"),
                    Flag::opt(
                        "preset",
                        "",
                        "fig4: '' = PJRT task preset (needs artifacts); \
                         tiny | small | stress = native <task>_<preset> \
                         variant (end-to-end, no artifacts)",
                    ),
                    Flag::opt("points", "3", "points per curve for fig4"),
                    Flag::opt("seed", "17", "seed"),
                    Flag::opt("artifacts", "artifacts", "artifacts directory"),
                    Flag::switch("no-measure", "table1: skip the measured round"),
                    Flag::opt("log", "info", "log level"),
                ],
            },
            Command {
                name: "inspect",
                about: "list artifacts and model specs from the manifest",
                flags: vec![
                    Flag::opt("artifacts", "artifacts", "artifacts directory"),
                    Flag::switch("compile", "compile every artifact (slow)"),
                    Flag::opt("log", "warn", "log level"),
                ],
            },
            Command {
                name: "quantize",
                about: "quantize one batch of FEMNIST activations and report sizes",
                flags: vec![
                    Flag::opt("q", "1152", "subvectors"),
                    Flag::opt("l", "2", "centroids"),
                    Flag::opt("r", "1", "groups"),
                    Flag::opt("engine", "native", "native | pjrt"),
                    Flag::opt("artifacts", "artifacts", "artifacts directory"),
                    Flag::opt("seed", "33", "seed"),
                    Flag::opt("log", "warn", "log level"),
                ],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let inv = match cli().parse(&argv) {
        Ok(inv) => inv,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if argv.is_empty() { 2 } else { 0 });
        }
    };
    if let Err(e) = dispatch(inv.command, &inv.args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &fedlite::util::cli::Args) -> anyhow::Result<()> {
    logging::init(args.get("log").unwrap_or("info"));
    match cmd {
        "train" => cmd_train(args, false),
        "serve" => cmd_train(args, true),
        "join" => cmd_join(args),
        "exp" => cmd_exp(args),
        "inspect" => cmd_inspect(args),
        "quantize" => cmd_quantize(args),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn cmd_join(args: &fedlite::util::cli::Args) -> anyhow::Result<()> {
    let opts = fedlite::coordinator::worker::WorkerOptions {
        max_rounds: args.usize("max-rounds")?,
        reconnect_tries: args.usize("reconnect-tries")? as u32,
        backoff_ms: args.u64("backoff-ms")?,
        straggle_ms: args.u64("straggle-ms")?,
    };
    fedlite::coordinator::worker::run_worker(args.str("connect")?, opts)
}

fn cmd_train(args: &fedlite::util::cli::Args, force_socket: bool) -> anyhow::Result<()> {
    let task = args.str("task")?;
    let preset = args.get("preset").unwrap_or("");
    let native_preset = matches!(preset, "tiny" | "small" | "stress");
    let mut cfg = match preset {
        "" => RunConfig::preset(task)?,
        p if native_preset => RunConfig::native(task, p)?,
        other => {
            anyhow::bail!("unknown preset '{other}' (try '', tiny, small, or stress)")
        }
    };
    cfg.algorithm = Algorithm::parse(args.str("algorithm")?)?;
    cfg.workers = args.usize("workers")?;
    cfg.shards = args.usize("shards")?;
    cfg.rounds = args.usize("rounds")?;
    cfg.num_clients = args.usize("clients")?;
    let s = args.usize("clients-per-round")?;
    if s > 0 {
        cfg.clients_per_round = s;
    }
    cfg.local_steps = args.usize("local-steps")?;
    let (q, l, r) = (args.usize("q")?, args.usize("l")?, args.usize("r")?);
    if q > 0 && l > 0 {
        cfg.pq = PqConfig::new(q, r.max(1), l);
    }
    let iters = args.usize("kmeans-iters")?;
    if iters > 0 {
        cfg.pq = cfg.pq.with_iters(iters);
    }
    let lam = args.f64("lambda")?;
    if lam >= 0.0 {
        cfg.lambda = lam as f32;
    }
    cfg.quantizer = match args.str("quantizer")? {
        "pjrt" => QuantizerEngine::Pjrt,
        _ => QuantizerEngine::Native,
    };
    let lr = args.f64("lr")?;
    if lr > 0.0 {
        cfg.client_lr = lr as f32;
        cfg.server_lr = lr as f32;
    }
    cfg.alpha = args.f64("alpha")?;
    cfg.drop_prob = args.prob("drop-prob")?;
    cfg.straggler_frac = args.prob("straggler-frac")?;
    cfg.round_deadline = args.f64("round-deadline")?;
    cfg.min_survivors = args.usize("min-survivors")?;
    cfg.byzantine_frac = args.prob("byzantine-frac")?;
    cfg.byzantine_kind = ByzantineKind::parse(args.str("byzantine-kind")?)?;
    cfg.clip_norm = args.f64("clip-norm")?;
    cfg.aggregation = AggregationRule::parse(args.str("aggregation")?)?;
    cfg.seed = args.u64("seed")?;
    cfg.eval_every = args.usize("eval-every")?;
    cfg.chaos_drop = args.prob("chaos-drop")?;
    cfg.chaos_delay_ms = args.f64("chaos-delay-ms")?;
    cfg.chaos_truncate = args.prob("chaos-truncate")?;
    cfg.socket_deadline_floor = args.f64("socket-deadline-floor")?;
    cfg.checkpoint_every = args.usize("checkpoint-every")?;
    // the native presets always run on the built-in native engine
    if !native_preset {
        cfg.artifacts_dir = args.str("artifacts")?.to_string();
    }
    cfg.out_dir = args.get("out-dir").unwrap_or("").to_string();

    let rt = Arc::new(Runtime::open(&cfg.artifacts_dir)?);
    log::info!(
        "platform={} task={} algo={} rounds={} S={}/{} workers={} shards={} q={} \
         L={} R={} lambda={} quantizer={:?}",
        rt.platform(), cfg.task, cfg.algorithm.name(), cfg.rounds,
        cfg.clients_per_round, cfg.num_clients, cfg.resolved_workers(),
        cfg.shards, cfg.pq.q, cfg.pq.l, cfg.pq.r, cfg.lambda, cfg.quantizer
    );
    if cfg.drop_prob > 0.0 || cfg.straggler_frac > 0.0 || cfg.min_survivors > 0 {
        log::info!(
            "faults: drop_prob={} straggler_frac={} round_deadline={}s min_survivors={}",
            cfg.drop_prob, cfg.straggler_frac, cfg.round_deadline, cfg.min_survivors
        );
    }
    if cfg.byzantine_frac > 0.0 || cfg.clip_norm > 0.0
        || cfg.aggregation != AggregationRule::Mean
    {
        log::info!(
            "threat model: byzantine_frac={} kind={} clip_norm={} aggregation={}",
            cfg.byzantine_frac,
            cfg.byzantine_kind.name(),
            cfg.clip_norm,
            cfg.aggregation.name()
        );
    }
    let backend = if force_socket { "socket" } else { args.str("backend")? };
    let save = args.get("save").unwrap_or("").to_string();
    let resume = args.get("resume").unwrap_or("").to_string();
    let run_log = if backend == "socket" {
        if !save.is_empty() || !resume.is_empty() {
            log::warn!("--save/--resume are not supported with the socket backend; ignoring");
        }
        run_socket(cfg, rt, args.str("listen")?, args.usize("min-clients")?)?
    } else if backend != "inprocess" {
        anyhow::bail!("unknown backend '{backend}' (try inprocess or socket)")
    } else if (!save.is_empty() || !resume.is_empty()) && cfg.algorithm != Algorithm::FedAvg
    {
        use fedlite::coordinator::checkpoint;
        use fedlite::coordinator::engine::RoundEngine;
        // keep the concrete trainer so parameters can be restored/saved
        let data = fedlite::coordinator::build_dataset(&cfg)?;
        let checkpoint_every = cfg.checkpoint_every;
        let cfg_save = cfg.clone();
        let mut trainer =
            fedlite::coordinator::split::SplitTrainer::new(cfg, rt, data)?;
        let mut start_round = 0usize;
        if !resume.is_empty() {
            let (wc, ws, done) = checkpoint::load_resume(&resume)?;
            trainer.set_params(wc, ws);
            start_round = done;
            log::info!("resuming from {resume}: {done} rounds already committed");
        }
        // periodic checkpoints land on the --save path, falling back to
        // overwriting the resumed file; round r's bits depend only on
        // (r, attempt, client) keys and the restored parameters, so the
        // continued run is bit-identical to the uninterrupted one
        let ckpt_path = if save.is_empty() { resume.clone() } else { save.clone() };
        let log = RoundEngine::new(&mut trainer).run_hooked(
            start_round,
            checkpoint_every,
            |t, done| {
                let (wc, ws) = t.params();
                checkpoint::save(&ckpt_path, wc, ws, Some(&cfg_save), done)
            },
        )?;
        let (wc, ws) = trainer.params();
        checkpoint::save(&ckpt_path, wc, ws, Some(&cfg_save), cfg_save.rounds)?;
        println!("checkpoint written to {ckpt_path}");
        log
    } else {
        if !save.is_empty() || !resume.is_empty() {
            log::warn!("--save/--resume are only supported for split algorithms; ignoring");
        }
        if cfg.checkpoint_every > 0 {
            log::warn!("--checkpoint-every needs --save or --resume; ignoring");
        }
        let mut trainer = build_trainer(cfg, rt)?;
        trainer.run()?
    };
    if let Some(last) = run_log.last() {
        println!(
            "done: rounds={} final_loss={:.4} final_metric={:.4} \
             best_eval_metric={:?} total_uplink={}B",
            run_log.rounds.len(),
            last.train_loss,
            last.train_metric,
            run_log.best_eval_metric(),
            run_log.total_uplink()
        );
    }
    Ok(())
}

/// Serve a training run over TCP: bind, wait for members, then drive the
/// same round engine with a `SocketBackend`. The phase machine, RNG keys,
/// and reduction order are untouched, so the records are byte-identical
/// to the in-process run of the same config (CI diffs the CSVs).
fn run_socket(
    cfg: RunConfig,
    rt: Arc<Runtime>,
    listen: &str,
    min_clients: usize,
) -> anyhow::Result<fedlite::metrics::RunLog> {
    use fedlite::coordinator::backend::{CoordinatorService, SocketBackend};
    use fedlite::coordinator::engine::RoundEngine;
    cfg.validate()?;
    let service = CoordinatorService::bind(listen, min_clients, &cfg)?;
    log::info!(
        "serving on {} (min_clients={})",
        service.local_addr()?,
        min_clients.max(1)
    );
    let data = fedlite::coordinator::build_dataset(&cfg)?;
    match cfg.algorithm {
        Algorithm::FedAvg => {
            let mut t = fedlite::coordinator::fedavg::FedAvgTrainer::new(cfg, rt, data)?;
            RoundEngine::with_backend(&mut t, Box::new(SocketBackend::new(service))).run()
        }
        Algorithm::FedLite | Algorithm::SplitFed => {
            let mut t = fedlite::coordinator::split::SplitTrainer::new(cfg, rt, data)?;
            RoundEngine::with_backend(&mut t, Box::new(SocketBackend::new(service))).run()
        }
    }
}

fn cmd_exp(args: &fedlite::util::cli::Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: fedlite exp <table1|fig3|fig4|fig5ab|fig5c|fig6>"))?
        .clone();
    let artifacts = args.str("artifacts")?;
    let rounds = args.usize("rounds")?;
    let seed = args.u64("seed")?;
    std::fs::create_dir_all("results").ok();
    match which.as_str() {
        "table1" => {
            let rt = Runtime::open(artifacts).ok().map(Arc::new);
            let opts = table1::Table1Options {
                measure: !args.has("no-measure"),
                ..Default::default()
            };
            table1::run(&opts, rt)
        }
        "fig3" => {
            let rt = Arc::new(Runtime::open(artifacts)?);
            let opts = fig3::Fig3Options { seed, ..Default::default() };
            fig3::run(&opts, rt)
        }
        "fig4" => {
            let preset = args.get("preset").unwrap_or("").to_string();
            // native presets run on the built-in engine; no artifacts dir
            let rt = if preset.is_empty() {
                Arc::new(Runtime::open(artifacts)?)
            } else {
                Arc::new(Runtime::native())
            };
            let mut opts = fig4::Fig4Options {
                task: args.str("task")?.to_string(),
                preset,
                points: args.usize("points")?,
                seed,
                ..Default::default()
            };
            if rounds > 0 {
                opts.rounds = rounds;
            }
            fig4::run(&opts, rt)
        }
        "fig5ab" | "fig5c" => {
            let rt = Arc::new(Runtime::open(artifacts)?);
            let mut opts = fig5::Fig5Options { seed, ..Default::default() };
            if rounds > 0 {
                opts.rounds = rounds;
            }
            if which == "fig5ab" {
                fig5::run_ab(&opts, rt)
            } else {
                fig5::run_c(&opts, rt)
            }
        }
        "fig6" => {
            let rt = Arc::new(Runtime::open(artifacts)?);
            let mut opts = fig6::Fig6Options { seed, ..Default::default() };
            if rounds > 0 {
                opts.rounds = rounds;
            }
            fig6::run(&opts, rt)
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
}

fn cmd_inspect(args: &fedlite::util::cli::Args) -> anyhow::Result<()> {
    let rt = Runtime::open(args.str("artifacts")?)?;
    println!("platform: {} | jax: {}", rt.platform(), rt.manifest.jax_version);
    let mut names: Vec<&String> = rt.manifest.variants.keys().collect();
    names.sort();
    for vname in names {
        let v = &rt.manifest.variants[vname];
        println!(
            "\n[{vname}] cut_dim={} act_batch={} params: client={} ({:.2}%), server={}",
            v.spec.cut_dim,
            v.spec.act_batch,
            v.spec.client.numel(),
            100.0 * v.spec.client_fraction(),
            v.spec.server.numel(),
        );
        let mut anames: Vec<&String> = v.artifacts.keys().collect();
        anames.sort();
        for a in anames {
            let art = &v.artifacts[a];
            println!("  {a:<22} inputs={} outputs={}", art.inputs.len(), art.outputs.len());
            if args.has("compile") {
                let dt = rt.precompile(vname, &[a.as_str()])?;
                println!("    compiled in {dt:.2}s");
            }
        }
    }
    Ok(())
}

fn cmd_quantize(args: &fedlite::util::cli::Args) -> anyhow::Result<()> {
    use fedlite::quantizer::cost::CostModel;
    let rt = Arc::new(Runtime::open(args.str("artifacts")?)?);
    let seed = args.u64("seed")?;
    let (z, b, d) = fig3::femnist_activations(&rt, seed)?;
    let cfg = PqConfig::new(args.usize("q")?, args.usize("r")?, args.usize("l")?);
    let engine = match args.str("engine")? {
        "pjrt" => QuantizerEngine::Pjrt,
        _ => QuantizerEngine::Native,
    };
    let backend = fedlite::coordinator::quantize::QuantizeBackend::new(
        engine, cfg, d, Arc::clone(&rt), "femnist_paper",
    )?;
    let mut rng = fedlite::util::rng::Rng::new(seed);
    let t0 = std::time::Instant::now();
    let out = backend.quantize(&z, b, &mut rng)?;
    let dt = t0.elapsed().as_secs_f64();
    let cm = CostModel::default();
    println!(
        "engine={} q={} R={} L={} | d={d} B={b}\n\
         relative_error={:.5} kappa={:.4}\n\
         paper-ratio={:.1}x wire_bytes={} raw_bytes={} wire-ratio={:.1}x\n\
         quantize_time={:.3}s ({:.1} MB/s)",
        backend.engine_name(), cfg.q, cfg.r, cfg.l,
        out.relative_error(&z), out.kappa(&z),
        cm.ratio(b, d, cfg.q, cfg.r, cfg.l),
        cm.wire_bytes(b, d, cfg.q, cfg.r, cfg.l),
        b * d * 4,
        (b * d * 4) as f64 / cm.wire_bytes(b, d, cfg.q, cfg.r, cfg.l) as f64,
        dt,
        (b * d * 4) as f64 / dt / 1e6,
    );
    Ok(())
}
