//! Figure 5 ablations on FEMNIST.
//!
//! * **5a/5b** — accuracy for a grid of (q, L) at each *fixed* λ value
//!   (the paper shows that one small positive λ helps nearly all pairs).
//! * **5c** — grouping ablation: ours (R=1) vs vanilla PQ (R=q) at matched
//!   (q, L): same quantization levels, an order of magnitude apart in
//!   compression ratio, minimal accuracy gap.

use std::sync::Arc;

use crate::config::{Algorithm, RunConfig};
use crate::experiments::run_config;
use crate::quantizer::compression_ratio;
use crate::quantizer::pq::PqConfig;
use crate::runtime::Runtime;
use crate::util::logging::CsvWriter;

pub struct Fig5Options {
    pub rounds: usize,
    pub seed: u64,
}

impl Default for Fig5Options {
    fn default() -> Self {
        Fig5Options { rounds: 50, seed: 21 }
    }
}

/// Fig 5a/5b: λ grid ablation.
pub fn run_ab(opts: &Fig5Options, rt: Arc<Runtime>) -> anyhow::Result<()> {
    let lambdas = [0.0f32, 1e-5, 5e-5, 1e-4, 5e-4];
    let grid = [(288usize, 8usize), (288, 32), (1152, 2), (1152, 8)];
    let mut csv = CsvWriter::create(
        "results/fig5ab.csv",
        &["q", "l", "lambda", "final_metric", "final_loss", "diverged"],
    )?;
    println!("Figure 5a/b — FEMNIST λ ablation ({} rounds)", opts.rounds);
    println!("{:>6} {:>4} {:>9} {:>10} {:>9}", "q", "L", "lambda", "metric", "loss");
    for (q, l) in grid {
        for lam in lambdas {
            let mut cfg = RunConfig::preset("femnist")?;
            cfg.algorithm = Algorithm::FedLite;
            cfg.rounds = opts.rounds;
            cfg.seed = opts.seed;
            cfg.num_clients = 50;
            cfg.eval_every = (opts.rounds / 3).max(1);
            cfg.eval_batches = 6;
            cfg.pq = PqConfig::new(q, 1, l);
            cfg.lambda = lam;
            let (metric, loss, diverged) = match run_config(cfg, Arc::clone(&rt)) {
                Ok(log) => (
                    log.final_eval_metric(2).unwrap_or(0.0),
                    log.final_train_loss(3),
                    false,
                ),
                Err(e) if e.to_string().contains("diverged") => (0.0, f64::NAN, true),
                Err(e) => return Err(e),
            };
            println!("{q:>6} {l:>4} {lam:>9.0e} {metric:>10.4} {loss:>9.4}{}",
                     if diverged { "  DIVERGED" } else { "" });
            csv.row(&[
                q.to_string(), l.to_string(), format!("{lam:e}"),
                format!("{metric:.5}"), format!("{loss:.5}"),
                (diverged as u8).to_string(),
            ])?;
        }
    }
    csv.flush()?;
    println!("wrote results/fig5ab.csv");
    Ok(())
}

/// Fig 5c: grouped (R=1) vs vanilla PQ (R=q).
pub fn run_c(opts: &Fig5Options, rt: Arc<Runtime>) -> anyhow::Result<()> {
    let spec = rt.manifest.variant("femnist_paper")?.spec.clone();
    let (b, d) = (spec.act_batch, spec.cut_dim);
    let grid = [(288usize, 8usize), (1152, 2), (1152, 8)];
    let mut csv = CsvWriter::create(
        "results/fig5c.csv",
        &["scheme", "q", "r", "l", "compression_ratio", "final_metric", "diverged"],
    )?;
    println!("Figure 5c — grouping ablation ({} rounds)", opts.rounds);
    println!("{:<12} {:>6} {:>6} {:>4} {:>11} {:>10}", "scheme", "q", "R", "L", "ratio", "metric");
    for (q, l) in grid {
        for (scheme, r) in [("grouped", 1usize), ("vanilla_pq", q)] {
            let mut cfg = RunConfig::preset("femnist")?;
            cfg.algorithm = Algorithm::FedLite;
            cfg.rounds = opts.rounds;
            cfg.seed = opts.seed;
            cfg.num_clients = 50;
            cfg.eval_every = (opts.rounds / 3).max(1);
            cfg.eval_batches = 6;
            cfg.pq = PqConfig::new(q, r, l);
            cfg.lambda = 1e-4;
            let ratio = compression_ratio(b, d, q, r, l);
            let (metric, diverged) = match run_config(cfg, Arc::clone(&rt)) {
                Ok(log) => (log.final_eval_metric(2).unwrap_or(0.0), false),
                Err(e) if e.to_string().contains("diverged") => (0.0, true),
                Err(e) => return Err(e),
            };
            println!("{scheme:<12} {q:>6} {r:>6} {l:>4} {ratio:>11.1} {metric:>10.4}{}",
                     if diverged { "  DIVERGED" } else { "" });
            csv.row(&[
                scheme.into(), q.to_string(), r.to_string(), l.to_string(),
                format!("{ratio:.2}"), format!("{metric:.5}"),
                (diverged as u8).to_string(),
            ])?;
        }
    }
    csv.flush()?;
    println!("wrote results/fig5c.csv");
    Ok(())
}
