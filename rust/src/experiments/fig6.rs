//! Figure 6: training curves vs cumulative up-link communication.
//!
//! FedAvg (H local steps), SplitFed, and FedLite on FEMNIST, same seed and
//! round budget; per-round CSVs carry `cumulative_uplink` so the curves
//! can be plotted against bytes. Expected shape: FedLite reaches any given
//! metric level with far fewer bytes than SplitFed, which beats FedAvg.

use std::sync::Arc;

use crate::config::{Algorithm, RunConfig};
use crate::experiments::run_config;
use crate::runtime::Runtime;
use crate::util::logging::CsvWriter;

pub struct Fig6Options {
    pub rounds: usize,
    pub seed: u64,
    pub local_steps: usize,
    pub out_dir: String,
}

impl Default for Fig6Options {
    fn default() -> Self {
        Fig6Options { rounds: 60, seed: 29, local_steps: 4, out_dir: "results/fig6".into() }
    }
}

pub fn run(opts: &Fig6Options, rt: Arc<Runtime>) -> anyhow::Result<()> {
    let mut summary = CsvWriter::create(
        "results/fig6_summary.csv",
        &["algorithm", "rounds", "final_metric", "total_uplink_bytes",
          "bytes_per_round", "sim_comm_seconds_total"],
    )?;
    println!("Figure 6 — FEMNIST, {} rounds, seed {}", opts.rounds, opts.seed);
    println!("{:<10} {:>10} {:>16} {:>14}", "algorithm", "metric", "uplink-total", "bytes/round");
    for algo in [Algorithm::FedAvg, Algorithm::SplitFed, Algorithm::FedLite] {
        let mut cfg = RunConfig::preset("femnist")?;
        cfg.algorithm = algo;
        cfg.rounds = opts.rounds;
        cfg.seed = opts.seed;
        cfg.local_steps = if algo == Algorithm::FedAvg { opts.local_steps } else { 1 };
        cfg.num_clients = 50;
        cfg.eval_every = (opts.rounds / 6).max(1);
        cfg.eval_batches = 6;
        cfg.out_dir = opts.out_dir.clone();
        let log = run_config(cfg, Arc::clone(&rt))?;
        let metric = log.final_eval_metric(2).unwrap_or(0.0);
        let total_up = log.total_uplink();
        let per_round = total_up as f64 / opts.rounds as f64;
        let sim_s: f64 = log.rounds.iter().map(|r| r.sim_comm_seconds).sum();
        println!("{:<10} {:>10.4} {:>16} {:>14.0}", algo.name(), metric, total_up, per_round);
        summary.row(&[
            algo.name().into(), opts.rounds.to_string(), format!("{metric:.5}"),
            total_up.to_string(), format!("{per_round:.0}"), format!("{sim_s:.2}"),
        ])?;
    }
    summary.flush()?;
    println!("wrote results/fig6_summary.csv and per-round CSVs under {}/", opts.out_dir);
    Ok(())
}
