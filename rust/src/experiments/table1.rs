//! Table 1: FedAvg vs SplitFed (vs FedLite) compute & communication.
//!
//! Two parts: the analytic rows (exactly the paper's formulas, evaluated
//! for all three task splittings) and — when a runtime is available — a
//! *measured* column: actual wire bytes from running one round of each
//! algorithm through the metered network, confirming the model.

use std::sync::Arc;

use crate::config::{Algorithm, RunConfig};
use crate::experiments::run_config;
use crate::models::analytics::{self, CostRow, TaskCosts};
use crate::runtime::Runtime;
use crate::util::logging::CsvWriter;

pub struct Table1Options {
    pub h: usize,
    pub out_csv: String,
    /// Run one measured round per algorithm on FEMNIST (needs artifacts).
    pub measure: bool,
}

impl Default for Table1Options {
    fn default() -> Self {
        Table1Options { h: 4, out_csv: "results/table1.csv".into(), measure: true }
    }
}

pub fn run(opts: &Table1Options, rt: Option<Arc<Runtime>>) -> anyhow::Result<()> {
    let tasks: [(&str, TaskCosts, Option<(usize, usize, usize)>); 3] = [
        ("femnist", analytics::femnist_costs(), Some((1152, 1, 2))),
        ("so_tag", analytics::so_tag_costs(), Some((500, 1, 10))),
        ("so_nwp", analytics::so_nwp_costs(), Some((12, 1, 60))),
    ];
    let mut csv = CsvWriter::create(
        &opts.out_csv,
        &["task", "algorithm", "batch", "total_compute", "client_compute",
          "communication_scalars", "communication_ratio_vs_fedavg"],
    )?;
    println!("Table 1 — per-client per-iteration costs (scalar units, phi=64)");
    for (task, costs, fedlite) in &tasks {
        let rows = analytics::table1(costs, opts.h, *fedlite);
        let fedavg_comm = rows[0].communication;
        println!("\n[{task}]  |w_c|={} |w_s|={} d={} B={}", costs.wc, costs.ws, costs.d, costs.b);
        println!("{:<24} {:>10} {:>14} {:>14} {:>16} {:>8}",
                 "algorithm", "batch", "total-compute", "client-compute", "comm(scalars)", "vs-FA");
        for CostRow { algorithm, batch, total_compute, client_compute, communication } in &rows {
            let rel = communication / fedavg_comm;
            println!("{algorithm:<24} {batch:>10} {total_compute:>14.3e} {client_compute:>14.3e} {communication:>16.1} {rel:>8.4}");
            csv.row(&[
                task.to_string(), algorithm.clone(), batch.clone(),
                format!("{total_compute:.3e}"), format!("{client_compute:.3e}"),
                format!("{communication:.1}"), format!("{rel:.5}"),
            ])?;
        }
    }
    csv.flush()?;

    if opts.measure {
        if let Some(rt) = rt {
            measured_round(rt)?;
        } else {
            println!("\n(measured round skipped: no runtime)");
        }
    }
    Ok(())
}

/// One measured round per algorithm on FEMNIST: real wire bytes.
fn measured_round(rt: Arc<Runtime>) -> anyhow::Result<()> {
    println!("\nMeasured wire bytes, one FEMNIST round, 10 clients (f32 wire):");
    println!("{:<10} {:>14} {:>14}", "algorithm", "uplink", "downlink");
    for algo in [Algorithm::FedAvg, Algorithm::SplitFed, Algorithm::FedLite] {
        let mut cfg = RunConfig::preset("femnist")?;
        cfg.algorithm = algo;
        cfg.rounds = 1;
        cfg.eval_every = 0;
        cfg.num_clients = 20;
        cfg.clients_per_round = 10;
        cfg.pq.iters = 3;
        let log = run_config(cfg, Arc::clone(&rt))?;
        let r = log.rounds.last().unwrap();
        println!(
            "{:<10} {:>14} {:>14}",
            algo.name(),
            r.uplink_bytes,
            r.downlink_bytes
        );
    }
    Ok(())
}
