//! Figure 3: quantization error vs compression ratio.
//!
//! Paper setup: activations of a two-layer CNN on FEMNIST (d=9216, B=20);
//! three quantizer families over a range of L:
//!
//! * blue  — vanilla K-means (q = R = 1);
//! * green — vanilla PQ, q ∈ {288, 1152, 4608}, R = q;
//! * red   — ours, q = 4608 fixed, R ∈ {2304, 1152, 384, 1}.
//!
//! Expected shape: green below blue at equal ratio (more quantization
//! levels), red dominating both (shared codebooks slash the codebook
//! term). Activations come from `client_fwd` after a short SplitFed
//! warm-up so they carry class structure like the paper's trained net.

use std::sync::Arc;

use crate::coordinator::client::{assemble, draw_masks, InputSources};
use crate::data::femnist::SyntheticFemnist;
use crate::data::FederatedDataset;
use crate::models::ModelSpec;
use crate::quantizer::cost::CostModel;
use crate::quantizer::pq::{GroupedPq, PqConfig};
use crate::runtime::Runtime;
use crate::tensor::TensorList;
use crate::util::logging::CsvWriter;
use crate::util::rng::Rng;

pub struct Fig3Options {
    pub out_csv: String,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for Fig3Options {
    fn default() -> Self {
        Fig3Options { out_csv: "results/fig3.csv".into(), kmeans_iters: 8, seed: 33 }
    }
}

/// Grab one batch of cut-layer activations from the FEMNIST client model.
pub fn femnist_activations(rt: &Runtime, seed: u64) -> anyhow::Result<(Vec<f32>, usize, usize)> {
    let variant = "femnist_paper";
    let spec: &ModelSpec = &rt.manifest.variant(variant)?.spec;
    let rng = Rng::new(seed);
    let wc: TensorList = spec.client.init_tensors(&mut rng.fork(1));
    let data = SyntheticFemnist::new(seed, 10, 0.3);
    let batch = data.train_batch(0, spec.batch, &mut rng.fork(2));
    let meta = rt.manifest.artifact(variant, "client_fwd")?.clone();
    let masks = draw_masks(&[&meta], 0.0, 0.0, &mut rng.fork(3));
    let src = InputSources {
        wc: Some(&wc),
        batch: Some(&batch),
        masks: Some(&masks),
        ..Default::default()
    };
    let z = rt
        .run(variant, "client_fwd", &assemble(&meta, &src)?)?
        .remove(0);
    let v = z.as_f32().unwrap().to_vec();
    Ok((v, spec.batch, spec.cut_dim))
}

/// The sweep configurations of the figure: (family, q, r, Ls).
pub fn sweep_configs(d: usize) -> Vec<(&'static str, usize, usize, Vec<usize>)> {
    let ls = vec![2usize, 4, 8, 16, 32];
    let mut out = vec![("kmeans", 1usize, 1usize, vec![2, 4, 8, 16])];
    for q in [288usize, 1152, 4608] {
        if d % q == 0 {
            out.push(("vanilla_pq", q, q, ls.clone()));
        }
    }
    for r in [2304usize, 1152, 384, 1] {
        if d % 4608 == 0 && 4608 % r == 0 {
            out.push(("grouped_pq", 4608, r, ls.clone()));
        }
    }
    out
}

pub fn run(opts: &Fig3Options, rt: Arc<Runtime>) -> anyhow::Result<()> {
    let (z, b, d) = femnist_activations(&rt, opts.seed)?;
    let mut csv = CsvWriter::create(
        &opts.out_csv,
        &["family", "q", "r", "l", "compression_ratio", "relative_error"],
    )?;
    let cm = CostModel::default();
    println!("Figure 3 — FEMNIST activations d={d}, B={b}");
    println!("{:<12} {:>6} {:>6} {:>4} {:>12} {:>12}", "family", "q", "R", "L", "ratio", "rel-error");
    for (family, q, r, ls) in sweep_configs(d) {
        for &l in &ls {
            let cfg = PqConfig::new(q, r, l).with_iters(opts.kmeans_iters);
            let pq = GroupedPq::new(cfg, d)?;
            let mut rng = Rng::new(opts.seed ^ (q as u64) ^ ((l as u64) << 32));
            let out = pq.quantize(&z, b, &mut rng);
            let ratio = cm.ratio(b, d, q, r, l);
            let err = out.relative_error(&z);
            println!("{family:<12} {q:>6} {r:>6} {l:>4} {ratio:>12.2} {err:>12.5}");
            csv.row(&[
                family.into(), q.to_string(), r.to_string(), l.to_string(),
                format!("{ratio:.3}"), format!("{err:.6}"),
            ])?;
        }
    }
    csv.flush()?;
    println!("wrote {}", opts.out_csv);
    Ok(())
}
