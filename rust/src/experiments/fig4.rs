//! Figure 4: final accuracy vs compression ratio, per task, λ=0 vs λ>0.
//!
//! For each (q, L) operating point (a subset of the paper's §C.2 ranges,
//! scaled by `--points`), train FedLite for `--rounds` rounds with λ=0 and
//! with the preset λ, plus a SplitFed reference (ratio 1). Expected
//! shapes: accuracy ≈ SplitFed at ≥10x compression; λ>0 curves dominate
//! λ=0, dramatically so at high ratios where λ=0 may diverge (recorded as
//! `diverged=1` with metric 0).
//!
//! With a native `--preset` (tiny/small/stress), the whole sweep runs
//! end-to-end on the built-in engine — real federated training on any of
//! the `<task>_<preset>` registry variants, no artifacts directory — and
//! the quantizer-budget axis comes from the variant's own cut-width
//! divisors (the paper's q values target the wider PJRT cuts).

use std::sync::Arc;

use crate::config::{Algorithm, RunConfig};
use crate::experiments::run_config;
use crate::quantizer::compression_ratio;
use crate::quantizer::pq::PqConfig;
use crate::runtime::Runtime;
use crate::util::logging::CsvWriter;

pub struct Fig4Options {
    pub task: String,
    /// `""` = the task's PJRT preset (needs artifacts); `tiny` / `small`
    /// / `stress` = the corresponding native registry variant.
    pub preset: String,
    pub rounds: usize,
    pub out_csv: String,
    /// How many (q, L) points per curve.
    pub points: usize,
    pub seed: u64,
}

impl Default for Fig4Options {
    fn default() -> Self {
        Fig4Options {
            task: "femnist".into(),
            preset: String::new(),
            rounds: 60,
            out_csv: String::new(),
            points: 3,
            seed: 17,
        }
    }
}

/// The paper's §C.2 sweep ranges per task (q values, L values, tuned λ).
pub fn paper_ranges(task: &str, cut_dim: usize) -> (Vec<usize>, Vec<usize>, f32) {
    match task {
        "femnist" => (vec![1152, 288, 144], vec![2, 8, 32], 1e-4),
        "so_tag" => {
            // paper vocab/hidden -> small preset may shrink d; keep divisors
            let qs: Vec<usize> = [200usize, 50, 25]
                .iter()
                .copied()
                .filter(|q| cut_dim % q == 0)
                .collect();
            (qs, vec![10, 20, 40], 5e-3)
        }
        _ => (vec![12, 6, 3], vec![30, 60, 120], 1e-3),
    }
}

pub fn run(opts: &Fig4Options, rt: Arc<Runtime>) -> anyhow::Result<()> {
    let native = !opts.preset.is_empty();
    let mut base = if native {
        RunConfig::native(&opts.task, &opts.preset)?
    } else {
        RunConfig::preset(&opts.task)?
    };
    base.rounds = opts.rounds;
    base.seed = opts.seed;
    base.num_clients = 50;
    base.eval_every = (opts.rounds / 4).max(1);
    base.eval_batches = 6;
    let spec = rt.manifest.variant(&base.variant())?.spec.clone();
    let d = spec.cut_dim;
    let act_b = spec.act_batch;
    let (qs, ls, lam) = if native {
        // the paper's q values target the PJRT cut widths; the native
        // cuts are narrower, so the budget axis sweeps the variant's own
        // divisors, whole-vector PQ down to coarse grouping
        let mut qs: Vec<usize> = [d, d / 4, (d / 16).max(1)]
            .into_iter()
            .filter(|&q| q >= 1 && d % q == 0)
            .collect();
        qs.dedup();
        (qs, vec![2, 4, 8], base.lambda)
    } else {
        paper_ranges(&opts.task, d)
    };

    let out_csv = if opts.out_csv.is_empty() {
        if native {
            format!("results/fig4_{}_{}.csv", opts.task, opts.preset)
        } else {
            format!("results/fig4_{}.csv", opts.task)
        }
    } else {
        opts.out_csv.clone()
    };
    let mut csv = CsvWriter::create(
        &out_csv,
        &["task", "algorithm", "q", "l", "lambda", "compression_ratio",
          "final_metric", "final_loss", "diverged"],
    )?;

    // SplitFed reference (compression ratio 1)
    let mut sf = base.clone();
    sf.algorithm = Algorithm::SplitFed;
    let log = run_config(sf, Arc::clone(&rt))?;
    let sf_metric = log.final_eval_metric(2).unwrap_or(0.0);
    println!("Figure 4 [{}] — SplitFed reference metric: {sf_metric:.4}", opts.task);
    csv.row(&[
        opts.task.clone(), "splitfed".into(), "0".into(), "0".into(), "0".into(),
        "1".into(), format!("{sf_metric:.5}"), format!("{:.5}", log.final_train_loss(3)),
        "0".into(),
    ])?;

    println!("{:>6} {:>5} {:>9} {:>10} {:>10} {:>9}", "q", "L", "lambda", "ratio", "metric", "loss");
    for &q in qs.iter().take(opts.points) {
        for &l in ls.iter().take(opts.points) {
            if d % q != 0 {
                continue;
            }
            for lambda in [0.0f32, lam] {
                let mut cfg = base.clone();
                cfg.algorithm = Algorithm::FedLite;
                cfg.pq = PqConfig::new(q, 1, l);
                cfg.lambda = lambda;
                let ratio = compression_ratio(act_b, d, q, 1, l);
                let (metric, loss, diverged) = match run_config(cfg, Arc::clone(&rt)) {
                    Ok(log) => (
                        log.final_eval_metric(2).unwrap_or(0.0),
                        log.final_train_loss(3),
                        false,
                    ),
                    Err(e) if e.to_string().contains("diverged") => (0.0, f64::NAN, true),
                    Err(e) => return Err(e),
                };
                println!("{q:>6} {l:>5} {lambda:>9.0e} {ratio:>10.1} {metric:>10.4} {loss:>9.4}{}",
                         if diverged { "  DIVERGED" } else { "" });
                csv.row(&[
                    opts.task.clone(), "fedlite".into(), q.to_string(), l.to_string(),
                    format!("{lambda:e}"), format!("{ratio:.2}"),
                    format!("{metric:.5}"), format!("{loss:.5}"),
                    (diverged as u8).to_string(),
                ])?;
            }
        }
    }
    csv.flush()?;
    println!("wrote {out_csv}");
    Ok(())
}
