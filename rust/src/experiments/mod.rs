//! Experiment drivers: one per table/figure of the paper's evaluation.
//!
//! Every driver writes a CSV under `results/` with exactly the series the
//! paper plots, and prints a readable summary. Absolute numbers differ
//! from the paper (synthetic data, reduced rounds — see EXPERIMENTS.md);
//! the *shapes* (who wins, crossovers, correction effects) are the
//! reproduction target.
//!
//! | driver | paper asset |
//! |---|---|
//! | [`table1`] | Table 1 (cost model, analytic + measured wire bytes) |
//! | [`fig3`]   | Fig. 3 (quant error vs compression; K-means / PQ / ours) |
//! | [`fig4`]   | Fig. 4 (accuracy vs compression, λ=0 vs λ>0) |
//! | [`fig5`]   | Fig. 5ab (λ ablation grid), Fig. 5c (grouping ablation) |
//! | [`fig6`]   | Fig. 6 (metric vs cumulative uplink, 3 algorithms) |

pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::{build_trainer, Trainer};
use crate::metrics::RunLog;
use crate::runtime::Runtime;

/// Run one training config to completion (shared by figure drivers).
pub fn run_config(cfg: RunConfig, rt: Arc<Runtime>) -> anyhow::Result<RunLog> {
    let mut t: Box<dyn Trainer> = build_trainer(cfg, rt)?;
    t.run()
}
