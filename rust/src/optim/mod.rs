//! Optimizers for the server-side model and the aggregated client model.
//!
//! The paper's per-task choices (§C.2): FEMNIST — SGD (lr 10^-1.5),
//! SO NWP — Adam (lr 0.01), SO Tag — AdaGrad (lr 10^-0.5). Optimizer state
//! lives on the coordinator (server) in rust; the AOT artifacts only
//! produce gradients.

use crate::tensor::TensorList;

/// Common interface: apply one update given gradients.
///
/// `Send + Sync` because trainers holding optimizers are shared by
/// reference with the cohort worker threads (the round engine's fan-out);
/// the workers never touch optimizer state — `step` needs `&mut` — but
/// the auto-trait bound must hold for the share to compile.
pub trait Optimizer: Send + Sync {
    fn step(&mut self, params: &mut TensorList, grads: &TensorList);
    fn learning_rate(&self) -> f32;
    fn set_learning_rate(&mut self, lr: f32);
    fn name(&self) -> &'static str;
}

/// Build the optimizer named in a config (`sgd` | `adam` | `adagrad`).
pub fn build(name: &str, lr: f32) -> anyhow::Result<Box<dyn Optimizer>> {
    Ok(match name {
        "sgd" => Box::new(Sgd::new(lr, 0.0)),
        "sgdm" => Box::new(Sgd::new(lr, 0.9)),
        "adam" => Box::new(Adam::new(lr)),
        "adagrad" => Box::new(AdaGrad::new(lr)),
        other => anyhow::bail!("unknown optimizer '{other}'"),
    })
}

/// SGD with optional heavy-ball momentum.
pub struct Sgd {
    lr: f32,
    momentum: f32,
    velocity: Option<TensorList>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, velocity: None }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut TensorList, grads: &TensorList) {
        if self.momentum == 0.0 {
            params.axpy(-self.lr, grads);
            return;
        }
        let v = self
            .velocity
            .get_or_insert_with(|| grads.zeros_like());
        v.scale(self.momentum);
        v.axpy(1.0, grads);
        params.axpy(-self.lr, v);
    }

    fn learning_rate(&self) -> f32 {
        self.lr
    }

    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Adam (Kingma & Ba, 2014) with bias correction.
pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u64,
    m: Option<TensorList>,
    v: Option<TensorList>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut TensorList, grads: &TensorList) {
        self.t += 1;
        let m = self.m.get_or_insert_with(|| grads.zeros_like());
        let v = self.v.get_or_insert_with(|| grads.zeros_like());
        let (b1, b2) = (self.beta1, self.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr_t = self.lr * bc2.sqrt() / bc1;
        for ((p, g), (mt, vt)) in params
            .tensors
            .iter_mut()
            .zip(&grads.tensors)
            .zip(m.tensors.iter_mut().zip(v.tensors.iter_mut()))
        {
            let gd = g.data();
            let md = mt.data_mut();
            let vd = vt.data_mut();
            let pd = p.data_mut();
            for i in 0..gd.len() {
                md[i] = b1 * md[i] + (1.0 - b1) * gd[i];
                vd[i] = b2 * vd[i] + (1.0 - b2) * gd[i] * gd[i];
                pd[i] -= lr_t * md[i] / (vd[i].sqrt() + self.eps);
            }
        }
    }

    fn learning_rate(&self) -> f32 {
        self.lr
    }

    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// AdaGrad (Duchi et al., 2011).
pub struct AdaGrad {
    lr: f32,
    eps: f32,
    accum: Option<TensorList>,
}

impl AdaGrad {
    pub fn new(lr: f32) -> Self {
        AdaGrad { lr, eps: 1e-7, accum: None }
    }
}

impl Optimizer for AdaGrad {
    fn step(&mut self, params: &mut TensorList, grads: &TensorList) {
        let acc = self.accum.get_or_insert_with(|| grads.zeros_like());
        for ((p, g), a) in params
            .tensors
            .iter_mut()
            .zip(&grads.tensors)
            .zip(acc.tensors.iter_mut())
        {
            let gd = g.data();
            let ad = a.data_mut();
            let pd = p.data_mut();
            for i in 0..gd.len() {
                ad[i] += gd[i] * gd[i];
                pd[i] -= self.lr * gd[i] / (ad[i].sqrt() + self.eps);
            }
        }
    }

    fn learning_rate(&self) -> f32 {
        self.lr
    }

    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn name(&self) -> &'static str {
        "adagrad"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn quad_problem() -> (TensorList, TensorList) {
        // f(x) = 0.5 ||x - target||^2; grad = x - target
        let params = TensorList::new(
            vec!["x".into()],
            vec![Tensor::from_vec(&[3], vec![5.0, -3.0, 2.0])],
        );
        let target = TensorList::new(
            vec!["x".into()],
            vec![Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0])],
        );
        (params, target)
    }

    fn grad_of(params: &TensorList, target: &TensorList) -> TensorList {
        let mut g = params.clone();
        g.axpy(-1.0, target);
        g
    }

    fn converges(opt: &mut dyn Optimizer, steps: usize) -> f32 {
        let (mut params, target) = quad_problem();
        for _ in 0..steps {
            let g = grad_of(&params, &target);
            opt.step(&mut params, &g);
        }
        let g = grad_of(&params, &target);
        g.l2_norm()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(converges(&mut Sgd::new(0.1, 0.0), 200) < 1e-4);
    }

    #[test]
    fn sgd_momentum_converges() {
        assert!(converges(&mut Sgd::new(0.05, 0.9), 300) < 1e-3);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(converges(&mut Adam::new(0.1), 500) < 1e-2);
    }

    #[test]
    fn adagrad_converges_on_quadratic() {
        assert!(converges(&mut AdaGrad::new(1.0), 500) < 1e-2);
    }

    #[test]
    fn sgd_step_is_exact() {
        let (mut params, target) = quad_problem();
        let g = grad_of(&params, &target);
        Sgd::new(0.5, 0.0).step(&mut params, &g);
        // x <- x - 0.5 (x - t): 5 -> 3, -3 -> -1, 2 -> 1.5
        assert_eq!(params.tensors[0].data(), &[3.0, -1.0, 1.5]);
    }

    #[test]
    fn adam_first_step_size_is_lr() {
        // with bias correction the first |update| == lr regardless of grad scale
        let mut p = TensorList::new(
            vec!["x".into()],
            vec![Tensor::from_vec(&[2], vec![0.0, 0.0])],
        );
        let g = TensorList::new(
            vec!["x".into()],
            vec![Tensor::from_vec(&[2], vec![1000.0, -0.001])],
        );
        Adam::new(0.01).step(&mut p, &g);
        for (x, gsign) in p.tensors[0].data().iter().zip([1.0f32, -1.0]) {
            assert!((x + gsign * 0.01).abs() < 1e-4, "x={x}");
        }
    }

    #[test]
    fn builder_names() {
        for name in ["sgd", "sgdm", "adam", "adagrad"] {
            assert!(build(name, 0.1).is_ok());
        }
        assert!(build("lion", 0.1).is_err());
    }
}
