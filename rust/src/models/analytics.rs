//! Table 1 analytics: FedAvg vs SplitFed compute/memory/communication.
//!
//! The paper's Table 1 compares, per selected client and per iteration:
//!
//! | Algorithm | Batch | Total compute | Client compute | Communication |
//! |---|---|---|---|---|
//! | FedAvg    | B/H | O(B·|w|)   | O(B·|w|)     | |w| |
//! | SplitFed  | B/H | O(B·|w|/H) | O(B·|w_c|/H) | B·d/H + |w_c| |
//! | SplitFed  | B   | O(B·|w|)   | O(B·|w_c|)   | B·d + |w_c| |
//!
//! plus FedLite's row (ours): compute like SplitFed, communication
//! `compressed(B, d, q, R, L) + |w_c|`. Units: compute in parameter-
//! touches (the O(·) argument), communication in scalars (× phi bits).

use crate::quantizer::cost::CostModel;

/// Inputs to the cost model for one task.
#[derive(Clone, Copy, Debug)]
pub struct TaskCosts {
    /// Client-side parameter count |w_c|.
    pub wc: usize,
    /// Server-side parameter count |w_s|.
    pub ws: usize,
    /// Cut-layer activation dimension d.
    pub d: usize,
    /// Per-client mini-batch size B (activation rows: B·T for sequences).
    pub b: usize,
}

impl TaskCosts {
    pub fn total(&self) -> usize {
        self.wc + self.ws
    }
}

/// One Table-1 row, in scalar units (multiply by phi for bits).
#[derive(Clone, Debug, PartialEq)]
pub struct CostRow {
    pub algorithm: String,
    pub batch: String,
    pub total_compute: f64,
    pub client_compute: f64,
    /// Up-link scalars per client per iteration.
    pub communication: f64,
}

/// Compute all Table-1 rows (+ the FedLite row) for a task.
///
/// `h` is FedAvg's number of local steps; the SplitFed rows are reported
/// both at batch `B/H` (equal-computation comparison) and at batch `B`.
pub fn table1(costs: &TaskCosts, h: usize, fedlite: Option<(usize, usize, usize)>) -> Vec<CostRow> {
    let w = costs.total() as f64;
    let wc = costs.wc as f64;
    let b = costs.b as f64;
    let d = costs.d as f64;
    let hf = h as f64;
    let mut rows = vec![
        CostRow {
            algorithm: "fedavg".into(),
            batch: format!("B/H={}", costs.b / h.max(1)),
            total_compute: b * w,
            client_compute: b * w,
            communication: w,
        },
        CostRow {
            algorithm: "splitfed".into(),
            batch: format!("B/H={}", costs.b / h.max(1)),
            total_compute: b * w / hf,
            client_compute: b * wc / hf,
            communication: b * d / hf + wc,
        },
        CostRow {
            algorithm: "splitfed".into(),
            batch: format!("B={}", costs.b),
            total_compute: b * w,
            client_compute: b * wc,
            communication: b * d + wc,
        },
    ];
    if let Some((q, r, l)) = fedlite {
        let m = CostModel::default();
        let compressed_scalars = m.fedlite_bits(costs.b, costs.d, q, r, l) / m.phi as f64;
        rows.push(CostRow {
            algorithm: format!("fedlite(q={q},R={r},L={l})"),
            batch: format!("B={}", costs.b),
            total_compute: b * w,
            client_compute: b * wc,
            communication: compressed_scalars + wc,
        });
    }
    rows
}

/// The paper's FEMNIST splitting (§C.2).
pub fn femnist_costs() -> TaskCosts {
    TaskCosts { wc: 18_816, ws: 1_187_774, d: 9216, b: 20 }
}

/// The paper's SO Tag splitting (§C.2).
pub fn so_tag_costs() -> TaskCosts {
    TaskCosts { wc: 5000 * 2000 + 2000, ws: 2000 * 1000 + 1000, d: 2000, b: 100 }
}

/// The paper's SO NWP splitting (§C.2); activation rows are B·T.
pub fn so_nwp_costs() -> TaskCosts {
    TaskCosts { wc: 3_080_360, ws: 970_388, d: 96, b: 128 * 30 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitfed_always_cheaper_on_client() {
        for costs in [femnist_costs(), so_tag_costs(), so_nwp_costs()] {
            let rows = table1(&costs, 4, None);
            let fedavg = &rows[0];
            for sf in &rows[1..] {
                assert!(sf.client_compute < fedavg.client_compute);
            }
        }
    }

    #[test]
    fn femnist_splitfed_uplink_dominated_by_activations() {
        // paper: the activation message can be ~10x the client model
        let c = femnist_costs();
        let rows = table1(&c, 1, None);
        let sf = &rows[2];
        let act = (c.b * c.d) as f64;
        assert!(act / c.wc as f64 > 9.0);
        assert!((sf.communication - (act + c.wc as f64)).abs() < 1e-9);
    }

    #[test]
    fn fedlite_row_beats_both() {
        let c = femnist_costs();
        let rows = table1(&c, 1, Some((1152, 1, 2)));
        let fedavg_comm = rows[0].communication;
        let splitfed_comm = rows[2].communication;
        let fedlite_comm = rows[3].communication;
        assert!(fedlite_comm < splitfed_comm);
        assert!(fedlite_comm < fedavg_comm);
        // paper §5: FedLite uplink ~62x below FedAvg on FEMNIST
        let gain = fedavg_comm / fedlite_comm;
        assert!((45.0..80.0).contains(&gain), "gain {gain:.1}");
    }

    #[test]
    fn equal_compute_row_scales_with_h() {
        let c = femnist_costs();
        let r4 = table1(&c, 4, None);
        let r2 = table1(&c, 2, None);
        assert!(r4[1].total_compute < r2[1].total_compute);
        assert!(r4[1].communication < r2[1].communication + c.wc as f64);
    }

    #[test]
    fn paper_client_fractions() {
        let f = femnist_costs();
        let frac = f.wc as f64 / f.total() as f64;
        assert!((0.015..0.017).contains(&frac)); // ~1.6%
        let t = so_tag_costs();
        let frac_t = t.wc as f64 / t.total() as f64;
        assert!((0.82..0.84).contains(&frac_t)); // ~83%
        let n = so_nwp_costs();
        let frac_n = n.wc as f64 / n.total() as f64;
        assert!((0.74..0.80).contains(&frac_n)); // paper says 79%
    }
}
