//! Split-model metadata: parameter specs, initialization, and the Table-1
//! compute/communication cost analytics.
//!
//! The source of truth for shapes is `artifacts/manifest.json` (emitted by
//! `python/compile/aot.py`); [`ModelSpec`] is its typed view plus the
//! parameter initializers the coordinator applies (mirroring
//! `python/compile/models/common.py::init_param`).

pub mod analytics;

use crate::tensor::{Tensor, TensorList};
use crate::util::json::Value;
use crate::util::rng::Rng;

/// One trainable parameter as described by the manifest.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub scale: f64,
    pub fan_in: usize,
    pub fan_out: usize,
}

impl ParamSpec {
    pub fn from_json(v: &Value) -> anyhow::Result<ParamSpec> {
        Ok(ParamSpec {
            name: v.get("name").as_str().unwrap_or_default().to_string(),
            shape: v
                .get("shape")
                .as_usize_vec()
                .ok_or_else(|| anyhow::anyhow!("param spec missing shape"))?,
            init: v.get("init").as_str().unwrap_or("zeros").to_string(),
            scale: v.get("scale").as_f64().unwrap_or(1.0),
            fan_in: v.get("fan_in").as_usize().unwrap_or(1),
            fan_out: v.get("fan_out").as_usize().unwrap_or(1),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Initialize this parameter (matches the python reference initializer).
    pub fn init_tensor(&self, rng: &mut Rng) -> Tensor {
        let n = self.numel();
        let data = match self.init.as_str() {
            "zeros" => vec![0.0; n],
            "glorot_uniform" => {
                let limit = (6.0 / (self.fan_in + self.fan_out) as f64).sqrt() as f32;
                rng.uniform_vec(n, -limit, limit)
            }
            "uniform" => {
                let s = self.scale as f32;
                rng.uniform_vec(n, -s, s)
            }
            other => panic!("unknown init '{other}'"),
        };
        Tensor::from_vec(&self.shape, data)
    }
}

/// One side (client or server) of a split model.
#[derive(Clone, Debug)]
pub struct SideSpec {
    pub params: Vec<ParamSpec>,
}

impl SideSpec {
    pub fn from_json(arr: &Value) -> anyhow::Result<SideSpec> {
        let params = arr
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("param list not an array"))?
            .iter()
            .map(ParamSpec::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(SideSpec { params })
    }

    pub fn numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Allocate + initialize all parameters of this side.
    pub fn init_tensors(&self, rng: &mut Rng) -> TensorList {
        let names = self.params.iter().map(|p| p.name.clone()).collect();
        let tensors = self
            .params
            .iter()
            .enumerate()
            .map(|(i, p)| p.init_tensor(&mut rng.fork(i as u64 + 1)))
            .collect();
        TensorList::new(names, tensors)
    }
}

/// Full split-model description for one task variant.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub task: String,
    pub preset: String,
    pub cut_dim: usize,
    /// Rows the quantizer sees per batch (B, or B*T for sequence tasks).
    pub act_batch: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub client: SideSpec,
    pub server: SideSpec,
    pub metrics: Vec<String>,
    pub client_args: Vec<String>,
    pub server_args: Vec<String>,
    pub config: Value,
}

impl ModelSpec {
    pub fn from_manifest_variant(v: &Value) -> anyhow::Result<ModelSpec> {
        let cfg = v.get("config");
        Ok(ModelSpec {
            task: v.get("task").as_str().unwrap_or_default().to_string(),
            preset: v.get("preset").as_str().unwrap_or_default().to_string(),
            cut_dim: v
                .get("cut_dim")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing cut_dim"))?,
            act_batch: v
                .get("act_batch")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing act_batch"))?,
            batch: cfg.get("batch").as_usize().unwrap_or(1),
            eval_batch: cfg.get("eval_batch").as_usize().unwrap_or(1),
            client: SideSpec::from_json(v.get("client_params"))?,
            server: SideSpec::from_json(v.get("server_params"))?,
            metrics: str_vec(v.get("metrics")),
            client_args: str_vec(v.get("client_args")),
            server_args: str_vec(v.get("server_args")),
            config: cfg.clone(),
        })
    }

    pub fn total_params(&self) -> usize {
        self.client.numel() + self.server.numel()
    }

    /// Fraction of parameters held by clients (paper: 1.6% on FEMNIST).
    pub fn client_fraction(&self) -> f64 {
        self.client.numel() as f64 / self.total_params() as f64
    }
}

fn str_vec(v: &Value) -> Vec<String> {
    v.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn spec_json() -> Value {
        json::parse(
            r#"{
            "name": "dense_w", "shape": [4, 8], "init": "glorot_uniform",
            "scale": 1.0, "fan_in": 4, "fan_out": 8
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn param_spec_roundtrip() {
        let p = ParamSpec::from_json(&spec_json()).unwrap();
        assert_eq!(p.numel(), 32);
        let mut rng = Rng::new(0);
        let t = p.init_tensor(&mut rng);
        assert_eq!(t.shape(), &[4, 8]);
        let limit = (6.0f64 / 12.0).sqrt() as f32;
        assert!(t.data().iter().all(|&x| x.abs() <= limit));
        assert!(t.data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn zeros_init() {
        let v = json::parse(r#"{"name":"b","shape":[5],"init":"zeros","scale":1,"fan_in":5,"fan_out":5}"#).unwrap();
        let p = ParamSpec::from_json(&v).unwrap();
        let t = p.init_tensor(&mut Rng::new(1));
        assert_eq!(t.data(), &[0.0; 5]);
    }

    #[test]
    fn uniform_scale_respected() {
        let v = json::parse(r#"{"name":"e","shape":[100],"init":"uniform","scale":0.05,"fan_in":1,"fan_out":1}"#).unwrap();
        let p = ParamSpec::from_json(&v).unwrap();
        let t = p.init_tensor(&mut Rng::new(2));
        assert!(t.data().iter().all(|&x| x.abs() <= 0.05));
        assert!(t.max_abs() > 0.01);
    }

    #[test]
    fn side_spec_init_deterministic() {
        let arr = json::parse(
            r#"[{"name":"w","shape":[3,3],"init":"glorot_uniform","scale":1,"fan_in":3,"fan_out":3},
                {"name":"b","shape":[3],"init":"zeros","scale":1,"fan_in":3,"fan_out":3}]"#,
        )
        .unwrap();
        let side = SideSpec::from_json(&arr).unwrap();
        assert_eq!(side.numel(), 12);
        let t1 = side.init_tensors(&mut Rng::new(7));
        let t2 = side.init_tensors(&mut Rng::new(7));
        assert_eq!(t1.tensors[0].data(), t2.tensors[0].data());
        assert_eq!(t1.names, vec!["w", "b"]);
    }
}
