//! Deterministic tiled GEMM kernels for the native engine's dense math.
//!
//! Three shapes cover every matmul in a split-MLP round:
//!
//! * [`dense_into`] — `out[m,n] = x[m,k] @ w[k,n] + bias[n]` (forward);
//! * [`matmul_at_b_into`] — `out[k,n] = a[m,k]ᵀ @ g[m,n]` (weight grads);
//! * [`matmul_a_bt_into`] — `out[m,k] = g[m,n] @ w[k,n]ᵀ` (input grads).
//!
//! # Exactness contract (what tiling may and may not reorder)
//!
//! Every kernel here is **bit-identical** to its naive triple-loop
//! reference ([`naive`]) by construction: for each output element the
//! reduction over the contraction dimension runs **strictly in ascending
//! order into a single accumulator** — the exact FP-operation sequence
//! the naive loop performs. Tiling only changes *which output element is
//! worked on when* (row blocks so a streamed operand is loaded once per
//! block instead of once per row, contraction-dim blocking so the hot
//! output block stays cache-resident) — reorderings across *independent*
//! output elements, which cannot change any rounding. The unrolled inner
//! primitives follow the same rule the quantizer's `dot8` established:
//! [`axpy8`] updates independent elements (order irrelevant), and
//! [`dot_serial`] is the rolled single-accumulator loop unrolled *without
//! reassociation* — one accumulator, same op sequence, fewer branches.
//! What is **never** done: splitting a reduction across lanes, partial
//! accumulators per k-block, or FMA contraction — all of which round
//! differently and would break the golden fixtures.
//!
//! # Parallel fan-out
//!
//! [`GemmPolicy::parallel`] fans the *output rows* across scoped worker
//! threads ([`scoped_row_chunks`]): rows are disjoint output regions and
//! each element's reduction is untouched, so results are bit-identical at
//! any worker count (enforced by `prop_gemm_modes_bitwise_identical` in
//! `rust/tests/properties.rs` and the CI golden job). Small problems stay
//! serial ([`PAR_MIN_WORK`]) — thread spawn would dominate.
//!
//! All kernels write caller-provided buffers and allocate nothing (the
//! parallel path spawns scoped threads, which is why the round engine's
//! per-client fan-out uses the serial policy — the cohort is already
//! parallel; see `rust/tests/alloc.rs` for the zero-allocation audit).

use std::thread;

/// Kernel implementation selector.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GemmMode {
    /// Verbatim reference triple loops (bench baseline, property oracle).
    Naive,
    /// Cache-blocked kernels (bit-identical to naive; the default).
    #[default]
    Tiled,
}

/// How the engine's dense math runs: kernel flavor + row fan-out width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmPolicy {
    pub mode: GemmMode,
    /// Scoped worker threads for the row fan-out (`<= 1` = serial).
    /// Ignored in `Naive` mode — the reference is strictly serial.
    pub workers: usize,
}

impl GemmPolicy {
    /// The reference kernels, serial (bench baseline / test oracle).
    pub fn naive() -> GemmPolicy {
        GemmPolicy { mode: GemmMode::Naive, workers: 1 }
    }

    /// Tiled kernels, serial — what the round engine's cohort workers
    /// use (the cohort fan-out already owns the cores).
    pub fn tiled() -> GemmPolicy {
        GemmPolicy { mode: GemmMode::Tiled, workers: 1 }
    }

    /// Tiled kernels + row-parallel fan-out over disjoint output rows.
    pub fn parallel(workers: usize) -> GemmPolicy {
        GemmPolicy { mode: GemmMode::Tiled, workers: workers.max(1) }
    }

    /// Display label for benches/logs.
    pub fn label(&self) -> &'static str {
        match (self.mode, self.workers > 1) {
            (GemmMode::Naive, _) => "naive",
            (GemmMode::Tiled, false) => "tiled",
            (GemmMode::Tiled, true) => "tiled+parallel",
        }
    }
}

impl Default for GemmPolicy {
    fn default() -> Self {
        GemmPolicy::tiled()
    }
}

/// Output rows processed together in the row-blocked kernels: the shared
/// operand row (`w`/`g`) is loaded once per block instead of once per
/// output row. Any value is bit-safe (rows are independent).
const MR: usize = 4;

/// Contraction-dim block for `matmul_at_b`: this many *output* rows stay
/// cache-resident while the whole batch streams past, instead of the full
/// `[k, n]` output being re-streamed per sample.
const KB: usize = 8;

/// Minimum `m·k·n` MAC count before the parallel policy actually spawns
/// threads; below this the spawn cost dominates the kernel.
const PAR_MIN_WORK: usize = 1 << 16;

// -- public kernels ----------------------------------------------------------

/// `out[m,n] = x[m,k] @ w[k,n] + bias[n]`.
pub fn dense_into(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    policy: GemmPolicy,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    assert_eq!(out.len(), m * n);
    match policy.mode {
        GemmMode::Naive => naive::dense(x, w, bias, m, k, n, out),
        GemmMode::Tiled => {
            row_fanout(out, m, n, policy.workers, m * k * n, |row0, rows, o| {
                dense_rows(&x[row0 * k..(row0 + rows) * k], w, bias, rows, k, n, o)
            });
        }
    }
}

/// `out[k,n] = a[m,k]ᵀ @ g[m,n]` (weight gradients; reduction over the
/// batch dimension `m`, in ascending sample order per output element).
pub fn matmul_at_b_into(
    a: &[f32],
    g: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    policy: GemmPolicy,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    assert_eq!(out.len(), k * n);
    match policy.mode {
        GemmMode::Naive => naive::matmul_at_b(a, g, m, k, n, out),
        GemmMode::Tiled => {
            // output rows are indexed by the contraction-free dim k
            row_fanout(out, k, n, policy.workers, m * k * n, |row0, rows, o| {
                at_b_rows(a, g, m, k, n, row0, rows, o)
            });
        }
    }
}

/// `out[m,k] = g[m,n] @ w[k,n]ᵀ` (input gradients; each output element is
/// a single-accumulator dot over `n` in ascending order).
pub fn matmul_a_bt_into(
    g: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
    policy: GemmPolicy,
) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * k);
    match policy.mode {
        GemmMode::Naive => naive::matmul_a_bt(g, w, m, n, k, out),
        GemmMode::Tiled => {
            row_fanout(out, m, k, policy.workers, m * k * n, |row0, rows, o| {
                a_bt_rows(&g[row0 * n..(row0 + rows) * n], w, rows, n, k, o)
            });
        }
    }
}

/// Column sums of `g[m,n]` (bias gradients), rows accumulated in
/// ascending order — too cheap to tile or fan out.
pub fn colsum_into(g: &[f32], m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(g.len(), m * n);
    assert_eq!(out.len(), n);
    out.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        for (ov, &gv) in out.iter_mut().zip(grow) {
            *ov += gv;
        }
    }
}

// -- tiled row kernels -------------------------------------------------------

/// Row-blocked `x @ w + bias` over `rows` rows of `x`/`out`: each `w` row
/// is loaded once per MR-block and axpy'd into the block's output rows.
/// Per output element: init from `bias[j]`, then `+= x[i,kk]·w[kk,j]` for
/// `kk` ascending — the naive loop's exact op sequence.
fn dense_rows(x: &[f32], w: &[f32], bias: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    let mut i = 0;
    while i < rows {
        let mr = MR.min(rows - i);
        for r in 0..mr {
            out[(i + r) * n..(i + r + 1) * n].copy_from_slice(bias);
        }
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            for r in 0..mr {
                let xv = x[(i + r) * k + kk];
                axpy8(&mut out[(i + r) * n..(i + r + 1) * n], xv, wrow);
            }
        }
        i += mr;
    }
}

/// `aᵀ @ g` restricted to output rows `[row0, row0+rows)`: KB-row output
/// blocks stay cache-resident while all `m` samples stream past once. Per
/// output element `(kk, j)`: `+= a[i,kk]·g[i,j]` for `i` ascending from a
/// zeroed slot — the naive loop's exact op sequence.
fn at_b_rows(
    a: &[f32],
    g: &[f32],
    m: usize,
    k: usize,
    n: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    out.iter_mut().for_each(|v| *v = 0.0);
    let mut kb = 0;
    while kb < rows {
        let kbw = KB.min(rows - kb);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let grow = &g[i * n..(i + 1) * n];
            for r in 0..kbw {
                let kk = row0 + kb + r;
                axpy8(&mut out[(kb + r) * n..(kb + r + 1) * n], arow[kk], grow);
            }
        }
        kb += kbw;
    }
}

/// `g @ wᵀ` over `rows` rows of `g`/`out`: each `w` row is loaded once
/// per MR-block and dotted against the block's `g` rows. Per output
/// element: one [`dot_serial`] — a single accumulator over `n` in
/// ascending order, exactly the naive inner loop.
fn a_bt_rows(g: &[f32], w: &[f32], rows: usize, n: usize, k: usize, out: &mut [f32]) {
    let mut i = 0;
    while i < rows {
        let mr = MR.min(rows - i);
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            for r in 0..mr {
                let grow = &g[(i + r) * n..(i + r + 1) * n];
                out[(i + r) * k + kk] = dot_serial(grow, wrow);
            }
        }
        i += mr;
    }
}

// -- fan-out -----------------------------------------------------------------

/// Run `f(first_row, n_rows, row_chunk)` over row-aligned contiguous
/// chunks of `out` (`rows` rows of `row_len` elements), fanned across up
/// to `workers` scoped threads. Chunks are disjoint output regions and
/// every per-element reduction lives entirely inside one chunk, so the
/// result is bit-identical at any worker count. Serial (one chunk) when
/// `workers <= 1`, the problem is too small, or there is only one row.
fn row_fanout<F>(out: &mut [f32], rows: usize, row_len: usize, workers: usize, work: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    let workers = workers.min(rows).max(1);
    if workers <= 1 || work < PAR_MIN_WORK {
        f(0, rows, out);
        return;
    }
    scoped_row_chunks(out, rows, row_len, workers, &f);
}

/// The scoped split itself: `chunks` contiguous row ranges, one thread
/// each (mirrors `util::pool::scoped_chunks`, but row-aligned).
fn scoped_row_chunks<F>(out: &mut [f32], rows: usize, row_len: usize, chunks: usize, f: &F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let base = rows / chunks;
    let rem = rows % chunks;
    thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0;
        for c in 0..chunks {
            let nrows = base + usize::from(c < rem);
            let (head, tail) = rest.split_at_mut(nrows * row_len);
            rest = tail;
            let start = row0;
            s.spawn(move || f(start, nrows, head));
            row0 += nrows;
        }
    });
}

// -- unrolled inner primitives (bit-identical by construction) ---------------

/// `o[j] += v * w[j]`, unrolled 8-wide with a scalar tail. Every update
/// touches an independent element, so the unroll cannot change rounding.
#[inline]
fn axpy8(o: &mut [f32], v: f32, w: &[f32]) {
    debug_assert_eq!(o.len(), w.len());
    let chunks = o.len() / 8;
    for c in 0..chunks {
        let j = c * 8;
        o[j] += v * w[j];
        o[j + 1] += v * w[j + 1];
        o[j + 2] += v * w[j + 2];
        o[j + 3] += v * w[j + 3];
        o[j + 4] += v * w[j + 4];
        o[j + 5] += v * w[j + 5];
        o[j + 6] += v * w[j + 6];
        o[j + 7] += v * w[j + 7];
    }
    for j in chunks * 8..o.len() {
        o[j] += v * w[j];
    }
}

/// Single-accumulator dot in strictly ascending index order, unrolled
/// 8-wide *without reassociation* (the `dsub % 8` trick from the
/// quantizer's `dot8`, restricted to one accumulator): the op sequence is
/// the rolled loop's, so the sum is bit-identical — deliberately NOT a
/// multi-accumulator dot, which would round differently and break the
/// engine's exactness contract against the naive `matmul_a_bt`.
#[inline]
fn dot_serial(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let j = c * 8;
        s += a[j] * b[j];
        s += a[j + 1] * b[j + 1];
        s += a[j + 2] * b[j + 2];
        s += a[j + 3] * b[j + 3];
        s += a[j + 4] * b[j + 4];
        s += a[j + 5] * b[j + 5];
        s += a[j + 6] * b[j + 6];
        s += a[j + 7] * b[j + 7];
    }
    for j in chunks * 8..a.len() {
        s += a[j] * b[j];
    }
    s
}

// -- the reference kernels ---------------------------------------------------

/// The naive triple loops, verbatim from the pre-tiling engine: the
/// bit-identity oracle for the tiled kernels (property tests, benches).
pub mod naive {
    /// `x [m, k] @ w [k, n] + bias [n]`.
    pub fn dense(x: &[f32], w: &[f32], bias: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for i in 0..m {
            let row = &x[i * k..(i + 1) * k];
            let o = &mut out[i * n..(i + 1) * n];
            o.copy_from_slice(bias);
            for (kk, &xv) in row.iter().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                for (ov, &wv) in o.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
        }
    }

    /// `a^T [k, m] @ g [m, n]` for `a [m, k]` (weight gradients).
    pub fn matmul_at_b(a: &[f32], g: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let grow = &g[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let o = &mut out[kk * n..(kk + 1) * n];
                for (ov, &gv) in o.iter_mut().zip(grow) {
                    *ov += av * gv;
                }
            }
        }
    }

    /// `g [m, n] @ w^T [n, k]` for `w [k, n]` (input gradients).
    pub fn matmul_a_bt(g: &[f32], w: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
        for i in 0..m {
            let grow = &g[i * n..(i + 1) * n];
            let orow = &mut out[i * k..(i + 1) * k];
            for (kk, ov) in orow.iter_mut().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut s = 0.0f32;
                for (gv, wv) in grow.iter().zip(wrow) {
                    s += gv * wv;
                }
                *ov = s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn run_all(
        policy: GemmPolicy,
        (m, k, n): (usize, usize, usize),
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        g: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut d = vec![0.0f32; m * n];
        dense_into(x, w, bias, m, k, n, &mut d, policy);
        let mut atb = vec![0.0f32; k * n];
        matmul_at_b_into(x, g, m, k, n, &mut atb, policy);
        let mut abt = vec![0.0f32; m * k];
        matmul_a_bt_into(g, w, m, n, k, &mut abt, policy);
        (d, atb, abt)
    }

    /// Tiled and parallel match naive bitwise on shapes that cross every
    /// tile/unroll boundary (MR, KB, the 8-wide tails, single rows).
    #[test]
    fn tiled_and_parallel_match_naive_bitwise() {
        let mut rng = Rng::new(0xD07);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (4, 8, 8),
            (5, 9, 17),
            (8, 784, 32),
            (2, 33, 62),
            (13, 40, 24),
        ] {
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let g = rand_vec(&mut rng, m * n);
            let base = run_all(GemmPolicy::naive(), (m, k, n), &x, &w, &bias, &g);
            for policy in [GemmPolicy::tiled(), GemmPolicy::parallel(3)] {
                let got = run_all(policy, (m, k, n), &x, &w, &bias, &g);
                assert_eq!(got.0, base.0, "dense {m}x{k}x{n} {:?}", policy);
                assert_eq!(got.1, base.1, "at_b {m}x{k}x{n} {:?}", policy);
                assert_eq!(got.2, base.2, "a_bt {m}x{k}x{n} {:?}", policy);
            }
        }
    }

    /// The parallel threshold must not change results, only scheduling:
    /// force a big-enough shape so threads actually spawn.
    #[test]
    fn parallel_spawns_and_matches_on_large_shapes() {
        let (m, k, n) = (32usize, 96usize, 48usize); // m*k*n > PAR_MIN_WORK
        assert!(m * k * n >= PAR_MIN_WORK);
        let mut rng = Rng::new(7);
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        let g = rand_vec(&mut rng, m * n);
        let base = run_all(GemmPolicy::naive(), (m, k, n), &x, &w, &bias, &g);
        for workers in [2usize, 5, 16] {
            let got = run_all(GemmPolicy::parallel(workers), (m, k, n), &x, &w, &bias, &g);
            assert_eq!(got, base, "workers={workers}");
        }
    }

    #[test]
    fn dense_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] + [10, 20]
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [1.0f32, 1.0, 1.0, 1.0];
        let bias = [10.0f32, 20.0];
        for policy in [GemmPolicy::naive(), GemmPolicy::tiled()] {
            let mut out = [0.0f32; 4];
            dense_into(&x, &w, &bias, 2, 2, 2, &mut out, policy);
            assert_eq!(out, [13.0, 23.0, 17.0, 27.0]);
        }
    }

    #[test]
    fn transposed_kernels_match_explicit_transpose() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (5usize, 6usize, 4usize);
        let a = rand_vec(&mut rng, m * k);
        let g = rand_vec(&mut rng, m * n);
        let w = rand_vec(&mut rng, k * n);
        // aᵀ@g via the f64-free reference: out[kk][j] = Σ_i a[i][kk]·g[i][j]
        let mut want = vec![0.0f32; k * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[kk * n + j] += a[i * k + kk] * g[i * n + j];
                }
            }
        }
        let mut got = vec![0.0f32; k * n];
        matmul_at_b_into(&a, &g, m, k, n, &mut got, GemmPolicy::tiled());
        assert_eq!(got, want);
        // g@wᵀ: out[i][kk] = Σ_j g[i][j]·w[kk][j]
        let mut want = vec![0.0f32; m * k];
        for i in 0..m {
            for kk in 0..k {
                let mut s = 0.0f32;
                for j in 0..n {
                    s += g[i * n + j] * w[kk * n + j];
                }
                want[i * k + kk] = s;
            }
        }
        let mut got = vec![0.0f32; m * k];
        matmul_a_bt_into(&g, &w, m, n, k, &mut got, GemmPolicy::tiled());
        assert_eq!(got, want);
    }

    #[test]
    fn colsum_matches_reference() {
        let g = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = [0.0f32; 3];
        colsum_into(&g, 2, 3, &mut out);
        assert_eq!(out, [5.0, 7.0, 9.0]);
    }

    #[test]
    fn dot_serial_matches_rolled_loop_bitwise() {
        let mut rng = Rng::new(9);
        for len in [0usize, 1, 7, 8, 9, 16, 31, 62, 1152] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let mut rolled = 0.0f32;
            for j in 0..len {
                rolled += a[j] * b[j];
            }
            assert_eq!(dot_serial(&a, &b).to_bits(), rolled.to_bits(), "len={len}");
        }
    }

    #[test]
    fn policy_labels() {
        assert_eq!(GemmPolicy::naive().label(), "naive");
        assert_eq!(GemmPolicy::tiled().label(), "tiled");
        assert_eq!(GemmPolicy::parallel(4).label(), "tiled+parallel");
        assert_eq!(GemmPolicy::parallel(1).label(), "tiled");
        assert_eq!(GemmPolicy::default(), GemmPolicy::tiled());
    }
}
