//! Small row-major `f32` tensor used on the coordinator hot path.
//!
//! Heavy model math lives in the AOT artifacts (L2); this type exists for
//! the L3-side linear algebra — parameter aggregation, optimizer updates,
//! quantizer buffers — so it optimizes for flat `Vec<f32>` access rather
//! than generality. Shapes are explicit; element ops check them. The
//! native engine's dense compute kernels live in [`gemm`].

pub mod gemm;

use std::fmt;

/// Row-major dense `f32` tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // -- elementwise ---------------------------------------------------------

    fn check_same(&self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "shape mismatch");
    }

    /// `self += alpha * other` (the aggregation/optimizer workhorse).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        self.check_same(other);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|a| *a = v);
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.check_same(other);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.check_same(other);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Squared L2 distance to another tensor.
    pub fn sq_dist(&self, other: &Tensor) -> f32 {
        self.check_same(other);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    }

    /// 2-D matmul, for tests and tiny host-side checks only.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(other.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &other.data[p * n..(p + 1) * n];
                let dst = &mut out[i * n..(i + 1) * n];
                for (d, b) in dst.iter_mut().zip(row) {
                    *d += a * b;
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }
}

/// A named list of tensors: model parameters or gradients for one side.
#[derive(Clone, Debug, Default)]
pub struct TensorList {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl TensorList {
    pub fn new(names: Vec<String>, tensors: Vec<Tensor>) -> Self {
        assert_eq!(names.len(), tensors.len());
        TensorList { names, tensors }
    }

    pub fn zeros_like(&self) -> TensorList {
        TensorList {
            names: self.names.clone(),
            tensors: self.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// `self += alpha * other`, tensor by tensor.
    pub fn axpy(&mut self, alpha: f32, other: &TensorList) {
        assert_eq!(self.len(), other.len());
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            a.axpy(alpha, b);
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        self.tensors.iter_mut().for_each(|t| t.scale(alpha));
    }

    pub fn l2_norm(&self) -> f32 {
        self.tensors
            .iter()
            .map(|t| {
                let n = t.l2_norm();
                n * n
            })
            .sum::<f32>()
            .sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.tensors.iter().all(|t| t.is_finite())
    }

    /// Total serialized size in bytes at `phi` bits per element.
    pub fn wire_bits(&self, phi: usize) -> usize {
        self.numel() * phi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_item() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let b = Tensor::from_vec(&[3], vec![1., 1., 1.]);
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[3., 4., 5.]);
        assert!((a.l2_norm() - 50f32.sqrt()).abs() < 1e-6);
        assert_eq!(a.max_abs(), 5.0);
        assert_eq!(a.sq_dist(&b), 4. + 9. + 16.);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn tensor_list_ops() {
        let tl = TensorList::new(
            vec!["w".into(), "b".into()],
            vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[2])],
        );
        assert_eq!(tl.numel(), 6);
        assert_eq!(tl.wire_bits(64), 384);
        let mut acc = tl.zeros_like();
        let mut ones = tl.zeros_like();
        ones.tensors.iter_mut().for_each(|t| t.fill(1.0));
        acc.axpy(0.5, &ones);
        assert_eq!(acc.tensors[0].data(), &[0.5; 4]);
        assert!(acc.is_finite());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data()[4], 5.0);
    }
}
