// Dev tool: load an HLO text file, compile on PJRT CPU, print I/O shapes.
use anyhow::Result;
fn main() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    for path in std::env::args().skip(1) {
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        match client.compile(&comp) {
            Ok(_) => println!("{path}: compile OK"),
            Err(e) => println!("{path}: COMPILE FAILED: {e}"),
        }
    }
    Ok(())
}
