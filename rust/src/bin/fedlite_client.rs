//! `fedlite-client` — standalone replica worker for networked runs.
//!
//! Connects to a `fedlite serve` coordinator, rebuilds the run from the
//! `Welcome` config, and serves client steps over the socket until the
//! run ends (or `--max-rounds` rounds have been served, after which it
//! leaves gracefully between rounds). A dropped session triggers a
//! bounded exponential-backoff reconnect (`--reconnect-tries`,
//! `--backoff-ms`); every round re-syncs the replica's state, so a
//! rejoined worker is bit-identical to one that never left. See
//! `fedlite::coordinator::worker` for the protocol.

use fedlite::coordinator::worker::WorkerOptions;
use fedlite::util::logging;

const USAGE: &str = "\
fedlite-client — replica worker for a `fedlite serve` coordinator

USAGE:
    fedlite-client [--connect <addr>] [--max-rounds <n>] [--log <level>]
                   [--reconnect-tries <n>] [--backoff-ms <ms>]
                   [--straggle-ms <ms>]

FLAGS:
    --connect <addr>       coordinator address [default: 127.0.0.1:7878]
    --max-rounds <n>       leave after serving n rounds; 0 = serve until the
                           coordinator shuts the run down [default: 0]
    --reconnect-tries <n>  consecutive failed connects tolerated before
                           giving up (budget refills after each successful
                           handshake) [default: 5]
    --backoff-ms <ms>      base reconnect delay; doubles per consecutive
                           failure, capped at 10s [default: 100]
    --straggle-ms <ms>     debug: sleep this long before every reply,
                           making this worker a deterministic straggler
                           [default: 0]
    --log <level>          log level [default: info]
    --help                 print this help
";

fn main() {
    let mut connect = String::from("127.0.0.1:7878");
    let mut opts = WorkerOptions::default();
    let mut level = String::from("info");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut val = |flag: &str| {
            args.next()
                .ok_or_else(|| format!("{flag} needs a value"))
        };
        fn parsed<T: std::str::FromStr>(flag: &str, v: String) -> Result<T, String> {
            v.parse().map_err(|_| format!("{flag}: bad value '{v}'"))
        }
        let r = match a.as_str() {
            "--connect" => val("--connect").map(|v| connect = v),
            "--max-rounds" => val("--max-rounds")
                .and_then(|v| parsed("--max-rounds", v))
                .map(|n| opts.max_rounds = n),
            "--reconnect-tries" => val("--reconnect-tries")
                .and_then(|v| parsed("--reconnect-tries", v))
                .map(|n| opts.reconnect_tries = n),
            "--backoff-ms" => val("--backoff-ms")
                .and_then(|v| parsed("--backoff-ms", v))
                .map(|n| opts.backoff_ms = n),
            "--straggle-ms" => val("--straggle-ms")
                .and_then(|v| parsed("--straggle-ms", v))
                .map(|n| opts.straggle_ms = n),
            "--log" => val("--log").map(|v| level = v),
            "--help" | "-h" => {
                print!("{USAGE}");
                return;
            }
            other => Err(format!("unknown flag '{other}'")),
        };
        if let Err(msg) = r {
            eprintln!("{msg}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    logging::init(&level);
    if let Err(e) = fedlite::coordinator::worker::run_worker(&connect, opts) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
