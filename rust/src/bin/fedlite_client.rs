//! `fedlite-client` — standalone replica worker for networked runs.
//!
//! Connects to a `fedlite serve` coordinator, rebuilds the run from the
//! `Welcome` config, and serves client steps over the socket until the
//! run ends (or `--max-rounds` rounds have been served, after which it
//! leaves gracefully between rounds). See
//! `fedlite::coordinator::worker` for the protocol.

use fedlite::util::logging;

const USAGE: &str = "\
fedlite-client — replica worker for a `fedlite serve` coordinator

USAGE:
    fedlite-client [--connect <addr>] [--max-rounds <n>] [--log <level>]

FLAGS:
    --connect <addr>    coordinator address [default: 127.0.0.1:7878]
    --max-rounds <n>    leave after serving n rounds; 0 = serve until the
                        coordinator shuts the run down [default: 0]
    --log <level>       log level [default: info]
    --help              print this help
";

fn main() {
    let mut connect = String::from("127.0.0.1:7878");
    let mut max_rounds = 0usize;
    let mut level = String::from("info");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut val = |flag: &str| {
            args.next()
                .ok_or_else(|| format!("{flag} needs a value"))
        };
        let r = match a.as_str() {
            "--connect" => val("--connect").map(|v| connect = v),
            "--max-rounds" => val("--max-rounds").and_then(|v| {
                v.parse()
                    .map(|n| max_rounds = n)
                    .map_err(|_| format!("--max-rounds: bad count '{v}'"))
            }),
            "--log" => val("--log").map(|v| level = v),
            "--help" | "-h" => {
                print!("{USAGE}");
                return;
            }
            other => Err(format!("unknown flag '{other}'")),
        };
        if let Err(msg) = r {
            eprintln!("{msg}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    logging::init(&level);
    if let Err(e) = fedlite::coordinator::worker::run_worker(&connect, max_rounds) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
