//! The paper's message-size and compression-ratio accounting (§4.1, §3).
//!
//! Original up-link activation payload: `phi * d * B` bits. FedLite
//! payload: codebook `phi * d * L * R / q` bits + codewords
//! `B * q * log2(L)` bits. The paper's reported ratios use the *exact*
//! (possibly fractional) `log2 L` and `phi = 64`; the wire format in
//! [`crate::comm::message`] uses `ceil(log2 L)` and actual byte counts —
//! both are exposed here and compared in tests.

use crate::quantizer::packing;

/// Accounting parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Bits per floating-point scalar in the paper's accounting (64).
    pub phi: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { phi: 64 }
    }
}

impl CostModel {
    pub fn new(phi: usize) -> Self {
        CostModel { phi }
    }

    /// Uncompressed activation upload for one batch, in bits.
    pub fn raw_activation_bits(&self, b: usize, d: usize) -> f64 {
        (self.phi * d * b) as f64
    }

    /// FedLite compressed payload in bits with *exact* `log2 L`
    /// (paper formula: `phi*d*R*L/q + B*q*log2 L`).
    pub fn fedlite_bits(&self, b: usize, d: usize, q: usize, r: usize, l: usize) -> f64 {
        let codebook = self.phi as f64 * d as f64 * r as f64 * l as f64 / q as f64;
        let codewords = b as f64 * q as f64 * (l as f64).log2().max(0.0);
        codebook + codewords
    }

    /// Compression ratio: raw / compressed (paper Figs. 3–5 x-axis).
    pub fn ratio(&self, b: usize, d: usize, q: usize, r: usize, l: usize) -> f64 {
        self.raw_activation_bits(b, d) / self.fedlite_bits(b, d, q, r, l)
    }

    /// Actual wire bytes of the quantized upload, exactly as
    /// [`crate::comm::message`] frames it: f32 codebook entries at 4
    /// bytes, the bit-packed codewords as *one* stream across all R
    /// groups (not R separately padded streams), plus the message framing
    /// ([`QUANTIZED_WIRE_OVERHEAD`]: the 13-byte header, six u32 geometry
    /// fields, and two length prefixes). Kept in lockstep with
    /// `Message::wire_len` by `wire_bytes_matches_wire_format_exactly`.
    pub fn wire_bytes(&self, b: usize, d: usize, q: usize, r: usize, l: usize) -> usize {
        let dsub = d / q;
        let codebook = r * l * dsub * 4;
        let ncodes = b * q; // == r * group_size(b)
        QUANTIZED_WIRE_OVERHEAD + codebook + packing::packed_len(ncodes, l)
    }

    // -- per-round per-client up-link totals (Table 1 / Fig. 6) -------------

    /// FedAvg: the whole model every round.
    pub fn fedavg_uplink_bits(&self, model_params: usize) -> f64 {
        (self.phi * model_params) as f64
    }

    /// SplitFed: raw activations + client-side model sync (`B d + |w_c|`).
    pub fn splitfed_uplink_bits(&self, b: usize, d: usize, wc_params: usize) -> f64 {
        self.raw_activation_bits(b, d) + (self.phi * wc_params) as f64
    }

    /// FedLite: compressed activations + client-side model sync.
    pub fn fedlite_uplink_bits(
        &self,
        b: usize,
        d: usize,
        q: usize,
        r: usize,
        l: usize,
        wc_params: usize,
    ) -> f64 {
        self.fedlite_bits(b, d, q, r, l) + (self.phi * wc_params) as f64
    }
}

/// Framing bytes [`crate::comm::message`] puts around a quantized upload
/// body: `magic u32 | type u8 | round u32 | client u32` (13-byte header),
/// six `u32` geometry fields (q, R, L, B, d, Ng), and the two `u32`
/// length prefixes of the codebook and codeword sections.
pub const QUANTIZED_WIRE_OVERHEAD: usize = 13 + 6 * 4 + 4 + 4;

/// Convenience free functions mirroring the paper's formulas.
pub fn compressed_bits(phi: usize, b: usize, d: usize, q: usize, r: usize, l: usize) -> f64 {
    CostModel::new(phi).fedlite_bits(b, d, q, r, l)
}

pub fn compression_ratio(b: usize, d: usize, q: usize, r: usize, l: usize) -> f64 {
    CostModel::default().ratio(b, d, q, r, l)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FEMNIST headline: d=9216, B=20, q=1152, L=2, R=1 must land near the
    /// paper's 490x claim.
    #[test]
    fn femnist_headline_ratio_matches_paper() {
        let ratio = compression_ratio(20, 9216, 1152, 1, 2);
        assert!(
            (480.0..500.0).contains(&ratio),
            "expected ~490x, got {ratio:.1}x"
        );
    }

    #[test]
    fn kmeans_limit_matches_formula() {
        // q = R = 1: ratio = phi d B / (phi d L + B log2 L)
        let m = CostModel::default();
        let r = m.ratio(20, 100, 1, 1, 4);
        let expect = (64.0 * 100.0 * 20.0) / (64.0 * 100.0 * 4.0 + 20.0 * 2.0);
        assert!((r - expect).abs() < 1e-9);
        // vanilla K-means with L>=B can never compress
        assert!(m.ratio(20, 100, 1, 1, 32) < 1.0);
    }

    #[test]
    fn grouping_improves_ratio() {
        // fixing q, decreasing R shrinks the codebook -> larger ratio
        let m = CostModel::default();
        let r_grouped = m.ratio(20, 9216, 4608, 1, 8);
        let r_vanilla = m.ratio(20, 9216, 4608, 4608, 8);
        assert!(r_grouped > 10.0 * r_vanilla);
    }

    #[test]
    fn subvector_division_shrinks_codewords_not_codebook() {
        let m = CostModel::default();
        // with R = q (vanilla PQ) codebook bits are phi*d*L regardless of q
        let b1 = m.fedlite_bits(20, 9216, 1, 1, 8);
        let b2 = m.fedlite_bits(20, 9216, 288, 288, 8);
        let codebook = 64.0 * 9216.0 * 8.0;
        assert!((b1 - (codebook + 20.0 * 3.0)).abs() < 1e-6);
        assert!((b2 - (codebook + 20.0 * 288.0 * 3.0)).abs() < 1e-6);
    }

    #[test]
    fn wire_bytes_close_to_model() {
        // packed wire bytes should track the f32-variant of the model:
        // for the headline config log2 L is exact and the packing is
        // byte-aligned, so the only gap is the message framing (45 bytes
        // against a ~2.9 KB payload, ~1.6%)
        let m = CostModel::new(32); // wire floats are f32
        let (b, d, q, r, l) = (20, 9216, 1152, 1, 2);
        let model_bits = m.fedlite_bits(b, d, q, r, l);
        let wire = m.wire_bytes(b, d, q, r, l) as f64 * 8.0;
        let rel = (wire - model_bits).abs() / model_bits;
        assert!(rel < 0.02, "wire {wire} vs model {model_bits} (rel {rel:.4})");
        // and the framing is the entire gap
        let framed = model_bits + (QUANTIZED_WIRE_OVERHEAD * 8) as f64;
        assert!((wire - framed).abs() < 1e-9, "wire {wire} vs framed model {framed}");
    }

    /// `wire_bytes` must equal what the wire format actually transports,
    /// byte for byte — codebooks, single packed codeword stream, and
    /// message framing included.
    #[test]
    fn wire_bytes_matches_wire_format_exactly() {
        use crate::comm::message::Message;
        use crate::quantizer::packing;
        let m = CostModel::default();
        for (b, d, q, r, l) in
            [(20, 9216, 1152, 1, 2), (6, 16, 4, 2, 3), (20, 100, 1, 1, 4), (8, 32, 8, 4, 5)]
        {
            let dsub = d / q;
            let ng = b * q / r;
            let msg = Message::QuantizedUpload {
                q,
                r,
                l,
                b,
                d,
                ng,
                codebooks: vec![0.0; r * l * dsub],
                packed_codes: vec![0; packing::packed_len(r * ng, l)],
            };
            assert_eq!(
                m.wire_bytes(b, d, q, r, l),
                msg.wire_len(),
                "({b},{d},{q},{r},{l})"
            );
        }
    }

    #[test]
    fn uplink_totals_ordering() {
        // FEMNIST: FedLite << SplitFed < FedAvg (paper Fig. 6 regime)
        let m = CostModel::default();
        let (wc, w) = (18_816usize, 1_206_590usize);
        let fa = m.fedavg_uplink_bits(w);
        let sf = m.splitfed_uplink_bits(20, 9216, wc);
        let fl = m.fedlite_uplink_bits(20, 9216, 1152, 1, 2, wc);
        assert!(fl < sf && sf < fa);
        // paper §5: FedLite total uplink ~10x smaller than SplitFed
        let gain = sf / fl;
        assert!((7.0..14.0).contains(&gain), "gain {gain:.1}");
        // paper §5: ~62x less than FedAvg
        let gain_fa = fa / fl;
        assert!((45.0..80.0).contains(&gain_fa), "gain vs fedavg {gain_fa:.1}");
    }
}
