//! The FedLite grouped product quantizer (paper §4.1), native engine.
//!
//! Two interchangeable implementations exist in the system:
//!
//! * this **native rust engine** — used for arbitrary `(q, L, R)` sweeps
//!   (Figures 3, 4, 5) and on the hot path when `quantizer = "native"`;
//! * the **Pallas/PJRT artifacts** (`artifacts/*/pq_q*_L*_R*.hlo.txt`) —
//!   the L1 kernels, used when `quantizer = "pjrt"`.
//!
//! Integration tests cross-validate the two paths on identical inputs.
//!
//! Submodules: [`kmeans`] (Lloyd + k-means++ init), [`pq`] (subvector
//! split/grouping + end-to-end quantize), [`packing`] (log2(L)-bit
//! codeword packing for the wire), [`cost`] (the paper's message-size
//! and compression-ratio model).

pub mod cost;
pub mod kmeans;
pub mod packing;
pub mod pq;

pub use cost::{compressed_bits, compression_ratio, CostModel};
pub use kmeans::{KMeans, KMeansInit, KMeansScratch};
pub use pq::{GroupedPq, PqConfig, PqOutput, QuantizeScratch};
