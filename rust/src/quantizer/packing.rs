//! Bit-packing of PQ codewords for the wire.
//!
//! Each code needs `ceil(log2 L)` bits; the paper's size accounting uses
//! the exact (possibly fractional) `log2 L` — [`super::cost`] models that —
//! while the actual transported bytes use this packed form. Codes are
//! packed little-endian within a contiguous bit stream.

/// Bits needed to store one code for `l` clusters (`ceil(log2 l)`, min 1
/// bit so the stream is never empty; L = 1 still carries one (zero) bit).
pub fn bits_per_code(l: usize) -> u32 {
    debug_assert!(l >= 1);
    if l <= 1 {
        1
    } else {
        usize::BITS - (l - 1).leading_zeros()
    }
}

/// Pack `codes` (each `< l`) into a little-endian bit stream.
pub fn pack(codes: &[u32], l: usize) -> Vec<u8> {
    let bits = bits_per_code(l) as usize;
    let total_bits = codes.len() * bits;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as usize) < l.max(1), "code {c} out of range for L={l}");
        let mut v = c as u64;
        let mut remaining = bits;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = remaining.min(8 - off);
            out[byte] |= ((v & ((1u64 << take) - 1)) as u8) << off;
            v >>= take;
            bitpos += take;
            remaining -= take;
        }
    }
    out
}

/// Unpack `n` codes from a bit stream produced by [`pack`].
pub fn unpack(bytes: &[u8], n: usize, l: usize) -> anyhow::Result<Vec<u32>> {
    let bits = bits_per_code(l) as usize;
    let need = (n * bits).div_ceil(8);
    anyhow::ensure!(
        bytes.len() >= need,
        "packed stream too short: {} bytes < {} needed",
        bytes.len(),
        need
    );
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut v = 0u64;
        let mut got = 0usize;
        while got < bits {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (bits - got).min(8 - off);
            let chunk = (bytes[byte] >> off) as u64 & ((1u64 << take) - 1);
            v |= chunk << got;
            got += take;
            bitpos += take;
        }
        // L = 1 still carries one bit per code, but only 0 is a valid
        // codeword — reject streams whose padding bits were tampered with
        anyhow::ensure!((v as usize) < l.max(1), "decoded code {v} >= L={l}");
        out.push(v as u32);
    }
    Ok(out)
}

/// Packed size in bytes for `n` codes with `l` clusters.
pub fn packed_len(n: usize, l: usize) -> usize {
    (n * bits_per_code(l) as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_per_code_values() {
        assert_eq!(bits_per_code(1), 1);
        assert_eq!(bits_per_code(2), 1);
        assert_eq!(bits_per_code(3), 2);
        assert_eq!(bits_per_code(4), 2);
        assert_eq!(bits_per_code(5), 3);
        assert_eq!(bits_per_code(32), 5);
        assert_eq!(bits_per_code(33), 6);
        assert_eq!(bits_per_code(1024), 10);
    }

    #[test]
    fn roundtrip_various_l() {
        let mut rng = Rng::new(0);
        for &l in &[1usize, 2, 3, 7, 8, 17, 60, 100, 960] {
            for &n in &[0usize, 1, 5, 64, 1000] {
                let codes: Vec<u32> =
                    (0..n).map(|_| rng.below(l.max(1)) as u32).collect();
                let packed = pack(&codes, l);
                assert_eq!(packed.len(), packed_len(n, l));
                let back = unpack(&packed, n, l).unwrap();
                assert_eq!(back, codes, "L={l} n={n}");
            }
        }
    }

    #[test]
    fn packing_is_compact() {
        // 8 codes with L=2 -> exactly 1 byte
        assert_eq!(pack(&[1, 0, 1, 1, 0, 0, 1, 0], 2).len(), 1);
        // 3 codes with L=32 (5 bits) -> 15 bits -> 2 bytes
        assert_eq!(pack(&[31, 0, 17], 32).len(), 2);
    }

    #[test]
    fn l1_corrupt_bit_rejected() {
        // L = 1: only the zero codeword exists; a stray 1 bit is corruption
        let packed = pack(&[0; 8], 1);
        assert_eq!(packed, vec![0u8]);
        assert_eq!(unpack(&packed, 8, 1).unwrap(), vec![0; 8]);
        assert!(unpack(&[0b0000_0100], 8, 1).is_err());
    }

    #[test]
    fn short_stream_rejected() {
        let packed = pack(&[1, 2, 3], 4);
        assert!(unpack(&packed[..packed.len() - 1], 3, 4).is_err());
    }

    #[test]
    fn cross_byte_boundaries() {
        // 5-bit codes crossing byte boundaries exercise split writes
        let codes: Vec<u32> = (0..29).map(|i| (i * 7) % 31).collect();
        let packed = pack(&codes, 31);
        assert_eq!(unpack(&packed, 29, 31).unwrap(), codes);
    }
}
