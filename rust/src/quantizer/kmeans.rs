//! Lloyd's K-means over flat `[n, d]` point buffers.
//!
//! Semantics are kept bit-compatible with the Pallas kernel and the jnp
//! oracle (`python/compile/kernels/ref.py`): squared-euclidean metric,
//! argmin ties broken toward the lowest centroid index, empty clusters
//! keep their previous centroid. Initialization is either L distinct
//! random rows (what the AOT artifacts receive) or k-means++.

use crate::util::rng::Rng;

/// Centroid initialization strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KMeansInit {
    /// L distinct rows sampled uniformly (matches the PJRT artifact path).
    RandomRows,
    /// k-means++ seeding (D² sampling) — better error at equal iterations.
    PlusPlus,
}

/// K-means state over points of dimension `d`.
pub struct KMeans {
    pub l: usize,
    pub d: usize,
    pub iters: usize,
    pub init: KMeansInit,
}

impl KMeans {
    pub fn new(l: usize, d: usize, iters: usize, init: KMeansInit) -> Self {
        assert!(l >= 1 && d >= 1);
        KMeans { l, d, iters, init }
    }

    /// Pick initial centroids from `points` (`n x d`, flat row-major).
    pub fn init_centroids(&self, points: &[f32], n: usize, rng: &mut Rng) -> Vec<f32> {
        assert_eq!(points.len(), n * self.d);
        assert!(n >= 1, "kmeans on empty point set");
        match self.init {
            KMeansInit::RandomRows => {
                // L distinct rows when possible; wrap when n < L.
                let mut out = Vec::with_capacity(self.l * self.d);
                let idx = if n >= self.l {
                    rng.choose_k(n, self.l)
                } else {
                    (0..self.l).map(|i| i % n).collect()
                };
                for i in idx {
                    out.extend_from_slice(&points[i * self.d..(i + 1) * self.d]);
                }
                out
            }
            KMeansInit::PlusPlus => self.plus_plus(points, n, rng),
        }
    }

    fn plus_plus(&self, points: &[f32], n: usize, rng: &mut Rng) -> Vec<f32> {
        let d = self.d;
        let mut cents = Vec::with_capacity(self.l * d);
        let first = rng.below(n);
        cents.extend_from_slice(&points[first * d..(first + 1) * d]);
        let mut dist2: Vec<f64> = (0..n)
            .map(|i| sq_dist(&points[i * d..(i + 1) * d], &cents[0..d]) as f64)
            .collect();
        for _ in 1..self.l {
            let total: f64 = dist2.iter().sum();
            let pick = if total <= 0.0 {
                rng.below(n)
            } else {
                rng.categorical(&dist2)
            };
            let start = cents.len();
            cents.extend_from_slice(&points[pick * d..(pick + 1) * d]);
            let c = cents[start..start + d].to_vec();
            for (i, dst) in dist2.iter_mut().enumerate() {
                let nd = sq_dist(&points[i * d..(i + 1) * d], &c) as f64;
                if nd < *dst {
                    *dst = nd;
                }
            }
        }
        cents
    }

    /// Nearest-centroid assignment; writes codes and returns total error.
    pub fn assign(
        &self,
        points: &[f32],
        n: usize,
        centroids: &[f32],
        codes: &mut [u32],
    ) -> f64 {
        let xnorms = point_norms(points, n, self.d);
        self.assign_with_norms(points, &xnorms, n, centroids, codes)
    }

    /// Assignment with pre-computed `||x||^2` per point. `run_from` hoists
    /// the norm computation out of the Lloyd loop (§Perf: the points never
    /// change across iterations, only the centroids do).
    pub fn assign_with_norms(
        &self,
        points: &[f32],
        xnorms: &[f32],
        n: usize,
        centroids: &[f32],
        codes: &mut [u32],
    ) -> f64 {
        assert_eq!(centroids.len(), self.l * self.d);
        assert_eq!(codes.len(), n);
        let d = self.d;
        // ||c||^2 precomputed once per pass.
        let cnorm: Vec<f32> = (0..self.l)
            .map(|j| dot(&centroids[j * d..(j + 1) * d], &centroids[j * d..(j + 1) * d]))
            .collect();
        let mut total = 0.0f64;
        for i in 0..n {
            let x = &points[i * d..(i + 1) * d];
            let xn = xnorms[i];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for j in 0..self.l {
                let c = &centroids[j * d..(j + 1) * d];
                let dist = xn - 2.0 * dot(x, c) + cnorm[j];
                if dist < best_d {
                    best_d = dist;
                    best = j;
                }
            }
            codes[i] = best as u32;
            total += best_d.max(0.0) as f64;
        }
        total
    }

    /// Lloyd centroid update; empty clusters keep the previous centroid.
    pub fn update(
        &self,
        points: &[f32],
        n: usize,
        codes: &[u32],
        centroids: &mut [f32],
    ) {
        let d = self.d;
        let mut sums = vec![0.0f64; self.l * d];
        let mut counts = vec![0usize; self.l];
        for i in 0..n {
            let j = codes[i] as usize;
            counts[j] += 1;
            let x = &points[i * d..(i + 1) * d];
            let s = &mut sums[j * d..(j + 1) * d];
            for (sv, xv) in s.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        for j in 0..self.l {
            if counts[j] > 0 {
                let inv = 1.0 / counts[j] as f64;
                for k in 0..d {
                    centroids[j * d + k] = (sums[j * d + k] * inv) as f32;
                }
            }
        }
    }

    /// Full run: init + `iters` Lloyd iterations + final assignment.
    /// Returns `(centroids, codes, final_sq_error)`.
    pub fn run(
        &self,
        points: &[f32],
        n: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<u32>, f64) {
        let mut centroids = self.init_centroids(points, n, rng);
        self.run_from(points, n, &mut centroids)
            .map_with(centroids)
    }

    /// Lloyd iterations from given initial centroids (mutated in place).
    /// Returns `(codes, final_sq_error)`.
    pub fn run_from(
        &self,
        points: &[f32],
        n: usize,
        centroids: &mut Vec<f32>,
    ) -> RunOut {
        let mut codes = vec![0u32; n];
        // §Perf: point norms are loop-invariant across Lloyd iterations.
        let xnorms = point_norms(points, n, self.d);
        for _ in 0..self.iters {
            self.assign_with_norms(points, &xnorms, n, centroids, &mut codes);
            self.update(points, n, &codes, centroids);
        }
        let err = self.assign_with_norms(points, &xnorms, n, centroids, &mut codes);
        RunOut { codes, err }
    }
}

/// Output of `run_from`.
pub struct RunOut {
    pub codes: Vec<u32>,
    pub err: f64,
}

impl RunOut {
    fn map_with(self, centroids: Vec<f32>) -> (Vec<f32>, Vec<u32>, f64) {
        (centroids, self.codes, self.err)
    }
}

fn point_norms(points: &[f32], n: usize, d: usize) -> Vec<f32> {
    (0..n)
        .map(|i| dot(&points[i * d..(i + 1) * d], &points[i * d..(i + 1) * d]))
        .collect()
}

/// 4-lane unrolled dot product — the assignment inner loop is dominated by
/// short dots (dsub 8–32); independent partial sums let the compiler keep
/// four accumulators live instead of a serial FP dependency chain (§Perf).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = k * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_points(rng: &mut Rng, centers: &[[f32; 2]], per: usize, std: f32) -> Vec<f32> {
        let mut out = Vec::new();
        for c in centers {
            for _ in 0..per {
                out.push(c[0] + rng.normal() as f32 * std);
                out.push(c[1] + rng.normal() as f32 * std);
            }
        }
        out
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(0);
        let centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let pts = blob_points(&mut rng, &centers, 50, 0.2);
        let km = KMeans::new(3, 2, 10, KMeansInit::PlusPlus);
        let (cents, codes, err) = km.run(&pts, 150, &mut rng);
        assert!(err / 150.0 < 0.3, "per-point err {}", err / 150.0);
        // each blob maps to exactly one cluster
        for blob in 0..3 {
            let c0 = codes[blob * 50];
            assert!(codes[blob * 50..(blob + 1) * 50].iter().all(|&c| c == c0));
        }
        // centroids near true centers (in some order)
        for c in &centers {
            let best = (0..3)
                .map(|j| sq_dist(&cents[j * 2..j * 2 + 2], c))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.1, "center {c:?} off by {best}");
        }
    }

    #[test]
    fn error_nonincreasing_over_iters() {
        let mut rng = Rng::new(1);
        let pts: Vec<f32> = (0..600).map(|_| rng.normal() as f32).collect();
        let mut prev = f64::INFINITY;
        for iters in 0..6 {
            let mut r = Rng::new(7); // same init each time
            let km = KMeans::new(8, 3, iters, KMeansInit::RandomRows);
            let (_, _, err) = km.run(&pts, 200, &mut r);
            assert!(err <= prev + 1e-6, "iters={iters}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        // 2 tight blobs + one far-away init centroid that captures nothing
        let pts = vec![0.0, 0.0, 0.1, 0.0, 5.0, 5.0, 5.1, 5.0];
        let km = KMeans::new(3, 2, 4, KMeansInit::RandomRows);
        let mut cents = vec![0.0, 0.0, 5.0, 5.0, 1e3, 1e3];
        let out = km.run_from(&pts, 4, &mut cents);
        assert_eq!(&cents[4..6], &[1e3, 1e3]);
        assert!(out.codes.iter().all(|&c| c != 2));
    }

    #[test]
    fn exact_match_assigns_self() {
        let pts = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let km = KMeans::new(3, 2, 0, KMeansInit::RandomRows);
        let mut codes = vec![0u32; 3];
        let err = km.assign(&pts, 3, &pts.clone(), &mut codes);
        assert_eq!(codes, vec![0, 1, 2]);
        assert!(err.abs() < 1e-9);
    }

    #[test]
    fn tie_breaks_to_lowest_index() {
        // two identical centroids: argmin must pick index 0
        let pts = vec![1.0f32, 1.0];
        let cents = vec![1.0f32, 1.0, 1.0, 1.0];
        let km = KMeans::new(2, 2, 0, KMeansInit::RandomRows);
        let mut codes = vec![9u32; 1];
        km.assign(&pts, 1, &cents, &mut codes);
        assert_eq!(codes[0], 0);
    }

    #[test]
    fn more_clusters_than_points_wraps() {
        let pts = vec![1.0f32, 2.0, 3.0, 4.0];
        let km = KMeans::new(4, 2, 2, KMeansInit::RandomRows);
        let mut rng = Rng::new(3);
        let (cents, codes, err) = km.run(&pts, 2, &mut rng);
        assert_eq!(cents.len(), 8);
        assert_eq!(codes.len(), 2);
        assert!(err < 1e-9); // 2 points, >=2 distinct centroids -> exact
    }

    #[test]
    fn l_equals_one_gives_mean() {
        let pts = vec![0.0f32, 0.0, 2.0, 0.0, 4.0, 6.0];
        let km = KMeans::new(1, 2, 3, KMeansInit::RandomRows);
        let mut rng = Rng::new(5);
        let (cents, _, _) = km.run(&pts, 3, &mut rng);
        assert!((cents[0] - 2.0).abs() < 1e-6);
        assert!((cents[1] - 2.0).abs() < 1e-6);
    }
}
