//! Lloyd's K-means over flat `[n, d]` point buffers.
//!
//! Semantics are kept bit-compatible with the Pallas kernel and the jnp
//! oracle (`python/compile/kernels/ref.py`): squared-euclidean metric,
//! argmin ties broken toward the lowest centroid index, empty clusters
//! keep their previous centroid. Initialization is either L distinct
//! random rows (what the AOT artifacts receive) or k-means++.
//!
//! # The pruned hot path and its exactness contract
//!
//! [`KMeans::run_from_into`] is the zero-allocation kernel behind
//! [`crate::quantizer::pq::GroupedPq::quantize_into`]. It carries
//! Hamerly-style norm bounds across Lloyd iterations — a per-point upper
//! bound on the distance to the assigned centroid, a per-point lower
//! bound on the distance to every *other* centroid, and per-centroid
//! drift tracking — so that most points skip the full L-centroid scan
//! once the clustering starts to settle.
//!
//! Exactness is mandatory, not best-effort: the bound test is inflated by
//! a conservative floating-point slack (see [`formula_slack`]) that
//! covers the worst-case rounding of the `xn − 2·dot + cnorm` distance
//! formula, so a point is only skipped when its previous assignment
//! *provably* equals what the full scan would pick — including the
//! lowest-index tie-break, which cannot fire under the strict separation
//! the test requires. Any point that fails the test takes the verbatim
//! naive scan ([`scan_point`], the same code path
//! [`KMeans::assign_with_norms`] uses). Codes, per-point errors, and the
//! f64 error-summation order are therefore bit-identical to the naive
//! kernel at any worker count — enforced by the golden fixtures and the
//! `prop_pruned_lloyd_matches_naive` property test.

use crate::util::pool::scoped_chunks;
use crate::util::rng::Rng;

/// Centroid initialization strategy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KMeansInit {
    /// L distinct rows sampled uniformly (matches the PJRT artifact path).
    #[default]
    RandomRows,
    /// k-means++ seeding (D² sampling) — better error at equal iterations.
    PlusPlus,
}

/// K-means state over points of dimension `d`.
pub struct KMeans {
    pub l: usize,
    pub d: usize,
    pub iters: usize,
    pub init: KMeansInit,
}

/// Per-point pruning state: assignment plus the Hamerly bounds (in
/// distance, not squared-distance, domain) and the final-pass formula
/// distance. Struct-of-one-array keeps the assignment pass cache-friendly
/// and lets [`scoped_chunks`] split the pass across workers.
#[derive(Clone, Copy, Debug, Default)]
struct PointState {
    code: u32,
    /// Upper bound on the true distance to the assigned centroid.
    ub: f32,
    /// Lower bound on the true distance to every other centroid.
    lb: f32,
    /// Formula distance to the assigned centroid (final pass only).
    dist: f32,
}

/// Reusable buffers for [`KMeans::run_from_into`]: after the first call
/// at a given `(n, l, d)` shape, subsequent runs perform no heap
/// allocation (capacities only grow, asserted by `tests/alloc.rs`).
#[derive(Default)]
pub struct KMeansScratch {
    /// `||x||²` per point — loop-invariant across Lloyd iterations.
    xnorms: Vec<f32>,
    /// `||x||` per point (feeds the float-error slack).
    sqrt_xn: Vec<f32>,
    /// `||c||²` per centroid, refreshed every assignment pass.
    cnorm: Vec<f32>,
    /// Per-point assignment + bounds.
    states: Vec<PointState>,
    /// Previous-iteration centroids (drift tracking).
    old_cents: Vec<f32>,
    /// Per-centroid drift `||c_new − c_old||` (inflated upper bound).
    drift: Vec<f32>,
    /// f64 accumulators for the Lloyd update.
    sums: Vec<f64>,
    counts: Vec<usize>,
}

impl KMeansScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn prepare(&mut self, n: usize, l: usize, d: usize) {
        self.xnorms.resize(n, 0.0);
        self.sqrt_xn.resize(n, 0.0);
        self.cnorm.resize(l, 0.0);
        self.states.resize(n, PointState::default());
        self.old_cents.resize(l * d, 0.0);
        self.drift.resize(l, 0.0);
        self.sums.resize(l * d, 0.0);
        self.counts.resize(l, 0);
    }

    /// Capacity fingerprint (pointer + capacity per buffer) — the
    /// scratch-stability tests assert this does not change across
    /// same-shape reuse.
    pub fn capacity_fingerprint(&self) -> Vec<(usize, usize)> {
        vec![
            (self.xnorms.as_ptr() as usize, self.xnorms.capacity()),
            (self.sqrt_xn.as_ptr() as usize, self.sqrt_xn.capacity()),
            (self.cnorm.as_ptr() as usize, self.cnorm.capacity()),
            (self.states.as_ptr() as usize, self.states.capacity()),
            (self.old_cents.as_ptr() as usize, self.old_cents.capacity()),
            (self.drift.as_ptr() as usize, self.drift.capacity()),
            (self.sums.as_ptr() as usize, self.sums.capacity()),
            (self.counts.as_ptr() as usize, self.counts.capacity()),
        ]
    }
}

/// Multiplicative inflation applied to every bound update; 8 ulps per
/// operation is far beyond what one add/sqrt can lose.
const BOUND_INFLATE: f32 = 1.0 + 8.0 * f32::EPSILON;
const BOUND_DEFLATE: f32 = 1.0 - 8.0 * f32::EPSILON;

/// Points-per-pass work threshold below which the assignment pass stays
/// serial even when `workers > 1` (thread spawn would dominate).
const PAR_MIN_WORK: usize = 1 << 17;

/// Conservative bound on `|fl(xn − 2·dot + cnorm) − exact|`: standard
/// dot-product error analysis gives ≤ (d+2)·u·(‖x‖+‖c‖)² with u = EPS/2;
/// (d+16)·EPS provides ≥ 4× headroom, which also absorbs the rounding of
/// the bound arithmetic itself. Overshooting only costs pruning rate,
/// never correctness.
#[inline]
fn formula_slack(d: usize, sx: f32, cmax: f32) -> f32 {
    let s = sx + cmax;
    (d as f32 + 16.0) * f32::EPSILON * s * s
}

impl KMeans {
    pub fn new(l: usize, d: usize, iters: usize, init: KMeansInit) -> Self {
        assert!(l >= 1 && d >= 1);
        KMeans { l, d, iters, init }
    }

    /// Pick initial centroids from `points` (`n x d`, flat row-major).
    pub fn init_centroids(&self, points: &[f32], n: usize, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0f32; self.l * self.d];
        let mut idx = Vec::new();
        self.init_centroids_into(points, n, rng, &mut idx, &mut out);
        out
    }

    /// Buffer-reusing [`KMeans::init_centroids`]: writes the `[L, d]`
    /// centroids into `out`, reusing `idx_scratch` for the row draw.
    /// Consumes exactly the same RNG stream as the allocating version.
    /// Allocation-free for `RandomRows` (the artifact/hot-path init);
    /// `PlusPlus` still allocates its seeding buffers — it is not part
    /// of the zero-alloc steady-state contract.
    pub fn init_centroids_into(
        &self,
        points: &[f32],
        n: usize,
        rng: &mut Rng,
        idx_scratch: &mut Vec<usize>,
        out: &mut [f32],
    ) {
        assert_eq!(points.len(), n * self.d);
        assert_eq!(out.len(), self.l * self.d);
        assert!(n >= 1, "kmeans on empty point set");
        match self.init {
            KMeansInit::RandomRows => {
                // L distinct rows when possible; wrap when n < L.
                if n >= self.l {
                    rng.choose_k_into(n, self.l, idx_scratch);
                } else {
                    idx_scratch.clear();
                    idx_scratch.extend((0..self.l).map(|i| i % n));
                }
                for (slot, &i) in idx_scratch.iter().enumerate() {
                    out[slot * self.d..(slot + 1) * self.d]
                        .copy_from_slice(&points[i * self.d..(i + 1) * self.d]);
                }
            }
            KMeansInit::PlusPlus => {
                let cents = self.plus_plus(points, n, rng);
                out.copy_from_slice(&cents);
            }
        }
    }

    fn plus_plus(&self, points: &[f32], n: usize, rng: &mut Rng) -> Vec<f32> {
        let d = self.d;
        let mut cents = Vec::with_capacity(self.l * d);
        let first = rng.below(n);
        cents.extend_from_slice(&points[first * d..(first + 1) * d]);
        let mut dist2: Vec<f64> = (0..n)
            .map(|i| sq_dist(&points[i * d..(i + 1) * d], &cents[0..d]) as f64)
            .collect();
        for _ in 1..self.l {
            let total: f64 = dist2.iter().sum();
            let pick = if total <= 0.0 {
                rng.below(n)
            } else {
                rng.categorical(&dist2)
            };
            let start = cents.len();
            cents.extend_from_slice(&points[pick * d..(pick + 1) * d]);
            let c = cents[start..start + d].to_vec();
            for (i, dst) in dist2.iter_mut().enumerate() {
                let nd = sq_dist(&points[i * d..(i + 1) * d], &c) as f64;
                if nd < *dst {
                    *dst = nd;
                }
            }
        }
        cents
    }

    /// Nearest-centroid assignment; writes codes and returns total error.
    pub fn assign(
        &self,
        points: &[f32],
        n: usize,
        centroids: &[f32],
        codes: &mut [u32],
    ) -> f64 {
        let xnorms = point_norms(points, n, self.d);
        self.assign_with_norms(points, &xnorms, n, centroids, codes)
    }

    /// Assignment with pre-computed `||x||^2` per point. `run_from` hoists
    /// the norm computation out of the Lloyd loop (§Perf: the points never
    /// change across iterations, only the centroids do). This is the naive
    /// full-scan reference the pruned kernel must match bit for bit.
    pub fn assign_with_norms(
        &self,
        points: &[f32],
        xnorms: &[f32],
        n: usize,
        centroids: &[f32],
        codes: &mut [u32],
    ) -> f64 {
        assert_eq!(centroids.len(), self.l * self.d);
        assert_eq!(codes.len(), n);
        let d = self.d;
        // ||c||^2 precomputed once per pass.
        let cnorm: Vec<f32> = (0..self.l)
            .map(|j| dot(&centroids[j * d..(j + 1) * d], &centroids[j * d..(j + 1) * d]))
            .collect();
        let mut total = 0.0f64;
        for i in 0..n {
            let x = &points[i * d..(i + 1) * d];
            let (best, best_d, _) = scan_point(x, xnorms[i], centroids, &cnorm, d);
            codes[i] = best as u32;
            total += best_d.max(0.0) as f64;
        }
        total
    }

    /// Lloyd centroid update; empty clusters keep the previous centroid.
    pub fn update(
        &self,
        points: &[f32],
        n: usize,
        codes: &[u32],
        centroids: &mut [f32],
    ) {
        let d = self.d;
        let mut sums = vec![0.0f64; self.l * d];
        let mut counts = vec![0usize; self.l];
        for i in 0..n {
            let j = codes[i] as usize;
            counts[j] += 1;
            let x = &points[i * d..(i + 1) * d];
            let s = &mut sums[j * d..(j + 1) * d];
            for (sv, xv) in s.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        for j in 0..self.l {
            if counts[j] > 0 {
                let inv = 1.0 / counts[j] as f64;
                for k in 0..d {
                    centroids[j * d + k] = (sums[j * d + k] * inv) as f32;
                }
            }
        }
    }

    /// Full run: init + `iters` Lloyd iterations + final assignment.
    /// Returns `(centroids, codes, final_sq_error)`.
    pub fn run(
        &self,
        points: &[f32],
        n: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<u32>, f64) {
        let mut centroids = self.init_centroids(points, n, rng);
        self.run_from(points, n, &mut centroids)
            .map_with(centroids)
    }

    /// Lloyd iterations from given initial centroids (mutated in place).
    /// Returns `(codes, final_sq_error)`. Convenience wrapper over
    /// [`KMeans::run_from_into`] with a throwaway scratch.
    pub fn run_from(
        &self,
        points: &[f32],
        n: usize,
        centroids: &mut Vec<f32>,
    ) -> RunOut {
        let mut codes = vec![0u32; n];
        let mut scratch = KMeansScratch::default();
        let err = self.run_from_into(points, n, centroids, &mut codes, &mut scratch, 1);
        RunOut { codes, err }
    }

    /// The pruned, allocation-free Lloyd kernel: `iters` iterations from
    /// the given centroids (mutated in place), codes written into the
    /// caller's buffer, scratch reused across calls. When `workers > 1`
    /// and the pass is large enough, the assignment chunks over points via
    /// [`scoped_chunks`]; per-point work is independent and the error is
    /// summed serially in point order afterwards, so results are
    /// bit-identical at any worker count. See the module docs for the
    /// exactness contract of the pruning.
    pub fn run_from_into(
        &self,
        points: &[f32],
        n: usize,
        centroids: &mut [f32],
        codes: &mut [u32],
        scratch: &mut KMeansScratch,
        workers: usize,
    ) -> f64 {
        assert_eq!(points.len(), n * self.d);
        assert_eq!(centroids.len(), self.l * self.d);
        assert_eq!(codes.len(), n);
        let d = self.d;
        scratch.prepare(n, self.l, d);
        // §Perf: point norms are loop-invariant across Lloyd iterations.
        for i in 0..n {
            let x = &points[i * d..(i + 1) * d];
            let xn = dot(x, x);
            scratch.xnorms[i] = xn;
            scratch.sqrt_xn[i] = xn.max(0.0).sqrt();
        }
        let mut cmax = refresh_cnorm(centroids, self.l, d, &mut scratch.cnorm);
        self.assign_pass(points, centroids, cmax, scratch, true, self.iters == 0, workers);
        for it in 0..self.iters {
            self.update_in(points, n, centroids, scratch);
            cmax = refresh_cnorm(centroids, self.l, d, &mut scratch.cnorm);
            let finalize = it + 1 == self.iters;
            self.assign_pass(points, centroids, cmax, scratch, false, finalize, workers);
        }
        // reduce codes + error in point order — the same f64 summation
        // order the naive final assignment uses
        let mut total = 0.0f64;
        for (code, st) in codes.iter_mut().zip(&scratch.states[..n]) {
            *code = st.code;
            total += st.dist.max(0.0) as f64;
        }
        total
    }

    /// One assignment pass over all points. `full` forces the naive scan
    /// (first pass, no bounds yet); `finalize` records the per-point
    /// formula distance for the error reduction.
    #[allow(clippy::too_many_arguments)]
    fn assign_pass(
        &self,
        points: &[f32],
        centroids: &[f32],
        cmax: f32,
        scratch: &mut KMeansScratch,
        full: bool,
        finalize: bool,
        workers: usize,
    ) {
        let d = self.d;
        let l = self.l;
        let n = scratch.states.len();
        let xnorms = &scratch.xnorms;
        let sqrt_xn = &scratch.sqrt_xn;
        let cnorm = &scratch.cnorm;
        let scan = |start: usize, chunk: &mut [PointState]| {
            for (k, st) in chunk.iter_mut().enumerate() {
                let i = start + k;
                let x = &points[i * d..(i + 1) * d];
                let xn = xnorms[i];
                let e = formula_slack(d, sqrt_xn[i], cmax);
                if !full {
                    // Hamerly skip test: the assigned centroid provably
                    // stays the formula-argmin (strict separation beats
                    // the combined float slack, so no tie is possible)
                    let keep = st.lb > 0.0 && st.ub * st.ub + 2.0 * e < st.lb * st.lb;
                    if keep {
                        if finalize {
                            let j = st.code as usize;
                            let c = &centroids[j * d..(j + 1) * d];
                            st.dist = xn - 2.0 * dot(x, c) + cnorm[j];
                        }
                        continue;
                    }
                }
                let (best, best_d, second) = scan_point(x, xn, centroids, cnorm, d);
                st.code = best as u32;
                st.ub = (best_d.max(0.0) + e).sqrt() * BOUND_INFLATE;
                st.lb = (second - e).max(0.0).sqrt() * BOUND_DEFLATE;
                if finalize {
                    st.dist = best_d;
                }
            }
        };
        if workers > 1 && n * l * d >= PAR_MIN_WORK {
            scoped_chunks(&mut scratch.states, workers.min(n), |_ci, start, chunk| {
                scan(start, chunk)
            });
        } else {
            scan(0, &mut scratch.states[..n]);
        }
    }

    /// Scratch-backed Lloyd update (identical arithmetic to
    /// [`KMeans::update`]) plus centroid-drift bound maintenance.
    fn update_in(
        &self,
        points: &[f32],
        n: usize,
        centroids: &mut [f32],
        scratch: &mut KMeansScratch,
    ) {
        let d = self.d;
        scratch.old_cents.copy_from_slice(centroids);
        scratch.sums.iter_mut().for_each(|s| *s = 0.0);
        scratch.counts.iter_mut().for_each(|c| *c = 0);
        for i in 0..n {
            let j = scratch.states[i].code as usize;
            scratch.counts[j] += 1;
            let x = &points[i * d..(i + 1) * d];
            let s = &mut scratch.sums[j * d..(j + 1) * d];
            for (sv, xv) in s.iter_mut().zip(x) {
                *sv += *xv as f64;
            }
        }
        for j in 0..self.l {
            if scratch.counts[j] > 0 {
                let inv = 1.0 / scratch.counts[j] as f64;
                for k in 0..d {
                    centroids[j * d + k] = (scratch.sums[j * d + k] * inv) as f32;
                }
            }
        }
        // per-centroid drift (inflated upper bound on ‖c_new − c_old‖);
        // empty clusters kept their centroid, so their drift is exactly 0
        let mut dmax = 0.0f32;
        for j in 0..self.l {
            let s2 = sq_dist(
                &scratch.old_cents[j * d..(j + 1) * d],
                &centroids[j * d..(j + 1) * d],
            );
            let dj = (s2 * (1.0 + d as f32 * f32::EPSILON)).sqrt() * BOUND_INFLATE;
            scratch.drift[j] = dj;
            dmax = dmax.max(dj);
        }
        for st in scratch.states[..n].iter_mut() {
            st.ub = (st.ub + scratch.drift[st.code as usize]) * BOUND_INFLATE;
            st.lb = ((st.lb - dmax) * BOUND_DEFLATE).max(0.0);
        }
    }
}

/// Output of `run_from`.
pub struct RunOut {
    pub codes: Vec<u32>,
    pub err: f64,
}

impl RunOut {
    fn map_with(self, centroids: Vec<f32>) -> (Vec<f32>, Vec<u32>, f64) {
        (centroids, self.codes, self.err)
    }
}

fn point_norms(points: &[f32], n: usize, d: usize) -> Vec<f32> {
    (0..n)
        .map(|i| dot(&points[i * d..(i + 1) * d], &points[i * d..(i + 1) * d]))
        .collect()
}

/// Refresh `||c||²` per centroid; returns an inflated upper bound on
/// `max_j ||c_j||` (feeds the float-error slack).
fn refresh_cnorm(centroids: &[f32], l: usize, d: usize, cnorm: &mut [f32]) -> f32 {
    let mut cmax2 = 0.0f32;
    for (j, cn) in cnorm.iter_mut().enumerate().take(l) {
        let c = &centroids[j * d..(j + 1) * d];
        *cn = dot(c, c);
        cmax2 = cmax2.max(*cn);
    }
    cmax2.max(0.0).sqrt() * BOUND_INFLATE
}

/// The naive scan over all L centroids for one point: the formula argmin
/// with the lowest-index tie-break, plus the second-best distance for the
/// pruning bounds. Tracking `second` adds comparisons but never changes
/// which `(best, best_d)` the original loop produced.
#[inline]
fn scan_point(
    x: &[f32],
    xn: f32,
    centroids: &[f32],
    cnorm: &[f32],
    d: usize,
) -> (usize, f32, f32) {
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    let mut second = f32::INFINITY;
    for (j, cn) in cnorm.iter().enumerate() {
        let c = &centroids[j * d..(j + 1) * d];
        let dist = xn - 2.0 * dot(x, c) + cn;
        if dist < best_d {
            second = best_d;
            best_d = dist;
            best = j;
        } else if dist < second {
            second = dist;
        }
    }
    (best, best_d, second)
}

/// Unrolled dot product — the assignment inner loop is dominated by short
/// dots (dsub 8–32); independent partial sums let the compiler keep four
/// accumulators live instead of a serial FP dependency chain (§Perf).
/// `dsub % 8 == 0` (the paper's FEMNIST shapes) takes the wide variant.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    if a.len() % 8 == 0 {
        dot8(a, b)
    } else {
        dot4(a, b)
    }
}

/// 8-elements-per-iteration variant for `len % 8 == 0`. Deliberately
/// keeps the *same four accumulators in the same update order* as
/// [`dot4`] (two of its iterations unrolled), so the result is
/// bit-identical to the 4-lane path — an 8-accumulator version would
/// round differently and break the golden fixtures. The win is halved
/// loop overhead and wider instruction scheduling, not a different sum.
#[inline]
fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % 8, 0);
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 8;
    for k in 0..chunks {
        let i = k * 8;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
        acc[0] += a[i + 4] * b[i + 4];
        acc[1] += a[i + 5] * b[i + 5];
        acc[2] += a[i + 6] * b[i + 6];
        acc[3] += a[i + 7] * b[i + 7];
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// 4-lane unrolled dot with a scalar tail (any length).
#[inline]
fn dot4(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = k * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_points(rng: &mut Rng, centers: &[[f32; 2]], per: usize, std: f32) -> Vec<f32> {
        let mut out = Vec::new();
        for c in centers {
            for _ in 0..per {
                out.push(c[0] + rng.normal() as f32 * std);
                out.push(c[1] + rng.normal() as f32 * std);
            }
        }
        out
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(0);
        let centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let pts = blob_points(&mut rng, &centers, 50, 0.2);
        let km = KMeans::new(3, 2, 10, KMeansInit::PlusPlus);
        let (cents, codes, err) = km.run(&pts, 150, &mut rng);
        assert!(err / 150.0 < 0.3, "per-point err {}", err / 150.0);
        // each blob maps to exactly one cluster
        for blob in 0..3 {
            let c0 = codes[blob * 50];
            assert!(codes[blob * 50..(blob + 1) * 50].iter().all(|&c| c == c0));
        }
        // centroids near true centers (in some order)
        for c in &centers {
            let best = (0..3)
                .map(|j| sq_dist(&cents[j * 2..j * 2 + 2], c))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.1, "center {c:?} off by {best}");
        }
    }

    #[test]
    fn error_nonincreasing_over_iters() {
        let mut rng = Rng::new(1);
        let pts: Vec<f32> = (0..600).map(|_| rng.normal() as f32).collect();
        let mut prev = f64::INFINITY;
        for iters in 0..6 {
            let mut r = Rng::new(7); // same init each time
            let km = KMeans::new(8, 3, iters, KMeansInit::RandomRows);
            let (_, _, err) = km.run(&pts, 200, &mut r);
            assert!(err <= prev + 1e-6, "iters={iters}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        // 2 tight blobs + one far-away init centroid that captures nothing
        let pts = vec![0.0, 0.0, 0.1, 0.0, 5.0, 5.0, 5.1, 5.0];
        let km = KMeans::new(3, 2, 4, KMeansInit::RandomRows);
        let mut cents = vec![0.0, 0.0, 5.0, 5.0, 1e3, 1e3];
        let out = km.run_from(&pts, 4, &mut cents);
        assert_eq!(&cents[4..6], &[1e3, 1e3]);
        assert!(out.codes.iter().all(|&c| c != 2));
    }

    #[test]
    fn exact_match_assigns_self() {
        let pts = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let km = KMeans::new(3, 2, 0, KMeansInit::RandomRows);
        let mut codes = vec![0u32; 3];
        let err = km.assign(&pts, 3, &pts.clone(), &mut codes);
        assert_eq!(codes, vec![0, 1, 2]);
        assert!(err.abs() < 1e-9);
    }

    #[test]
    fn tie_breaks_to_lowest_index() {
        // two identical centroids: argmin must pick index 0
        let pts = vec![1.0f32, 1.0];
        let cents = vec![1.0f32, 1.0, 1.0, 1.0];
        let km = KMeans::new(2, 2, 0, KMeansInit::RandomRows);
        let mut codes = vec![9u32; 1];
        km.assign(&pts, 1, &cents, &mut codes);
        assert_eq!(codes[0], 0);
    }

    #[test]
    fn pruned_tie_break_matches_naive_on_duplicate_centroids() {
        // duplicated centroids + several iterations: skipped points must
        // keep reporting the lowest index, exactly like the full scan
        let mut rng = Rng::new(11);
        let n = 40;
        let pts: Vec<f32> = (0..n * 2).map(|_| (rng.below(3) as f32) - 1.0).collect();
        let km = KMeans::new(4, 2, 5, KMeansInit::RandomRows);
        let mut cents = vec![0.5f32, 0.5, 0.5, 0.5, -0.5, -0.5, 2.0, 2.0];
        let mut cents_naive = cents.clone();
        // naive reference: the historical assign/update sequence
        let mut codes_naive = vec![0u32; n];
        let xn = point_norms(&pts, n, 2);
        for _ in 0..km.iters {
            km.assign_with_norms(&pts, &xn, n, &cents_naive, &mut codes_naive);
            km.update(&pts, n, &codes_naive, &mut cents_naive);
        }
        let err_naive = km.assign_with_norms(&pts, &xn, n, &cents_naive, &mut codes_naive);
        let mut codes = vec![0u32; n];
        let mut scratch = KMeansScratch::default();
        let err = km.run_from_into(&pts, n, &mut cents, &mut codes, &mut scratch, 1);
        assert_eq!(codes, codes_naive);
        assert_eq!(cents, cents_naive);
        assert_eq!(err.to_bits(), err_naive.to_bits());
    }

    #[test]
    fn more_clusters_than_points_wraps() {
        let pts = vec![1.0f32, 2.0, 3.0, 4.0];
        let km = KMeans::new(4, 2, 2, KMeansInit::RandomRows);
        let mut rng = Rng::new(3);
        let (cents, codes, err) = km.run(&pts, 2, &mut rng);
        assert_eq!(cents.len(), 8);
        assert_eq!(codes.len(), 2);
        assert!(err < 1e-9); // 2 points, >=2 distinct centroids -> exact
    }

    #[test]
    fn l_equals_one_gives_mean() {
        let pts = vec![0.0f32, 0.0, 2.0, 0.0, 4.0, 6.0];
        let km = KMeans::new(1, 2, 3, KMeansInit::RandomRows);
        let mut rng = Rng::new(5);
        let (cents, _, _) = km.run(&pts, 3, &mut rng);
        assert!((cents[0] - 2.0).abs() < 1e-6);
        assert!((cents[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn dot8_is_bit_identical_to_dot4() {
        let mut rng = Rng::new(21);
        for len in [8usize, 16, 24, 32, 64, 128] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal() as f32 * 3.0).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal() as f32 * 3.0).collect();
            assert_eq!(dot8(&a, &b).to_bits(), dot4(&a, &b).to_bits(), "len={len}");
        }
    }

    #[test]
    fn scratch_capacity_stable_across_same_shape_runs() {
        let mut rng = Rng::new(9);
        let (n, d, l) = (120, 8, 6);
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let km = KMeans::new(l, d, 4, KMeansInit::RandomRows);
        let mut scratch = KMeansScratch::default();
        let mut codes = vec![0u32; n];
        let mut cents = km.init_centroids(&pts, n, &mut rng);
        km.run_from_into(&pts, n, &mut cents, &mut codes, &mut scratch, 1);
        let fp = scratch.capacity_fingerprint();
        for _ in 0..3 {
            let mut c2 = cents.clone();
            km.run_from_into(&pts, n, &mut c2, &mut codes, &mut scratch, 1);
            assert_eq!(scratch.capacity_fingerprint(), fp, "scratch reallocated");
        }
    }
}
