//! Grouped product quantization of activation batches (paper Fig. 2).
//!
//! Given activations `Z [B, d]`: split each row into `q` subvectors of
//! dim `d/q`, stack subvectors into `R` index-contiguous groups (group `g`
//! holds subvector indices `[g·q/R, (g+1)·q/R)` of every example), K-means
//! each group to `L` centroids, emit (codebooks, codes, quantized Z).
//!
//! `q = 1` degenerates to vanilla K-means over whole vectors; `R = q`
//! is vanilla product quantization (per-subvector-position codebooks);
//! `R = 1` is the paper's preferred configuration.

use crate::quantizer::kmeans::{sq_dist, KMeans, KMeansInit};
use crate::util::rng::Rng;

/// Quantizer hyper-parameters (paper notation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PqConfig {
    /// Number of subvectors each activation vector is split into.
    pub q: usize,
    /// Number of groups sharing a codebook (1 <= R <= q, R | q).
    pub r: usize,
    /// Number of centroids per group.
    pub l: usize,
    /// Lloyd iterations.
    pub iters: usize,
    /// Centroid init (RandomRows matches the PJRT artifacts).
    pub init: KMeansInit,
}

impl PqConfig {
    pub fn new(q: usize, r: usize, l: usize) -> Self {
        PqConfig { q, r, l, iters: 8, init: KMeansInit::RandomRows }
    }

    pub fn with_iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    pub fn with_init(mut self, init: KMeansInit) -> Self {
        self.init = init;
        self
    }

    pub fn validate(&self, d: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.q >= 1 && self.r >= 1 && self.l >= 1, "q,R,L >= 1");
        anyhow::ensure!(d % self.q == 0, "q={} must divide d={}", self.q, d);
        anyhow::ensure!(self.q % self.r == 0, "R={} must divide q={}", self.r, self.q);
        Ok(())
    }

    pub fn dsub(&self, d: usize) -> usize {
        d / self.q
    }

    /// Subvectors per group for an activation batch of `b` rows.
    pub fn group_size(&self, b: usize) -> usize {
        b * self.q / self.r
    }
}

/// Result of quantizing one activation batch.
#[derive(Clone, Debug)]
pub struct PqOutput {
    /// `[R, L, dsub]` flat codebooks.
    pub codebooks: Vec<f32>,
    /// `[R, Ng]` flat cluster assignments.
    pub codes: Vec<u32>,
    /// `[B, d]` reconstructed (quantized) activations.
    pub z_tilde: Vec<f32>,
    /// Sum of squared quantization error `||Z - Z~||^2`.
    pub sq_error: f64,
    pub config: PqConfig,
    pub b: usize,
    pub d: usize,
}

impl PqOutput {
    /// Relative error `||Z - Z~||_F / ||Z||_F` (Fig. 3 y-axis).
    pub fn relative_error(&self, z: &[f32]) -> f64 {
        let zn: f64 = z.iter().map(|&x| (x as f64) * (x as f64)).sum();
        (self.sq_error / zn.max(1e-24)).sqrt()
    }

    /// Maximum per-example quantization error `max_j ||z_j - z~_j||`
    /// (the κ in Theorem 4.1).
    pub fn kappa(&self, z: &[f32]) -> f64 {
        let mut kmax = 0.0f64;
        for j in 0..self.b {
            let row = &z[j * self.d..(j + 1) * self.d];
            let qrow = &self.z_tilde[j * self.d..(j + 1) * self.d];
            kmax = kmax.max(sq_dist(row, qrow) as f64);
        }
        kmax.sqrt()
    }
}

/// The grouped product quantizer engine.
pub struct GroupedPq {
    pub config: PqConfig,
    pub d: usize,
}

impl GroupedPq {
    pub fn new(config: PqConfig, d: usize) -> anyhow::Result<Self> {
        config.validate(d)?;
        Ok(GroupedPq { config, d })
    }

    /// Gather the subvectors of group `g` from `z [b, d]` into a flat
    /// `[Ng, dsub]` buffer (paper Fig. 2 steps i–ii).
    pub fn gather_group(&self, z: &[f32], b: usize, g: usize, out: &mut Vec<f32>) {
        let c = &self.config;
        let dsub = c.dsub(self.d);
        let per_group = c.q / c.r;
        out.clear();
        out.reserve(b * per_group * dsub);
        for j in 0..b {
            let row = &z[j * self.d..(j + 1) * self.d];
            let start = g * per_group * dsub;
            out.extend_from_slice(&row[start..start + per_group * dsub]);
        }
    }

    /// Scatter quantized group subvectors back into `z_tilde [b, d]`.
    fn scatter_group(&self, group: &[f32], b: usize, g: usize, z_tilde: &mut [f32]) {
        let c = &self.config;
        let dsub = c.dsub(self.d);
        let per_group = c.q / c.r;
        let chunk = per_group * dsub;
        for j in 0..b {
            let dst = &mut z_tilde[j * self.d + g * chunk..j * self.d + (g + 1) * chunk];
            dst.copy_from_slice(&group[j * chunk..(j + 1) * chunk]);
        }
    }

    /// Quantize one activation batch `z [b, d]`.
    pub fn quantize(&self, z: &[f32], b: usize, rng: &mut Rng) -> PqOutput {
        assert_eq!(z.len(), b * self.d, "z len vs b*d");
        let c = self.config;
        let dsub = c.dsub(self.d);
        let ng = c.group_size(b);
        let km = KMeans::new(c.l, dsub, c.iters, c.init);

        let mut codebooks = Vec::with_capacity(c.r * c.l * dsub);
        let mut codes = Vec::with_capacity(c.r * ng);
        let mut z_tilde = vec![0.0f32; b * self.d];
        let mut sq_error = 0.0f64;
        let mut group_buf: Vec<f32> = Vec::new();
        let mut recon = vec![0.0f32; ng * dsub];

        for g in 0..c.r {
            self.gather_group(z, b, g, &mut group_buf);
            let mut cents = km.init_centroids(&group_buf, ng, rng);
            let out = km.run_from(&group_buf, ng, &mut cents);
            sq_error += out.err;
            for (i, &code) in out.codes.iter().enumerate() {
                let src = &cents[code as usize * dsub..(code as usize + 1) * dsub];
                recon[i * dsub..(i + 1) * dsub].copy_from_slice(src);
            }
            self.scatter_group(&recon, b, g, &mut z_tilde);
            codebooks.extend_from_slice(&cents);
            codes.extend(out.codes);
        }

        PqOutput { codebooks, codes, z_tilde, sq_error, config: c, b, d: self.d }
    }

    /// Reconstruct `z_tilde` from codebooks + codes (server side).
    pub fn reconstruct(
        &self,
        codebooks: &[f32],
        codes: &[u32],
        b: usize,
    ) -> Vec<f32> {
        let c = self.config;
        let dsub = c.dsub(self.d);
        let ng = c.group_size(b);
        assert_eq!(codebooks.len(), c.r * c.l * dsub);
        assert_eq!(codes.len(), c.r * ng);
        let mut z_tilde = vec![0.0f32; b * self.d];
        let mut recon = vec![0.0f32; ng * dsub];
        for g in 0..c.r {
            let cb = &codebooks[g * c.l * dsub..(g + 1) * c.l * dsub];
            let gc = &codes[g * ng..(g + 1) * ng];
            for (i, &code) in gc.iter().enumerate() {
                let src = &cb[code as usize * dsub..(code as usize + 1) * dsub];
                recon[i * dsub..(i + 1) * dsub].copy_from_slice(src);
            }
            self.scatter_group(&recon, b, g, &mut z_tilde);
        }
        z_tilde
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randz(rng: &mut Rng, b: usize, d: usize) -> Vec<f32> {
        (0..b * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn roundtrip_reconstruct_matches_quantize() {
        let mut rng = Rng::new(0);
        let (b, d) = (6, 24);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(8, 2, 3), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let rec = pq.reconstruct(&out.codebooks, &out.codes, b);
        assert_eq!(rec, out.z_tilde);
    }

    #[test]
    fn qerr_matches_z_tilde_distance() {
        let mut rng = Rng::new(1);
        let (b, d) = (5, 16);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(4, 1, 2), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let direct: f64 = z
            .iter()
            .zip(&out.z_tilde)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        assert!((out.sq_error - direct).abs() < 1e-4 * direct.max(1.0));
    }

    #[test]
    fn grouping_layout_matches_paper() {
        // z[j, s] = 10*j + s with dsub=1: group g must contain subvector
        // indices [g*q/R, (g+1)*q/R) of every example.
        let (b, d, q, r) = (2, 4, 4, 2);
        let z: Vec<f32> = (0..b)
            .flat_map(|j| (0..d).map(move |s| (10 * j + s) as f32))
            .collect();
        let pq = GroupedPq::new(PqConfig::new(q, r, 2), d).unwrap();
        let mut buf = Vec::new();
        pq.gather_group(&z, b, 0, &mut buf);
        assert_eq!(buf, vec![0.0, 1.0, 10.0, 11.0]);
        pq.gather_group(&z, b, 1, &mut buf);
        assert_eq!(buf, vec![2.0, 3.0, 12.0, 13.0]);
    }

    #[test]
    fn perfect_quantization_when_patterns_repeat() {
        // Subvectors drawn from exactly L patterns -> zero error.
        let mut rng = Rng::new(2);
        let patterns: Vec<Vec<f32>> = (0..2)
            .map(|_| (0..4).map(|_| rng.normal() as f32).collect())
            .collect();
        let (b, q) = (6, 8);
        let d = q * 4;
        let mut z = Vec::with_capacity(b * d);
        for _ in 0..b {
            for _ in 0..q {
                z.extend_from_slice(&patterns[rng.below(2)]);
            }
        }
        let pq = GroupedPq::new(PqConfig::new(q, 1, 2).with_iters(12), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        assert!(out.sq_error < 1e-6, "err {}", out.sq_error);
        assert!(out.relative_error(&z) < 1e-4);
    }

    #[test]
    fn q1_is_vanilla_kmeans_rows() {
        let mut rng = Rng::new(3);
        let (b, d) = (10, 6);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(1, 1, 3), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        // every reconstructed row must be one of the 3 codebook rows
        for j in 0..b {
            let row = &out.z_tilde[j * d..(j + 1) * d];
            let matched = (0..3).any(|l| {
                let c = &out.codebooks[l * d..(l + 1) * d];
                sq_dist(row, c) < 1e-12
            });
            assert!(matched, "row {j} not a centroid");
        }
    }

    #[test]
    fn more_clusters_lower_error() {
        let mut rng = Rng::new(4);
        let (b, d) = (20, 32);
        let z = randz(&mut rng, b, d);
        let mut last = f64::INFINITY;
        for l in [1usize, 2, 8, 32] {
            let pq = GroupedPq::new(PqConfig::new(8, 1, l).with_iters(15), d).unwrap();
            // fixed rng per run for fair comparison
            let mut r = Rng::new(99);
            let out = pq.quantize(&z, b, &mut r);
            assert!(
                out.sq_error <= last * 1.05,
                "L={l}: {} vs {}",
                out.sq_error,
                last
            );
            last = out.sq_error;
        }
    }

    #[test]
    fn kappa_bounds_mean_error() {
        let mut rng = Rng::new(5);
        let (b, d) = (8, 16);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(4, 1, 2), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let kappa = out.kappa(&z);
        let mean_sq = out.sq_error / b as f64;
        assert!(kappa * kappa + 1e-9 >= mean_sq);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(GroupedPq::new(PqConfig::new(5, 1, 2), 16).is_err()); // q !| d
        assert!(GroupedPq::new(PqConfig::new(4, 3, 2), 16).is_err()); // r !| q
        assert!(GroupedPq::new(PqConfig::new(4, 2, 2), 16).is_ok());
    }
}
