//! Grouped product quantization of activation batches (paper Fig. 2).
//!
//! Given activations `Z [B, d]`: split each row into `q` subvectors of
//! dim `d/q`, stack subvectors into `R` index-contiguous groups (group `g`
//! holds subvector indices `[g·q/R, (g+1)·q/R)` of every example), K-means
//! each group to `L` centroids, emit (codebooks, codes, quantized Z).
//!
//! `q = 1` degenerates to vanilla K-means over whole vectors; `R = q`
//! is vanilla product quantization (per-subvector-position codebooks);
//! `R = 1` is the paper's preferred configuration.

use crate::quantizer::kmeans::{sq_dist, KMeans, KMeansInit, KMeansScratch};
use crate::util::rng::Rng;

/// Quantizer hyper-parameters (paper notation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PqConfig {
    /// Number of subvectors each activation vector is split into.
    pub q: usize,
    /// Number of groups sharing a codebook (1 <= R <= q, R | q).
    pub r: usize,
    /// Number of centroids per group.
    pub l: usize,
    /// Lloyd iterations.
    pub iters: usize,
    /// Centroid init (RandomRows matches the PJRT artifacts).
    pub init: KMeansInit,
}

impl PqConfig {
    pub fn new(q: usize, r: usize, l: usize) -> Self {
        PqConfig { q, r, l, iters: 8, init: KMeansInit::RandomRows }
    }

    pub fn with_iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    pub fn with_init(mut self, init: KMeansInit) -> Self {
        self.init = init;
        self
    }

    pub fn validate(&self, d: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.q >= 1 && self.r >= 1 && self.l >= 1, "q,R,L >= 1");
        anyhow::ensure!(d % self.q == 0, "q={} must divide d={}", self.q, d);
        anyhow::ensure!(self.q % self.r == 0, "R={} must divide q={}", self.r, self.q);
        Ok(())
    }

    pub fn dsub(&self, d: usize) -> usize {
        d / self.q
    }

    /// Subvectors per group for an activation batch of `b` rows.
    pub fn group_size(&self, b: usize) -> usize {
        b * self.q / self.r
    }
}

/// Result of quantizing one activation batch. [`GroupedPq::quantize_into`]
/// reuses the buffers of a caller-owned instance (capacities only grow),
/// so a warm `PqOutput` makes the steady-state hot path allocation-free.
#[derive(Clone, Debug, Default)]
pub struct PqOutput {
    /// `[R, L, dsub]` flat codebooks.
    pub codebooks: Vec<f32>,
    /// `[R, Ng]` flat cluster assignments.
    pub codes: Vec<u32>,
    /// `[B, d]` reconstructed (quantized) activations.
    pub z_tilde: Vec<f32>,
    /// Sum of squared quantization error `||Z - Z~||^2`.
    pub sq_error: f64,
    pub config: PqConfig,
    pub b: usize,
    pub d: usize,
}

impl PqOutput {
    /// Relative error `||Z - Z~||_F / ||Z||_F` (Fig. 3 y-axis).
    pub fn relative_error(&self, z: &[f32]) -> f64 {
        let zn: f64 = z.iter().map(|&x| (x as f64) * (x as f64)).sum();
        (self.sq_error / zn.max(1e-24)).sqrt()
    }

    /// Maximum per-example quantization error `max_j ||z_j - z~_j||`
    /// (the κ in Theorem 4.1).
    pub fn kappa(&self, z: &[f32]) -> f64 {
        let mut kmax = 0.0f64;
        for j in 0..self.b {
            let row = &z[j * self.d..(j + 1) * self.d];
            let qrow = &self.z_tilde[j * self.d..(j + 1) * self.d];
            kmax = kmax.max(sq_dist(row, qrow) as f64);
        }
        kmax.sqrt()
    }
}

/// Reusable working buffers for [`GroupedPq::quantize_into`]: the gather
/// arena (all `R` groups back to back), per-group reconstruction slices,
/// per-group error slots (reduced in group order), the init row-draw
/// buffer, and one [`KMeansScratch`] per fan-out lane. After warm-up at a
/// fixed shape, the quantize path allocates nothing (`tests/alloc.rs`).
#[derive(Default)]
pub struct QuantizeScratch {
    /// `[R][Ng, dsub]` gathered groups (`b·d` floats total).
    groups: Vec<f32>,
    /// `[R][Ng, dsub]` per-group reconstructions.
    recon: Vec<f32>,
    /// Per-group final squared error, reduced serially in group order.
    group_err: Vec<f64>,
    /// Index buffer for the RandomRows draw (`Rng::choose_k_into`).
    init_idx: Vec<usize>,
    /// One k-means scratch per fan-out lane (lane 0 is the serial path).
    kms: Vec<KMeansScratch>,
    /// Fan-out width: across groups when `R > 1`, across points inside
    /// the single group otherwise. `0`/`1` = fully serial — what the
    /// cohort workers use, since the round engine already parallelizes
    /// over clients. Results are bit-identical at any setting.
    pub workers: usize,
}

impl QuantizeScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch sized for nested fan-out inside one quantize call.
    pub fn with_workers(workers: usize) -> Self {
        QuantizeScratch { workers, ..Default::default() }
    }

    /// Capacity fingerprint for scratch-stability assertions (pointer +
    /// capacity per buffer; lane scratches excluded — they have their
    /// own fingerprints).
    pub fn capacity_fingerprint(&self) -> Vec<(usize, usize)> {
        vec![
            (self.groups.as_ptr() as usize, self.groups.capacity()),
            (self.recon.as_ptr() as usize, self.recon.capacity()),
            (self.group_err.as_ptr() as usize, self.group_err.capacity()),
            (self.init_idx.as_ptr() as usize, self.init_idx.capacity()),
            (self.kms.as_ptr() as usize, self.kms.capacity()),
        ]
    }
}

/// The grouped product quantizer engine.
pub struct GroupedPq {
    pub config: PqConfig,
    pub d: usize,
}

impl GroupedPq {
    pub fn new(config: PqConfig, d: usize) -> anyhow::Result<Self> {
        config.validate(d)?;
        Ok(GroupedPq { config, d })
    }

    /// Gather the subvectors of group `g` from `z [b, d]` into a flat
    /// `[Ng, dsub]` buffer (paper Fig. 2 steps i–ii).
    pub fn gather_group(&self, z: &[f32], b: usize, g: usize, out: &mut Vec<f32>) {
        let c = &self.config;
        let chunk = (c.q / c.r) * c.dsub(self.d);
        out.clear();
        out.resize(b * chunk, 0.0);
        self.gather_group_into(z, b, g, out);
    }

    /// Allocation-free [`GroupedPq::gather_group`] writing into a caller
    /// slice of exactly `Ng * dsub` floats.
    pub fn gather_group_into(&self, z: &[f32], b: usize, g: usize, out: &mut [f32]) {
        let c = &self.config;
        let chunk = (c.q / c.r) * c.dsub(self.d);
        assert_eq!(out.len(), b * chunk);
        for j in 0..b {
            let row = &z[j * self.d..(j + 1) * self.d];
            out[j * chunk..(j + 1) * chunk]
                .copy_from_slice(&row[g * chunk..(g + 1) * chunk]);
        }
    }

    /// Scatter quantized group subvectors back into `z_tilde [b, d]`.
    fn scatter_group(&self, group: &[f32], b: usize, g: usize, z_tilde: &mut [f32]) {
        let c = &self.config;
        let dsub = c.dsub(self.d);
        let per_group = c.q / c.r;
        let chunk = per_group * dsub;
        for j in 0..b {
            let dst = &mut z_tilde[j * self.d + g * chunk..j * self.d + (g + 1) * chunk];
            dst.copy_from_slice(&group[j * chunk..(j + 1) * chunk]);
        }
    }

    /// Quantize one activation batch `z [b, d]`. Convenience wrapper over
    /// [`GroupedPq::quantize_into`] with throwaway buffers (bit-identical
    /// output; the `_into` form is the steady-state hot path).
    pub fn quantize(&self, z: &[f32], b: usize, rng: &mut Rng) -> PqOutput {
        let mut scratch = QuantizeScratch::default();
        let mut out = PqOutput::default();
        self.quantize_into(z, b, rng, &mut scratch, &mut out);
        out
    }

    /// Quantize one activation batch `z [b, d]` into caller-owned buffers.
    ///
    /// After the first call at a given `(b, d, config)` shape, repeated
    /// calls perform **no heap allocation**: every working buffer lives in
    /// `scratch`, and `out`'s vectors are resized in place (capacities
    /// only grow). Results are bit-identical to [`GroupedPq::quantize`]
    /// and to the pre-scratch serial engine at any `scratch.workers`
    /// setting:
    ///
    /// * gathering and centroid init run serially in group order, so the
    ///   RNG stream is consumed exactly as before (the Lloyd runs never
    ///   touch the RNG);
    /// * with `R > 1` and `workers > 1`, the per-group k-means runs fan
    ///   out across scoped threads over disjoint output slices, and the
    ///   error reduction happens serially in group-slot order afterwards
    ///   (the same determinism contract as the cohort engine);
    /// * with `R == 1`, the assignment pass inside the single k-means run
    ///   chunks over points instead (see [`KMeans::run_from_into`]).
    pub fn quantize_into(
        &self,
        z: &[f32],
        b: usize,
        rng: &mut Rng,
        scratch: &mut QuantizeScratch,
        out: &mut PqOutput,
    ) {
        assert_eq!(z.len(), b * self.d, "z len vs b*d");
        let c = self.config;
        let dsub = c.dsub(self.d);
        let ng = c.group_size(b);
        let gsz = ng * dsub;
        let cbsz = c.l * dsub;
        let km = KMeans::new(c.l, dsub, c.iters, c.init);
        let workers = scratch.workers.max(1);

        out.config = c;
        out.b = b;
        out.d = self.d;
        out.codebooks.resize(c.r * cbsz, 0.0);
        out.codes.resize(c.r * ng, 0);
        out.z_tilde.resize(b * self.d, 0.0);
        scratch.groups.resize(c.r * gsz, 0.0);
        scratch.recon.resize(c.r * gsz, 0.0);
        scratch.group_err.resize(c.r, 0.0);

        // phase 1 (serial): gather every group and draw its initial
        // centroids directly into the codebook slots — the RNG is only
        // consumed here, in group order, exactly like the serial engine
        for g in 0..c.r {
            let grp = &mut scratch.groups[g * gsz..(g + 1) * gsz];
            self.gather_group_into(z, b, g, grp);
            km.init_centroids_into(
                grp,
                ng,
                rng,
                &mut scratch.init_idx,
                &mut out.codebooks[g * cbsz..(g + 1) * cbsz],
            );
        }

        // phase 2: per-group Lloyd runs + group-local reconstruction,
        // fanned across lanes when there are many codebooks
        let lanes = if c.r > 1 { workers.min(c.r) } else { 1 };
        while scratch.kms.len() < lanes {
            scratch.kms.push(KMeansScratch::default());
        }
        let run_group = |g: usize,
                         cb: &mut [f32],
                         codes: &mut [u32],
                         rec: &mut [f32],
                         kms: &mut KMeansScratch,
                         inner_workers: usize|
         -> f64 {
            let grp = &scratch.groups[g * gsz..(g + 1) * gsz];
            let err = km.run_from_into(grp, ng, cb, codes, kms, inner_workers);
            for (i, &code) in codes.iter().enumerate() {
                let src = &cb[code as usize * dsub..(code as usize + 1) * dsub];
                rec[i * dsub..(i + 1) * dsub].copy_from_slice(src);
            }
            err
        };
        if lanes > 1 {
            // contiguous group ranges per lane over disjoint output slices
            let base = c.r / lanes;
            let rem = c.r % lanes;
            std::thread::scope(|s| {
                let mut cb_rest: &mut [f32] = &mut out.codebooks;
                let mut code_rest: &mut [u32] = &mut out.codes;
                let mut recon_rest: &mut [f32] = &mut scratch.recon;
                let mut err_rest: &mut [f64] = &mut scratch.group_err;
                let mut kms_rest: &mut [KMeansScratch] = &mut scratch.kms;
                let run_group = &run_group;
                let mut g0 = 0usize;
                for lane in 0..lanes {
                    let glen = base + usize::from(lane < rem);
                    let (cb, t) = cb_rest.split_at_mut(glen * cbsz);
                    cb_rest = t;
                    let (codes, t) = code_rest.split_at_mut(glen * ng);
                    code_rest = t;
                    let (rec, t) = recon_rest.split_at_mut(glen * gsz);
                    recon_rest = t;
                    let (errs, t) = err_rest.split_at_mut(glen);
                    err_rest = t;
                    let (kms, t) = kms_rest.split_at_mut(1);
                    kms_rest = t;
                    let start = g0;
                    s.spawn(move || {
                        for k in 0..glen {
                            errs[k] = run_group(
                                start + k,
                                &mut cb[k * cbsz..(k + 1) * cbsz],
                                &mut codes[k * ng..(k + 1) * ng],
                                &mut rec[k * gsz..(k + 1) * gsz],
                                &mut kms[0],
                                1,
                            );
                        }
                    });
                    g0 += glen;
                }
            });
        } else {
            let (kms, _) = scratch.kms.split_at_mut(1);
            let (recon, _) = scratch.recon.split_at_mut(c.r * gsz);
            let (errs, _) = scratch.group_err.split_at_mut(c.r);
            for g in 0..c.r {
                errs[g] = run_group(
                    g,
                    &mut out.codebooks[g * cbsz..(g + 1) * cbsz],
                    &mut out.codes[g * ng..(g + 1) * ng],
                    &mut recon[g * gsz..(g + 1) * gsz],
                    &mut kms[0],
                    workers,
                );
            }
        }

        // phase 3 (serial): scatter + error reduction in group-slot order
        // (the f64 summation order of the serial engine)
        let mut sq_error = 0.0f64;
        for g in 0..c.r {
            self.scatter_group(&scratch.recon[g * gsz..(g + 1) * gsz], b, g, &mut out.z_tilde);
            sq_error += scratch.group_err[g];
        }
        out.sq_error = sq_error;
    }

    /// Reconstruct `z_tilde` from codebooks + codes (server side).
    pub fn reconstruct(
        &self,
        codebooks: &[f32],
        codes: &[u32],
        b: usize,
    ) -> Vec<f32> {
        let c = self.config;
        let dsub = c.dsub(self.d);
        let ng = c.group_size(b);
        assert_eq!(codebooks.len(), c.r * c.l * dsub);
        assert_eq!(codes.len(), c.r * ng);
        let mut z_tilde = vec![0.0f32; b * self.d];
        let mut recon = vec![0.0f32; ng * dsub];
        for g in 0..c.r {
            let cb = &codebooks[g * c.l * dsub..(g + 1) * c.l * dsub];
            let gc = &codes[g * ng..(g + 1) * ng];
            for (i, &code) in gc.iter().enumerate() {
                let src = &cb[code as usize * dsub..(code as usize + 1) * dsub];
                recon[i * dsub..(i + 1) * dsub].copy_from_slice(src);
            }
            self.scatter_group(&recon, b, g, &mut z_tilde);
        }
        z_tilde
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randz(rng: &mut Rng, b: usize, d: usize) -> Vec<f32> {
        (0..b * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn roundtrip_reconstruct_matches_quantize() {
        let mut rng = Rng::new(0);
        let (b, d) = (6, 24);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(8, 2, 3), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let rec = pq.reconstruct(&out.codebooks, &out.codes, b);
        assert_eq!(rec, out.z_tilde);
    }

    #[test]
    fn qerr_matches_z_tilde_distance() {
        let mut rng = Rng::new(1);
        let (b, d) = (5, 16);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(4, 1, 2), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let direct: f64 = z
            .iter()
            .zip(&out.z_tilde)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        assert!((out.sq_error - direct).abs() < 1e-4 * direct.max(1.0));
    }

    #[test]
    fn grouping_layout_matches_paper() {
        // z[j, s] = 10*j + s with dsub=1: group g must contain subvector
        // indices [g*q/R, (g+1)*q/R) of every example.
        let (b, d, q, r) = (2, 4, 4, 2);
        let z: Vec<f32> = (0..b)
            .flat_map(|j| (0..d).map(move |s| (10 * j + s) as f32))
            .collect();
        let pq = GroupedPq::new(PqConfig::new(q, r, 2), d).unwrap();
        let mut buf = Vec::new();
        pq.gather_group(&z, b, 0, &mut buf);
        assert_eq!(buf, vec![0.0, 1.0, 10.0, 11.0]);
        pq.gather_group(&z, b, 1, &mut buf);
        assert_eq!(buf, vec![2.0, 3.0, 12.0, 13.0]);
    }

    #[test]
    fn perfect_quantization_when_patterns_repeat() {
        // Subvectors drawn from exactly L patterns -> zero error.
        let mut rng = Rng::new(2);
        let patterns: Vec<Vec<f32>> = (0..2)
            .map(|_| (0..4).map(|_| rng.normal() as f32).collect())
            .collect();
        let (b, q) = (6, 8);
        let d = q * 4;
        let mut z = Vec::with_capacity(b * d);
        for _ in 0..b {
            for _ in 0..q {
                z.extend_from_slice(&patterns[rng.below(2)]);
            }
        }
        let pq = GroupedPq::new(PqConfig::new(q, 1, 2).with_iters(12), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        assert!(out.sq_error < 1e-6, "err {}", out.sq_error);
        assert!(out.relative_error(&z) < 1e-4);
    }

    #[test]
    fn q1_is_vanilla_kmeans_rows() {
        let mut rng = Rng::new(3);
        let (b, d) = (10, 6);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(1, 1, 3), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        // every reconstructed row must be one of the 3 codebook rows
        for j in 0..b {
            let row = &out.z_tilde[j * d..(j + 1) * d];
            let matched = (0..3).any(|l| {
                let c = &out.codebooks[l * d..(l + 1) * d];
                sq_dist(row, c) < 1e-12
            });
            assert!(matched, "row {j} not a centroid");
        }
    }

    #[test]
    fn more_clusters_lower_error() {
        let mut rng = Rng::new(4);
        let (b, d) = (20, 32);
        let z = randz(&mut rng, b, d);
        let mut last = f64::INFINITY;
        for l in [1usize, 2, 8, 32] {
            let pq = GroupedPq::new(PqConfig::new(8, 1, l).with_iters(15), d).unwrap();
            // fixed rng per run for fair comparison
            let mut r = Rng::new(99);
            let out = pq.quantize(&z, b, &mut r);
            assert!(
                out.sq_error <= last * 1.05,
                "L={l}: {} vs {}",
                out.sq_error,
                last
            );
            last = out.sq_error;
        }
    }

    #[test]
    fn kappa_bounds_mean_error() {
        let mut rng = Rng::new(5);
        let (b, d) = (8, 16);
        let z = randz(&mut rng, b, d);
        let pq = GroupedPq::new(PqConfig::new(4, 1, 2), d).unwrap();
        let out = pq.quantize(&z, b, &mut rng);
        let kappa = out.kappa(&z);
        let mean_sq = out.sq_error / b as f64;
        assert!(kappa * kappa + 1e-9 >= mean_sq);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(GroupedPq::new(PqConfig::new(5, 1, 2), 16).is_err()); // q !| d
        assert!(GroupedPq::new(PqConfig::new(4, 3, 2), 16).is_err()); // r !| q
        assert!(GroupedPq::new(PqConfig::new(4, 2, 2), 16).is_ok());
    }
}
