//! Synthetic federated datasets (substitutes for the TFF benchmarks).
//!
//! The paper trains on TFF FEMNIST / StackOverflow, which are not
//! available offline; these generators produce statistically analogous
//! workloads (see DESIGN.md §Substitutions): class/label structure that
//! makes within-batch activations cluster (what PQ exploits) and
//! per-client heterogeneity (Dirichlet label skew, client-specific style /
//! topic mixture / dialect).
//!
//! All sampling is deterministic in `(dataset seed, client id, step)`.

pub mod femnist;
pub mod partition;
pub mod so_nwp;
pub mod so_tag;

use crate::util::rng::Rng;

/// A typed dense array crossing the rust <-> PJRT boundary.
#[derive(Clone, Debug)]
pub enum Array {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Array {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Array {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Array::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Array {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Array::I32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Array::F32 { shape, .. } | Array::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Array::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Array::I32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

/// One training batch: model input + labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Array,
    pub y: Array,
}

/// A federated dataset: examples are reachable only through a client id.
pub trait FederatedDataset: Send + Sync {
    fn name(&self) -> &str;
    fn num_clients(&self) -> usize;
    /// Relative example count of a client (the p_i weights in eq. (1)).
    fn client_weight(&self, client: usize) -> f64;
    /// Draw a training batch from one client's local distribution.
    fn train_batch(&self, client: usize, batch: usize, rng: &mut Rng) -> Batch;
    /// Draw a held-out evaluation batch from the global mixture.
    fn eval_batch(&self, batch: usize, rng: &mut Rng) -> Batch;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn array_shape_checks() {
        let a = Array::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(a.numel(), 6);
        assert!(a.as_f32().is_some());
        assert!(a.as_i32().is_none());
    }

    #[test]
    #[should_panic]
    fn array_shape_mismatch_panics() {
        let _ = Array::i32(&[2, 2], vec![1, 2, 3]);
    }
}
