//! Non-IID partitioning utilities.
//!
//! Federated heterogeneity is modelled two ways, matching common FL
//! simulation practice (Hsu et al. 2019, used by the FedJAX baselines):
//!
//! * **label skew** — each client draws a Dirichlet(alpha) distribution
//!   over classes; small alpha concentrates mass on few classes;
//! * **quantity skew** — client dataset sizes follow a Zipf-like law, and
//!   the weights `p_i = n_i / sum n_j` of eq. (1) come from these sizes.

use crate::util::rng::Rng;

/// Per-client label distributions, `clients x classes`, rows sum to 1.
pub fn dirichlet_label_skew(
    clients: usize,
    classes: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<f64>> {
    (0..clients)
        .map(|i| rng.fork(i as u64).dirichlet_sym(alpha, classes))
        .collect()
}

/// Zipf-ish client sizes in `[min_size, ...]`; returns absolute counts.
pub fn zipf_client_sizes(
    clients: usize,
    mean_size: usize,
    skew: f64,
    min_size: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    // sample raw weights w_i = (rank+1)^-skew of a random permutation,
    // then scale to hit the requested mean
    let mut ranks: Vec<usize> = (0..clients).collect();
    rng.shuffle(&mut ranks);
    let raw: Vec<f64> = ranks
        .iter()
        .map(|&r| ((r + 1) as f64).powf(-skew))
        .collect();
    let total_raw: f64 = raw.iter().sum();
    let total_target = (mean_size * clients) as f64;
    raw.iter()
        .map(|w| ((w / total_raw * total_target).round() as usize).max(min_size))
        .collect()
}

/// Normalized p_i weights from sizes (eq. (1)).
pub fn weights_from_sizes(sizes: &[usize]) -> Vec<f64> {
    let total: usize = sizes.iter().sum();
    assert!(total > 0);
    sizes.iter().map(|&n| n as f64 / total as f64).collect()
}

/// Effective number of classes a distribution spreads over
/// (`exp(entropy)`), used by tests to verify skew levels.
pub fn effective_classes(dist: &[f64]) -> f64 {
    let h: f64 = dist
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.ln())
        .sum();
    h.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_distributions() {
        let mut rng = Rng::new(0);
        let skew = dirichlet_label_skew(50, 62, 0.3, &mut rng);
        assert_eq!(skew.len(), 50);
        for row in &skew {
            assert_eq!(row.len(), 62);
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn small_alpha_concentrates() {
        let mut rng = Rng::new(1);
        let skewed = dirichlet_label_skew(100, 62, 0.1, &mut rng);
        let uniformish = dirichlet_label_skew(100, 62, 100.0, &mut rng);
        let eff_s: f64 = skewed.iter().map(|r| effective_classes(r)).sum::<f64>() / 100.0;
        let eff_u: f64 =
            uniformish.iter().map(|r| effective_classes(r)).sum::<f64>() / 100.0;
        assert!(eff_s < 15.0, "skewed eff {eff_s}");
        assert!(eff_u > 50.0, "uniform eff {eff_u}");
    }

    #[test]
    fn sizes_positive_and_mean_close() {
        let mut rng = Rng::new(2);
        let sizes = zipf_client_sizes(200, 100, 1.2, 5, &mut rng);
        assert_eq!(sizes.len(), 200);
        assert!(sizes.iter().all(|&s| s >= 5));
        let mean = sizes.iter().sum::<usize>() as f64 / 200.0;
        assert!((mean - 100.0).abs() / 100.0 < 0.5, "mean {mean}");
        // genuinely skewed: max much larger than median
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert!(sorted[199] > 4 * sorted[100]);
    }

    #[test]
    fn weights_normalize() {
        let w = weights_from_sizes(&[10, 30, 60]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[2] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn partition_deterministic_per_seed() {
        let a = dirichlet_label_skew(10, 5, 0.5, &mut Rng::new(9));
        let b = dirichlet_label_skew(10, 5, 0.5, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
