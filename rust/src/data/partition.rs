//! Non-IID partitioning utilities.
//!
//! Federated heterogeneity is modelled two ways, matching common FL
//! simulation practice (Hsu et al. 2019, used by the FedJAX baselines):
//!
//! * **label skew** — each client draws a Dirichlet(alpha) distribution
//!   over classes; small alpha concentrates mass on few classes;
//! * **quantity skew** — client dataset sizes follow a Zipf-like law, and
//!   the weights `p_i = n_i / sum n_j` of eq. (1) come from these sizes.

use crate::util::rng::Rng;

/// Per-client label distributions, `clients x classes`, rows sum to 1.
pub fn dirichlet_label_skew(
    clients: usize,
    classes: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<f64>> {
    (0..clients)
        .map(|i| rng.fork(i as u64).dirichlet_sym(alpha, classes))
        .collect()
}

/// Zipf-ish client sizes in `[min_size, ...]`; returns absolute counts.
///
/// Known drift, kept deliberately: the trailing `.max(min_size)` clamp
/// adds mass to every below-floor client without removing it elsewhere,
/// so for skewed configs the realized mean sits *above* `mean_size`
/// (e.g. ~48% high at skew 1.2, min 5, mean 100 — pinned bit-for-bit by
/// `zipf_sizes_regression_pin` below). Every committed dataset seed and
/// golden fixture was blessed on these bits, so the dense path keeps
/// them; the streamed-population path ([`StreamedSizes`]) uses a
/// mean-honoring scheme instead.
pub fn zipf_client_sizes(
    clients: usize,
    mean_size: usize,
    skew: f64,
    min_size: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    // sample raw weights w_i = (rank+1)^-skew of a random permutation,
    // then scale to hit the requested mean
    let mut ranks: Vec<usize> = (0..clients).collect();
    rng.shuffle(&mut ranks);
    let raw: Vec<f64> = ranks
        .iter()
        .map(|&r| ((r + 1) as f64).powf(-skew))
        .collect();
    let total_raw: f64 = raw.iter().sum();
    let total_target = (mean_size * clients) as f64;
    raw.iter()
        .map(|w| ((w / total_raw * total_target).round() as usize).max(min_size))
        .collect()
}

/// O(1)-state quantity skew for streamed populations.
///
/// Instead of materializing a size vector (the [`zipf_client_sizes`]
/// path — O(population) memory and a *global* normalizer), each client's
/// size is a pure function of `(root, client_id)`: the client's fork
/// draws `u ∈ (0, 1]`, maps it through the inverse CDF of a Pareto tail
/// `W = u^(-1/skew)` truncated at `cap`, and scales by the *analytic*
/// expectation `E[min(W, cap)]` so the population mean converges to
/// `mean_size` without ever summing over clients. The surplus the dense
/// path's `.max(min_size)` clamp injects is redistributed here by
/// construction: sizes are `min_size + scaled excess`, so the floor is
/// part of the budget, not added on top — the mean contract holds (see
/// `streamed_sizes_honor_the_mean_contract`).
#[derive(Clone, Copy, Debug)]
pub struct StreamedSizes {
    mean_size: usize,
    min_size: usize,
    /// Pareto tail index (the quantity-skew knob; heavier tail as it
    /// approaches 1 from above).
    skew: f64,
    /// Truncation cap on the raw Pareto draw (keeps single-client sizes
    /// bounded; also what makes the expectation finite for skew <= 1).
    cap: f64,
    /// Precomputed `E[min(W, cap)] - 1` for `W ~ Pareto(skew)` — the
    /// normalizer for the excess-over-floor part of the draw.
    mean_excess: f64,
}

/// Stream-fork tag for per-client size draws (distinct from every
/// dataset-level tag so size streams never collide with batch streams).
const SIZE_FORK_TAG: u64 = 0x517E;

impl StreamedSizes {
    pub fn new(mean_size: usize, skew: f64, min_size: usize) -> StreamedSizes {
        assert!(mean_size > min_size, "mean {mean_size} must exceed floor {min_size}");
        assert!(skew > 1.0, "pareto tail needs skew > 1, got {skew}");
        let cap = 1e3;
        // E[min(W, cap)] for W ~ Pareto(alpha), W >= 1:
        //   ∫₁^cap w·α·w^-(α+1) dw + cap·P(W >= cap) = (α - cap^(1-α))/(α-1)
        let mean_trunc = (skew - cap.powf(1.0 - skew)) / (skew - 1.0);
        StreamedSizes { mean_size, min_size, skew, cap, mean_excess: mean_trunc - 1.0 }
    }

    /// Dataset size of `client`, derived on demand — O(1) time and state.
    /// Two-level fork (`root → size domain → client`) keeps the size
    /// streams disjoint from any other per-client fork domain a dataset
    /// hangs off the same root.
    pub fn size(&self, root: &Rng, client: u64) -> usize {
        // u ∈ (0, 1]: flip uniform()'s [0, 1) so the Pareto inverse CDF
        // never divides by zero
        let u = 1.0 - root.fork(SIZE_FORK_TAG).fork(client).uniform();
        let w = u.powf(-1.0 / self.skew).min(self.cap);
        let budget = (self.mean_size - self.min_size) as f64;
        self.min_size + (budget * (w - 1.0) / self.mean_excess).round() as usize
    }

    /// Eq. (1) sampling weight, normalized by the *expected* population
    /// total rather than the realized one (the realized total would be
    /// O(population) to compute; downstream aggregation renormalizes over
    /// survivors, so weights only need to be proportional to sizes).
    pub fn weight(&self, root: &Rng, client: u64, population: usize) -> f64 {
        self.size(root, client) as f64 / (self.mean_size * population) as f64
    }
}

/// Normalized p_i weights from sizes (eq. (1)).
pub fn weights_from_sizes(sizes: &[usize]) -> Vec<f64> {
    let total: usize = sizes.iter().sum();
    assert!(total > 0);
    sizes.iter().map(|&n| n as f64 / total as f64).collect()
}

/// Effective number of classes a distribution spreads over
/// (`exp(entropy)`), used by tests to verify skew levels.
pub fn effective_classes(dist: &[f64]) -> f64 {
    let h: f64 = dist
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.ln())
        .sum();
    h.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_distributions() {
        let mut rng = Rng::new(0);
        let skew = dirichlet_label_skew(50, 62, 0.3, &mut rng);
        assert_eq!(skew.len(), 50);
        for row in &skew {
            assert_eq!(row.len(), 62);
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn small_alpha_concentrates() {
        let mut rng = Rng::new(1);
        let skewed = dirichlet_label_skew(100, 62, 0.1, &mut rng);
        let uniformish = dirichlet_label_skew(100, 62, 100.0, &mut rng);
        let eff_s: f64 = skewed.iter().map(|r| effective_classes(r)).sum::<f64>() / 100.0;
        let eff_u: f64 =
            uniformish.iter().map(|r| effective_classes(r)).sum::<f64>() / 100.0;
        assert!(eff_s < 15.0, "skewed eff {eff_s}");
        assert!(eff_u > 50.0, "uniform eff {eff_u}");
    }

    #[test]
    fn sizes_positive_and_mean_close() {
        let mut rng = Rng::new(2);
        let sizes = zipf_client_sizes(200, 100, 1.2, 5, &mut rng);
        assert_eq!(sizes.len(), 200);
        assert!(sizes.iter().all(|&s| s >= 5));
        let mean = sizes.iter().sum::<usize>() as f64 / 200.0;
        assert!((mean - 100.0).abs() / 100.0 < 0.5, "mean {mean}");
        // genuinely skewed: max much larger than median
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert!(sorted[199] > 4 * sorted[100]);
    }

    #[test]
    fn zipf_sizes_regression_pin() {
        // Bit-for-bit pin of today's dense path, including the
        // mean-inflating `.max(min_size)` clamp: the expected vector is
        // an independent restatement of the blessed algorithm, so any
        // "fix" to the dense path (e.g. redistributing the clamp
        // surplus) fails here instead of silently re-rolling every
        // committed dataset. The fix lives in StreamedSizes only.
        let (clients, mean, skew, min) = (64usize, 100usize, 1.2f64, 5usize);
        let sizes = zipf_client_sizes(clients, mean, skew, min, &mut Rng::new(42));
        let mut ranks: Vec<usize> = (0..clients).collect();
        Rng::new(42).shuffle(&mut ranks);
        let raw: Vec<f64> = ranks.iter().map(|&r| ((r + 1) as f64).powf(-skew)).collect();
        let total_raw: f64 = raw.iter().sum();
        let total_target = (mean * clients) as f64;
        let expect: Vec<usize> = raw
            .iter()
            .map(|w| ((w / total_raw * total_target).round() as usize).max(min))
            .collect();
        assert_eq!(sizes, expect);

        // ...and the documented drift those bits carry: the clamp only
        // ever adds mass, so the realized mean exceeds the contract
        let realized = sizes.iter().sum::<usize>() as f64 / clients as f64;
        assert!(
            realized > mean as f64 * 1.05,
            "dense-path mean drift vanished ({realized} vs {mean}) — \
             if the clamp bug was fixed, rebless every dataset golden"
        );
    }

    #[test]
    fn streamed_sizes_honor_the_mean_contract() {
        // the surplus-redistribution fix: floor included in the budget,
        // analytic normalizer — realized mean ≈ mean_size even though no
        // population-wide total is ever computed
        let s = StreamedSizes::new(100, 1.2, 5);
        let root = Rng::new(11);
        let n = 1_000_000u64;
        let total: usize = (0..n).map(|i| s.size(&root, i)).sum();
        let realized = total as f64 / n as f64;
        // the estimator's std over 1M draws is ~0.5 examples (truncated
        // tail, cap 1e3), so a 3% band is ~6 sigma — deterministic seed,
        // but the margin survives any reseeding
        assert!(
            (realized - 100.0).abs() / 100.0 < 0.03,
            "streamed mean {realized} drifted from contract 100"
        );
    }

    #[test]
    fn streamed_sizes_floor_skew_and_determinism() {
        let s = StreamedSizes::new(100, 1.2, 5);
        let root = Rng::new(11);
        let sizes: Vec<usize> = (0..4096u64).map(|i| s.size(&root, i)).collect();
        assert!(sizes.iter().all(|&v| v >= 5), "floor violated");
        // pure function of (root, client): re-derivation is identical
        assert_eq!(sizes[777], s.size(&root, 777));
        assert_eq!(sizes[0], s.size(&Rng::new(11), 0));
        // genuinely heavy-tailed: max far above the median
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert!(sorted[4095] > 4 * sorted[2048], "tail too light: {:?}", &sorted[4090..]);
    }

    #[test]
    fn streamed_weights_proportional_to_sizes() {
        let s = StreamedSizes::new(100, 1.2, 5);
        let root = Rng::new(3);
        let pop = 1_000_000usize;
        let (a, b) = (123u64, 456_789u64);
        let ratio = s.weight(&root, a, pop) / s.weight(&root, b, pop);
        let size_ratio = s.size(&root, a) as f64 / s.size(&root, b) as f64;
        assert!((ratio - size_ratio).abs() < 1e-12);
        // expected-total normalizer: weights of a mean-sized client come
        // out near 1/population
        assert!(s.weight(&root, a, pop) > 0.0);
    }

    #[test]
    fn weights_normalize() {
        let w = weights_from_sizes(&[10, 30, 60]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[2] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn partition_deterministic_per_seed() {
        let a = dirichlet_label_skew(10, 5, 0.5, &mut Rng::new(9));
        let b = dirichlet_label_skew(10, 5, 0.5, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
