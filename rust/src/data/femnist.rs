//! Synthetic FEMNIST: procedural 28x28 glyphs, 62 classes, writer styles.
//!
//! Each class has a deterministic prototype glyph built from 3–6 strokes.
//! Each client ("writer") gets (a) a Dirichlet label distribution (label
//! skew) and (b) a persistent style — affine jitter (shift/rotate/scale),
//! stroke thickness, and ink intensity — so activations cluster by class
//! *and* shift by writer, the structure the paper's quantizer exploits.
//! Per-example noise is added on top.

use crate::data::{partition, Array, Batch, FederatedDataset};
use crate::util::rng::Rng;

pub const IMAGE: usize = 28;
pub const CLASSES: usize = 62;

/// One stroke of a glyph prototype: a line segment in unit coordinates.
#[derive(Clone, Copy, Debug)]
struct Stroke {
    x0: f32,
    y0: f32,
    x1: f32,
    y1: f32,
}

/// Persistent per-writer rendering style.
#[derive(Clone, Copy, Debug)]
struct WriterStyle {
    dx: f32,
    dy: f32,
    rot: f32,
    scale: f32,
    thickness: f32,
    intensity: f32,
}

/// How per-client state is realized.
///
/// `Dense` is the historical mode: styles, label distributions, and
/// sampling weights are materialized for the whole population at
/// construction (O(population) state; bits pinned by the golden
/// fixtures). `Streamed` is the million-client mode: every per-client
/// quantity is a pure function of `(root_seed, client_id)` — a two-level
/// RNG fork per domain — derived on demand, so the dataset holds only
/// O(classes) shared state no matter how many client ids exist.
enum Population {
    Dense {
        styles: Vec<WriterStyle>,
        label_dist: Vec<Vec<f64>>,
        weights: Vec<f64>,
    },
    Streamed {
        alpha: f64,
        sizes: partition::StreamedSizes,
    },
}

/// Fork domain for streamed per-client writer styles.
const STYLE_DOMAIN: u64 = 0x57E1;
/// Fork domain for streamed per-client label distributions.
const DIST_DOMAIN: u64 = 0xD157;

/// The synthetic federated FEMNIST generator.
pub struct SyntheticFemnist {
    seed: u64,
    clients: usize,
    root: Rng,
    glyphs: Vec<Vec<Stroke>>,
    population: Population,
}

impl SyntheticFemnist {
    /// `alpha` controls label skew (paper-style non-IID: ~0.3).
    pub fn new(seed: u64, clients: usize, alpha: f64) -> Self {
        let root = Rng::new(seed);
        let glyphs = Self::build_glyphs(&root);
        let styles = (0..clients)
            .map(|i| Self::style_from(&mut root.fork(2000 + i as u64)))
            .collect();
        let mut r = root.fork(3000);
        let label_dist = partition::dirichlet_label_skew(clients, CLASSES, alpha, &mut r);
        let mut rs = root.fork(4000);
        let sizes = partition::zipf_client_sizes(clients, 120, 1.1, 10, &mut rs);
        let weights = partition::weights_from_sizes(&sizes);
        SyntheticFemnist {
            seed,
            clients,
            root,
            glyphs,
            population: Population::Dense { styles, label_dist, weights },
        }
    }

    /// Streamed population: `clients` ids exist, none are resident.
    /// Construction is O(classes); every per-client shard (style, label
    /// distribution, dataset size/weight) is forked from
    /// `(root_seed, client_id)` when a round touches that client. Sizes
    /// use the mean-honoring [`partition::StreamedSizes`] scheme, not the
    /// dense path's clamped zipf (see `zipf_client_sizes`' doc).
    pub fn streamed(seed: u64, clients: usize, alpha: f64) -> Self {
        let root = Rng::new(seed);
        let glyphs = Self::build_glyphs(&root);
        SyntheticFemnist {
            seed,
            clients,
            root,
            glyphs,
            population: Population::Streamed {
                alpha,
                sizes: partition::StreamedSizes::new(120, 1.1, 10),
            },
        }
    }

    /// Class prototypes (shared by all writers in either mode).
    fn build_glyphs(root: &Rng) -> Vec<Vec<Stroke>> {
        (0..CLASSES)
            .map(|c| {
                let mut r = root.fork(1000 + c as u64);
                let strokes = 3 + r.below(4);
                (0..strokes)
                    .map(|_| Stroke {
                        x0: r.uniform_in(0.15, 0.85) as f32,
                        y0: r.uniform_in(0.15, 0.85) as f32,
                        x1: r.uniform_in(0.15, 0.85) as f32,
                        y1: r.uniform_in(0.15, 0.85) as f32,
                    })
                    .collect()
            })
            .collect()
    }

    /// Draw a writer style from `r` (the draw order is part of the dense
    /// mode's bit contract — both modes share it).
    fn style_from(r: &mut Rng) -> WriterStyle {
        WriterStyle {
            dx: r.uniform_in(-0.08, 0.08) as f32,
            dy: r.uniform_in(-0.08, 0.08) as f32,
            rot: r.uniform_in(-0.25, 0.25) as f32,
            scale: r.uniform_in(0.85, 1.15) as f32,
            thickness: r.uniform_in(0.035, 0.075) as f32,
            intensity: r.uniform_in(0.7, 1.0) as f32,
        }
    }

    /// Render one example of `class` with `style` + per-example jitter.
    fn render(&self, class: usize, style: &WriterStyle, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), IMAGE * IMAGE);
        out.iter_mut().for_each(|p| *p = 0.0);
        let jx = style.dx + rng.normal_ms(0.0, 0.02) as f32;
        let jy = style.dy + rng.normal_ms(0.0, 0.02) as f32;
        let rot = style.rot + rng.normal_ms(0.0, 0.05) as f32;
        let scale = style.scale * (1.0 + rng.normal_ms(0.0, 0.03) as f32);
        let (sin, cos) = rot.sin_cos();
        let th = style.thickness;
        let ink = style.intensity * (1.0 + rng.normal_ms(0.0, 0.05) as f32);

        for s in &self.glyphs[class] {
            // transform endpoints around the glyph center (0.5, 0.5)
            let tf = |x: f32, y: f32| -> (f32, f32) {
                let (cx, cy) = (x - 0.5, y - 0.5);
                let rx = cx * cos - cy * sin;
                let ry = cx * sin + cy * cos;
                (0.5 + scale * rx + jx, 0.5 + scale * ry + jy)
            };
            let (x0, y0) = tf(s.x0, s.y0);
            let (x1, y1) = tf(s.x1, s.y1);
            // rasterize: walk the segment, splat a gaussian blob
            let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
            let steps = ((len / 0.02).ceil() as usize).max(1);
            for k in 0..=steps {
                let t = k as f32 / steps as f32;
                let px = x0 + t * (x1 - x0);
                let py = y0 + t * (y1 - y0);
                splat(out, px, py, th, ink);
            }
        }
        // pixel noise
        for p in out.iter_mut() {
            *p = (*p + rng.normal_ms(0.0, 0.02) as f32).clamp(0.0, 1.0);
        }
    }

    fn batch_from_dist(
        &self,
        dist: &[f64],
        style: &WriterStyle,
        batch: usize,
        rng: &mut Rng,
    ) -> Batch {
        let mut x = vec![0.0f32; batch * IMAGE * IMAGE];
        let mut y = vec![0i32; batch];
        for j in 0..batch {
            let class = rng.categorical(dist);
            y[j] = class as i32;
            let px = &mut x[j * IMAGE * IMAGE..(j + 1) * IMAGE * IMAGE];
            self.render(class, style, rng, px);
        }
        Batch {
            x: Array::f32(&[batch, IMAGE, IMAGE, 1], x),
            y: Array::i32(&[batch], y),
        }
    }
}

fn splat(img: &mut [f32], px: f32, py: f32, radius: f32, ink: f32) {
    let r_pix = (radius * IMAGE as f32).max(0.6);
    let cx = px * IMAGE as f32;
    let cy = py * IMAGE as f32;
    let lo_x = ((cx - 2.0 * r_pix).floor().max(0.0)) as usize;
    let hi_x = ((cx + 2.0 * r_pix).ceil().min((IMAGE - 1) as f32)) as usize;
    let lo_y = ((cy - 2.0 * r_pix).floor().max(0.0)) as usize;
    let hi_y = ((cy + 2.0 * r_pix).ceil().min((IMAGE - 1) as f32)) as usize;
    if cx < -2.0 * r_pix || cy < -2.0 * r_pix {
        return;
    }
    for yy in lo_y..=hi_y {
        for xx in lo_x..=hi_x {
            let d2 = (xx as f32 - cx).powi(2) + (yy as f32 - cy).powi(2);
            let v = ink * (-d2 / (2.0 * r_pix * r_pix)).exp();
            let p = &mut img[yy * IMAGE + xx];
            *p = (*p + v).min(1.0);
        }
    }
}

impl FederatedDataset for SyntheticFemnist {
    fn name(&self) -> &str {
        "femnist"
    }

    fn num_clients(&self) -> usize {
        self.clients
    }

    fn client_weight(&self, client: usize) -> f64 {
        match &self.population {
            Population::Dense { weights, .. } => weights[client],
            Population::Streamed { sizes, .. } => {
                sizes.weight(&self.root, client as u64, self.clients)
            }
        }
    }

    fn train_batch(&self, client: usize, batch: usize, rng: &mut Rng) -> Batch {
        match &self.population {
            Population::Dense { styles, label_dist, .. } => {
                self.batch_from_dist(&label_dist[client], &styles[client], batch, rng)
            }
            Population::Streamed { alpha, .. } => {
                // the client's shard, forked on demand — O(1) state
                let style = Self::style_from(
                    &mut self.root.fork(STYLE_DOMAIN).fork(client as u64),
                );
                let dist = self
                    .root
                    .fork(DIST_DOMAIN)
                    .fork(client as u64)
                    .dirichlet_sym(*alpha, CLASSES);
                self.batch_from_dist(&dist, &style, batch, rng)
            }
        }
    }

    fn eval_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        // global mixture: uniform classes, neutral style
        let uniform = vec![1.0 / CLASSES as f64; CLASSES];
        let neutral = WriterStyle {
            dx: 0.0,
            dy: 0.0,
            rot: 0.0,
            scale: 1.0,
            thickness: 0.055,
            intensity: 0.85,
        };
        let mut r = rng.fork(self.seed ^ 0xEEA1);
        self.batch_from_dist(&uniform, &neutral, batch, &mut r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticFemnist {
        SyntheticFemnist::new(7, 20, 0.3)
    }

    #[test]
    fn batch_shapes_and_ranges() {
        let d = ds();
        let mut rng = Rng::new(0);
        let b = d.train_batch(3, 5, &mut rng);
        assert_eq!(b.x.shape(), &[5, 28, 28, 1]);
        assert_eq!(b.y.shape(), &[5]);
        let xs = b.x.as_f32().unwrap();
        assert!(xs.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let ys = b.y.as_i32().unwrap();
        assert!(ys.iter().all(|&c| (0..62).contains(&c)));
    }

    #[test]
    fn images_have_ink() {
        let d = ds();
        let mut rng = Rng::new(1);
        let b = d.train_batch(0, 4, &mut rng);
        let xs = b.x.as_f32().unwrap();
        for j in 0..4 {
            let img = &xs[j * 784..(j + 1) * 784];
            let mass: f32 = img.iter().sum();
            assert!(mass > 10.0, "image {j} nearly blank: {mass}");
            let maxv = img.iter().fold(0.0f32, |m, &v| m.max(v));
            assert!(maxv > 0.5);
        }
    }

    #[test]
    fn same_class_same_writer_similar_different_class_different() {
        let d = ds();
        let style = match &d.population {
            Population::Dense { styles, .. } => styles[0],
            Population::Streamed { .. } => unreachable!("ds() is dense"),
        };
        let mut render = |class: usize, seed: u64| {
            let mut r = Rng::new(seed);
            let mut img = vec![0.0f32; 784];
            d.render(class, &style, &mut r, &mut img);
            img
        };
        let a1 = render(5, 10);
        let a2 = render(5, 11);
        let b1 = render(40, 10);
        let d_same: f32 = a1.iter().zip(&a2).map(|(p, q)| (p - q).powi(2)).sum();
        let d_diff: f32 = a1.iter().zip(&b1).map(|(p, q)| (p - q).powi(2)).sum();
        assert!(
            d_same < d_diff,
            "within-class {d_same} should be < cross-class {d_diff}"
        );
    }

    #[test]
    fn label_skew_differs_across_clients() {
        let d = ds();
        let mut rng = Rng::new(2);
        let mut hist = |c: usize| {
            let mut h = vec![0usize; 62];
            for _ in 0..10 {
                let b = d.train_batch(c, 20, &mut rng);
                for &y in b.y.as_i32().unwrap() {
                    h[y as usize] += 1;
                }
            }
            h
        };
        let h0 = hist(0);
        let h1 = hist(1);
        // non-IID: top class of client 0 differs from client 1 (w.h.p.)
        let top0 = h0.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        let top1 = h1.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        let conc0 = *h0.iter().max().unwrap() as f64 / 200.0;
        assert!(conc0 > 0.1, "client 0 not skewed: {conc0}");
        assert!(top0 != top1 || conc0 < 0.9);
    }

    #[test]
    fn weights_sum_to_one() {
        let d = ds();
        let s: f64 = (0..d.num_clients()).map(|i| d.client_weight(i)).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed_and_stream() {
        let d1 = ds();
        let d2 = ds();
        let b1 = d1.train_batch(4, 3, &mut Rng::new(42));
        let b2 = d2.train_batch(4, 3, &mut Rng::new(42));
        assert_eq!(b1.x.as_f32().unwrap(), b2.x.as_f32().unwrap());
        assert_eq!(b1.y.as_i32().unwrap(), b2.y.as_i32().unwrap());
    }

    #[test]
    fn streamed_million_client_construction_is_o_classes() {
        // constructing a 1M-client population must not materialize any
        // per-client vector — this finishing at all (instantly, with tiny
        // memory) is the point; the batch below proves a tail client is
        // reachable without touching the other 999_999
        let d = SyntheticFemnist::streamed(7, 1_000_000, 0.3);
        assert_eq!(d.num_clients(), 1_000_000);
        let b = d.train_batch(999_999, 2, &mut Rng::new(0));
        assert_eq!(b.x.shape(), &[2, 28, 28, 1]);
        assert!(d.client_weight(999_999) > 0.0);
    }

    #[test]
    fn streamed_shards_are_pure_functions_of_seed_and_id() {
        let d1 = SyntheticFemnist::streamed(7, 1 << 20, 0.3);
        let d2 = SyntheticFemnist::streamed(7, 1 << 20, 0.3);
        let b1 = d1.train_batch(123_456, 3, &mut Rng::new(42));
        let b2 = d2.train_batch(123_456, 3, &mut Rng::new(42));
        assert_eq!(b1.x.as_f32().unwrap(), b2.x.as_f32().unwrap());
        assert_eq!(b1.y.as_i32().unwrap(), b2.y.as_i32().unwrap());
        assert_eq!(d1.client_weight(55_555), d2.client_weight(55_555));
        // ... and distinct across clients: styles are continuous draws, so
        // two different shards can't render identical pixels
        let b3 = d1.train_batch(123_457, 3, &mut Rng::new(42));
        assert_ne!(b1.x.as_f32().unwrap(), b3.x.as_f32().unwrap());
    }

    #[test]
    fn streamed_clients_are_heterogeneous() {
        // label skew survives the streamed derivation: two clients' label
        // histograms should concentrate differently
        let d = SyntheticFemnist::streamed(3, 1 << 18, 0.1);
        let mut rng = Rng::new(5);
        let mut hist = |c: usize| {
            let mut h = vec![0usize; CLASSES];
            for _ in 0..5 {
                let b = d.train_batch(c, 20, &mut rng);
                for &y in b.y.as_i32().unwrap() {
                    h[y as usize] += 1;
                }
            }
            h
        };
        let h0 = hist(1000);
        let h1 = hist(200_000);
        let top0 = h0.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        let top1 = h1.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        let conc0 = *h0.iter().max().unwrap() as f64 / 100.0;
        assert!(conc0 > 0.1, "client not skewed: {conc0}");
        assert!(top0 != top1 || conc0 < 0.9);
    }
}
