//! Synthetic StackOverflow tag prediction: topic-model bag-of-words.
//!
//! Generative story: K latent topics; each topic owns a Zipf-weighted word
//! distribution over the vocabulary and a handful of characteristic tags.
//! A client has a persistent Dirichlet topic mixture (heterogeneity); each
//! example draws a topic sub-mixture, emits ~`words_per_post` word tokens
//! (bag-of-words, L1-normalized), and labels the example with the top tags
//! of its dominant topics. This preserves the multi-label sparse-input
//! regime and the Recall@5 metric of the paper.

use crate::data::{partition, Array, Batch, FederatedDataset};
use crate::util::rng::Rng;

/// Generator configuration (defaults mirror the task presets).
#[derive(Clone, Copy, Debug)]
pub struct SoTagConfig {
    pub vocab: usize,
    pub tags: usize,
    pub topics: usize,
    pub words_per_post: usize,
    pub tags_per_post: usize,
    /// Dirichlet alpha for client topic mixtures (small = heterogeneous).
    pub alpha: f64,
}

impl SoTagConfig {
    pub fn paper() -> Self {
        SoTagConfig { vocab: 5000, tags: 1000, topics: 50, words_per_post: 60,
                      tags_per_post: 3, alpha: 0.3 }
    }

    pub fn small() -> Self {
        SoTagConfig { vocab: 1000, tags: 200, topics: 20, words_per_post: 40,
                      tags_per_post: 3, alpha: 0.3 }
    }
}

/// Per-topic structure: word CDF support and tag ids.
struct Topic {
    /// Word ids this topic prefers (sampled with Zipf rank weights).
    words: Vec<usize>,
    /// Tags characteristic of this topic, in preference order.
    tags: Vec<usize>,
}

/// Dense (materialized) vs streamed (forked-on-demand) per-client state;
/// see `femnist::Population` for the model.
enum Population {
    Dense { client_mixture: Vec<Vec<f64>>, weights: Vec<f64> },
    Streamed { sizes: partition::StreamedSizes },
}

/// Fork domain for streamed per-client topic mixtures.
const MIXTURE_DOMAIN: u64 = 0xD157;

pub struct SyntheticSoTag {
    cfg: SoTagConfig,
    clients: usize,
    root: Rng,
    topics: Vec<Topic>,
    population: Population,
}

impl SyntheticSoTag {
    pub fn new(seed: u64, clients: usize, cfg: SoTagConfig) -> Self {
        let root = Rng::new(seed);
        let topics = Self::build_topics(&root, &cfg);
        let mut r = root.fork(7);
        let client_mixture =
            partition::dirichlet_label_skew(clients, cfg.topics, cfg.alpha, &mut r);
        let mut rs = root.fork(8);
        let sizes = partition::zipf_client_sizes(clients, 200, 1.2, 10, &mut rs);
        let weights = partition::weights_from_sizes(&sizes);
        SyntheticSoTag {
            cfg,
            clients,
            root,
            topics,
            population: Population::Dense { client_mixture, weights },
        }
    }

    /// Streamed population: O(topics) resident state regardless of
    /// `clients`; per-client mixtures and sizes fork from
    /// `(root_seed, client_id)` on demand.
    pub fn streamed(seed: u64, clients: usize, cfg: SoTagConfig) -> Self {
        let root = Rng::new(seed);
        let topics = Self::build_topics(&root, &cfg);
        SyntheticSoTag {
            cfg,
            clients,
            root,
            topics,
            population: Population::Streamed {
                sizes: partition::StreamedSizes::new(200, 1.2, 10),
            },
        }
    }

    /// Latent topics (shared global state in either mode).
    fn build_topics(root: &Rng, cfg: &SoTagConfig) -> Vec<Topic> {
        (0..cfg.topics)
            .map(|t| {
                let mut r = root.fork(100 + t as u64);
                // each topic uses a contiguous-ish slice of the vocab plus
                // random extras, so topics overlap but remain distinct
                let span = cfg.vocab / cfg.topics;
                let base = t * span;
                let mut words: Vec<usize> = (base..base + span).collect();
                for _ in 0..span / 2 {
                    words.push(r.below(cfg.vocab));
                }
                let tag_span = (cfg.tags / cfg.topics).max(1);
                let tags: Vec<usize> = (0..tag_span.max(3))
                    .map(|k| (t * tag_span + k) % cfg.tags)
                    .collect();
                Topic { words, tags }
            })
            .collect()
    }

    fn sample_post(&self, mixture: &[f64], rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let mut x = vec![0.0f32; cfg.vocab];
        let mut topic_hits = vec![0usize; cfg.topics];
        for _ in 0..cfg.words_per_post {
            let t = rng.categorical(mixture);
            topic_hits[t] += 1;
            let topic = &self.topics[t];
            // Zipf rank within the topic's word list
            let rank = rng.zipf(topic.words.len(), 1.1);
            x[topic.words[rank]] += 1.0;
        }
        // L1 normalize the bag (standard for LR-on-BoW baselines)
        let total: f32 = x.iter().sum();
        if total > 0.0 {
            x.iter_mut().for_each(|v| *v /= total);
        }
        // tags: top characteristic tags of the most-hit topics
        let mut y = vec![0.0f32; cfg.tags];
        let mut order: Vec<usize> = (0..cfg.topics).collect();
        order.sort_by(|&a, &b| topic_hits[b].cmp(&topic_hits[a]));
        let mut placed = 0;
        'outer: for &t in &order {
            if topic_hits[t] == 0 {
                break;
            }
            for &tag in &self.topics[t].tags {
                if y[tag] == 0.0 {
                    y[tag] = 1.0;
                    placed += 1;
                    if placed >= cfg.tags_per_post {
                        break 'outer;
                    }
                    break; // one tag per topic, move to next topic
                }
            }
        }
        if placed == 0 {
            y[rng.below(cfg.tags)] = 1.0;
        }
        (x, y)
    }

    fn batch_from_mixture(&self, mixture: &[f64], batch: usize, rng: &mut Rng) -> Batch {
        let cfg = &self.cfg;
        let mut xs = Vec::with_capacity(batch * cfg.vocab);
        let mut ys = Vec::with_capacity(batch * cfg.tags);
        for _ in 0..batch {
            let (x, y) = self.sample_post(mixture, rng);
            xs.extend(x);
            ys.extend(y);
        }
        Batch {
            x: Array::f32(&[batch, cfg.vocab], xs),
            y: Array::f32(&[batch, cfg.tags], ys),
        }
    }
}

impl FederatedDataset for SyntheticSoTag {
    fn name(&self) -> &str {
        "so_tag"
    }

    fn num_clients(&self) -> usize {
        self.clients
    }

    fn client_weight(&self, client: usize) -> f64 {
        match &self.population {
            Population::Dense { weights, .. } => weights[client],
            Population::Streamed { sizes } => {
                sizes.weight(&self.root, client as u64, self.clients)
            }
        }
    }

    fn train_batch(&self, client: usize, batch: usize, rng: &mut Rng) -> Batch {
        match &self.population {
            Population::Dense { client_mixture, .. } => {
                self.batch_from_mixture(&client_mixture[client], batch, rng)
            }
            Population::Streamed { .. } => {
                let mixture = self
                    .root
                    .fork(MIXTURE_DOMAIN)
                    .fork(client as u64)
                    .dirichlet_sym(self.cfg.alpha, self.cfg.topics);
                self.batch_from_mixture(&mixture, batch, rng)
            }
        }
    }

    fn eval_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let uniform = vec![1.0 / self.cfg.topics as f64; self.cfg.topics];
        self.batch_from_mixture(&uniform, batch, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticSoTag {
        SyntheticSoTag::new(11, 30, SoTagConfig::small())
    }

    #[test]
    fn shapes_and_normalization() {
        let d = ds();
        let mut rng = Rng::new(0);
        let b = d.train_batch(2, 8, &mut rng);
        assert_eq!(b.x.shape(), &[8, 1000]);
        assert_eq!(b.y.shape(), &[8, 200]);
        let xs = b.x.as_f32().unwrap();
        for j in 0..8 {
            let row = &xs[j * 1000..(j + 1) * 1000];
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {j} sums to {s}");
            assert!(row.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn labels_multi_hot_and_bounded() {
        let d = ds();
        let mut rng = Rng::new(1);
        let b = d.train_batch(0, 16, &mut rng);
        let ys = b.y.as_f32().unwrap();
        for j in 0..16 {
            let row = &ys[j * 200..(j + 1) * 200];
            let pos: f32 = row.iter().sum();
            assert!((1.0..=3.0).contains(&pos), "example {j} has {pos} tags");
            assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn tags_correlate_with_words() {
        // posts about the same dominant topic should share tags more often
        // than posts about different topics
        let d = ds();
        let mut rng = Rng::new(2);
        let mut one_hot_mix = vec![1e-9; 20];
        one_hot_mix[3] = 1.0;
        let b1 = d.batch_from_mixture(&one_hot_mix, 10, &mut rng);
        let ys = b1.y.as_f32().unwrap();
        // all examples from topic 3 share at least one common tag
        let mut common: Vec<f32> = ys[0..200].to_vec();
        for j in 1..10 {
            for (c, v) in common.iter_mut().zip(&ys[j * 200..(j + 1) * 200]) {
                *c = c.min(*v);
            }
        }
        assert!(common.iter().sum::<f32>() >= 1.0, "no shared tag");
    }

    #[test]
    fn clients_have_distinct_mixtures() {
        let d = ds();
        let (m0, m1) = match &d.population {
            Population::Dense { client_mixture, .. } => {
                (client_mixture[0].clone(), client_mixture[1].clone())
            }
            Population::Streamed { .. } => unreachable!("ds() is dense"),
        };
        let dist: f64 = m0.iter().zip(&m1).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 0.5, "mixtures too similar: {dist}");
    }

    #[test]
    fn deterministic() {
        let b1 = ds().train_batch(5, 4, &mut Rng::new(3));
        let b2 = ds().train_batch(5, 4, &mut Rng::new(3));
        assert_eq!(b1.x.as_f32().unwrap(), b2.x.as_f32().unwrap());
    }

    #[test]
    fn streamed_population_is_lazy_and_deterministic() {
        let d = SyntheticSoTag::streamed(11, 2_000_000, SoTagConfig::small());
        assert_eq!(d.num_clients(), 2_000_000);
        let b1 = d.train_batch(1_999_999, 4, &mut Rng::new(3));
        let b2 = d.train_batch(1_999_999, 4, &mut Rng::new(3));
        assert_eq!(b1.x.as_f32().unwrap(), b2.x.as_f32().unwrap());
        assert!(d.client_weight(1_999_999) > 0.0);
    }
}
