//! Synthetic StackOverflow next-word prediction: client-dialect Markov text.
//!
//! Generative story: a global first-order Markov chain over a Zipf-ranked
//! vocabulary (each token's successors are a deterministic pseudo-random
//! subset with Zipf weights), plus a per-client "dialect" — a client-
//! specific permutation bias that re-weights successor choices. Sequences
//! have variable length (padded with id 0); ids 1/2/3 are BOS/EOS/OOV like
//! the TFF preprocessing.

use crate::data::{partition, Array, Batch, FederatedDataset};
use crate::util::rng::Rng;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct SoNwpConfig {
    /// Total vocabulary including the 4 special ids.
    pub vocab: usize,
    pub seq: usize,
    /// Successors per token in the global chain.
    pub branch: usize,
    /// Strength of the client dialect (0 = IID clients).
    pub dialect: f64,
}

impl SoNwpConfig {
    pub fn paper() -> Self {
        SoNwpConfig { vocab: 10004, seq: 30, branch: 32, dialect: 0.5 }
    }

    pub fn small() -> Self {
        SoNwpConfig { vocab: 2004, seq: 20, branch: 16, dialect: 0.5 }
    }
}

/// Dense (materialized) vs streamed (forked-on-demand) per-client state;
/// see `femnist::Population` for the model.
///
/// The dense dialect table is the one per-client quantity in this crate
/// that was *not* independently forkable — it is a single sequential
/// stream (`fork(1)` drawn `clients` times), so client `i`'s dialect
/// depends on position, not identity. The streamed mode derives each
/// dialect from `(root_seed, client_id)` instead.
enum Population {
    Dense {
        /// Per-client dialect offsets into the successor table.
        dialect_shift: Vec<usize>,
        weights: Vec<f64>,
    },
    Streamed {
        sizes: partition::StreamedSizes,
    },
}

/// Fork domain for streamed per-client dialect draws.
const DIALECT_DOMAIN: u64 = 0xD1A1;

pub struct SyntheticSoNwp {
    cfg: SoNwpConfig,
    clients: usize,
    seed: u64,
    root: Rng,
    population: Population,
}

impl SyntheticSoNwp {
    pub fn new(seed: u64, clients: usize, cfg: SoNwpConfig) -> Self {
        let root = Rng::new(seed);
        let mut r = root.fork(1);
        let dialect_shift = (0..clients).map(|_| r.below(cfg.branch)).collect();
        let mut rs = root.fork(2);
        let sizes = partition::zipf_client_sizes(clients, 300, 1.2, 20, &mut rs);
        let weights = partition::weights_from_sizes(&sizes);
        SyntheticSoNwp {
            cfg,
            clients,
            seed,
            root,
            population: Population::Dense { dialect_shift, weights },
        }
    }

    /// Streamed population: O(1) resident per-client state; dialects and
    /// sizes are pure functions of `(root_seed, client_id)`.
    pub fn streamed(seed: u64, clients: usize, cfg: SoNwpConfig) -> Self {
        let root = Rng::new(seed);
        SyntheticSoNwp {
            cfg,
            clients,
            seed,
            root,
            population: Population::Streamed {
                sizes: partition::StreamedSizes::new(300, 1.2, 20),
            },
        }
    }

    /// k-th successor of `token` in the global chain (deterministic hash).
    #[inline]
    fn successor(&self, token: usize, k: usize) -> usize {
        let words = self.cfg.vocab - 4;
        let mut h = (token as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((k as u64).wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(self.seed);
        h ^= h >> 29;
        h = h.wrapping_mul(0x94D049BB133111EB);
        h ^= h >> 32;
        4 + (h as usize % words)
    }

    /// Sample the next token given the current one and a dialect shift.
    fn step(&self, token: usize, shift: usize, rng: &mut Rng) -> usize {
        // successor rank chosen Zipf-ily; the dialect rotates which
        // successor a given rank points at, so clients share the support
        // but prefer different continuations.
        let rank = rng.zipf(self.cfg.branch, 1.3);
        let k = if rng.uniform() < self.cfg.dialect {
            (rank + shift) % self.cfg.branch
        } else {
            rank
        };
        self.successor(token, k)
    }

    fn gen_sequence(&self, shift: usize, rng: &mut Rng, x: &mut [i32], y: &mut [i32]) {
        let t = self.cfg.seq;
        let words = self.cfg.vocab - 4;
        // variable length in [seq/2, seq]
        let len = t / 2 + rng.below(t / 2 + 1);
        let mut cur = 4 + rng.zipf(words, 1.1); // start token by unigram law
        x[0] = BOS;
        y[0] = cur as i32;
        for i in 1..t {
            if i < len {
                let nxt = self.step(cur, shift, rng);
                x[i] = cur as i32;
                y[i] = if i == len - 1 { EOS } else { nxt as i32 };
                cur = nxt;
            } else {
                x[i] = PAD;
                y[i] = PAD;
            }
        }
    }

    fn batch_with_shift(&self, shift: usize, batch: usize, rng: &mut Rng) -> Batch {
        let t = self.cfg.seq;
        let mut xs = vec![0i32; batch * t];
        let mut ys = vec![0i32; batch * t];
        for j in 0..batch {
            self.gen_sequence(
                shift,
                rng,
                &mut xs[j * t..(j + 1) * t],
                &mut ys[j * t..(j + 1) * t],
            );
        }
        Batch {
            x: Array::i32(&[batch, t], xs),
            y: Array::i32(&[batch, t], ys),
        }
    }
}

impl FederatedDataset for SyntheticSoNwp {
    fn name(&self) -> &str {
        "so_nwp"
    }

    fn num_clients(&self) -> usize {
        self.clients
    }

    fn client_weight(&self, client: usize) -> f64 {
        match &self.population {
            Population::Dense { weights, .. } => weights[client],
            Population::Streamed { sizes } => {
                sizes.weight(&self.root, client as u64, self.clients)
            }
        }
    }

    fn train_batch(&self, client: usize, batch: usize, rng: &mut Rng) -> Batch {
        let shift = match &self.population {
            Population::Dense { dialect_shift, .. } => dialect_shift[client],
            Population::Streamed { .. } => self
                .root
                .fork(DIALECT_DOMAIN)
                .fork(client as u64)
                .below(self.cfg.branch),
        };
        self.batch_with_shift(shift, batch, rng)
    }

    fn eval_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        self.batch_with_shift(0, batch, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SyntheticSoNwp {
        SyntheticSoNwp::new(5, 25, SoNwpConfig::small())
    }

    #[test]
    fn shapes_and_token_ranges() {
        let d = ds();
        let mut rng = Rng::new(0);
        let b = d.train_batch(1, 6, &mut rng);
        assert_eq!(b.x.shape(), &[6, 20]);
        assert_eq!(b.y.shape(), &[6, 20]);
        for &tok in b.x.as_i32().unwrap() {
            assert!((0..2004).contains(&tok));
        }
    }

    #[test]
    fn starts_with_bos_pads_align() {
        let d = ds();
        let mut rng = Rng::new(1);
        let b = d.train_batch(0, 10, &mut rng);
        let xs = b.x.as_i32().unwrap();
        let ys = b.y.as_i32().unwrap();
        for j in 0..10 {
            let xr = &xs[j * 20..(j + 1) * 20];
            let yr = &ys[j * 20..(j + 1) * 20];
            assert_eq!(xr[0], BOS);
            for i in 0..20 {
                assert_eq!(xr[i] == PAD, yr[i] == PAD, "pad misalign at {i}");
            }
            // non-pad prefix then pad suffix (no pad holes)
            let first_pad = xr.iter().position(|&t| t == PAD).unwrap_or(20);
            assert!(xr[..first_pad].iter().all(|&t| t != PAD));
            assert!(xr[first_pad..].iter().all(|&t| t == PAD));
            assert!(first_pad >= 10, "sequence too short: {first_pad}");
        }
    }

    #[test]
    fn y_is_next_token_of_x() {
        let d = ds();
        let mut rng = Rng::new(2);
        let b = d.train_batch(3, 8, &mut rng);
        let xs = b.x.as_i32().unwrap();
        let ys = b.y.as_i32().unwrap();
        for j in 0..8 {
            let xr = &xs[j * 20..(j + 1) * 20];
            let yr = &ys[j * 20..(j + 1) * 20];
            for i in 1..19 {
                if xr[i + 1] != PAD {
                    assert_eq!(yr[i], xr[i + 1], "teacher forcing broken at {i}");
                }
            }
        }
    }

    #[test]
    fn chain_is_learnable_not_uniform() {
        // successors of a fixed token concentrate on `branch` ids
        let d = ds();
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            seen.insert(d.step(100, 0, &mut rng));
        }
        assert!(seen.len() <= 16, "support {} > branch", seen.len());
        assert!(seen.len() >= 4);
    }

    #[test]
    fn dialects_shift_distributions() {
        let d = ds();
        let mut count = |shift: usize| {
            let mut rng = Rng::new(4);
            let mut hist = std::collections::HashMap::new();
            for _ in 0..400 {
                *hist.entry(d.step(50, shift, &mut rng)).or_insert(0usize) += 1;
            }
            hist
        };
        let h0 = count(0);
        let h5 = count(5);
        let top0 = h0.iter().max_by_key(|(_, &v)| v).unwrap().0;
        let v0 = h0[top0];
        let v5 = h5.get(top0).copied().unwrap_or(0);
        assert!(v0 > v5, "dialect shift has no effect: {v0} vs {v5}");
    }

    #[test]
    fn deterministic() {
        let b1 = ds().train_batch(2, 3, &mut Rng::new(9));
        let b2 = ds().train_batch(2, 3, &mut Rng::new(9));
        assert_eq!(b1.x.as_i32().unwrap(), b2.x.as_i32().unwrap());
    }

    #[test]
    fn streamed_dialects_are_identity_keyed_not_positional() {
        // in streamed mode a client's dialect is a pure function of its
        // id: the same id yields the same batch across instances, and the
        // population size doesn't perturb it (the dense mode's sequential
        // stream can't offer either property)
        let small = SyntheticSoNwp::streamed(5, 1 << 18, SoNwpConfig::small());
        let large = SyntheticSoNwp::streamed(5, 1 << 21, SoNwpConfig::small());
        let b1 = small.train_batch(99_999, 3, &mut Rng::new(9));
        let b2 = large.train_batch(99_999, 3, &mut Rng::new(9));
        assert_eq!(b1.x.as_i32().unwrap(), b2.x.as_i32().unwrap());
        assert_eq!(b1.y.as_i32().unwrap(), b2.y.as_i32().unwrap());
        assert_eq!(large.num_clients(), 1 << 21);
        assert!(large.client_weight(2_000_000) > 0.0);
    }
}
