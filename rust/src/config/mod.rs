//! Typed run configuration with per-task presets and JSON round-trip.
//!
//! A [`RunConfig`] fully determines a training run: task variant, federated
//! population, algorithm (`fedlite` / `splitfed` / `fedavg`), quantizer
//! settings, optimizers, and logging. Presets encode the paper's §C.2
//! hyper-parameters; CLI flags override individual fields.

use crate::quantizer::pq::PqConfig;
use crate::util::json::{Object, Value};

/// Which training algorithm the coordinator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Quantized split learning with gradient correction (the paper).
    FedLite,
    /// Split learning with raw activation upload (baseline, §3).
    SplitFed,
    /// Whole-model federated averaging (baseline).
    FedAvg,
}

impl Algorithm {
    pub fn parse(s: &str) -> anyhow::Result<Algorithm> {
        Ok(match s {
            "fedlite" => Algorithm::FedLite,
            "splitfed" => Algorithm::SplitFed,
            "fedavg" => Algorithm::FedAvg,
            other => anyhow::bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedLite => "fedlite",
            Algorithm::SplitFed => "splitfed",
            Algorithm::FedAvg => "fedavg",
        }
    }
}

/// Which quantizer implementation runs on the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantizerEngine {
    /// The rust engine (any (q, R, L); used for sweeps).
    Native,
    /// The AOT Pallas artifact (must exist in the manifest).
    Pjrt,
}

/// Dishonest-client attack model. The *schedule* (which sampled client
/// misbehaves, per `(round, attempt, client)` RNG fork) is drawn by
/// [`crate::coordinator::faults`]; the kind selects what a flagged client
/// does. See the README "Untrusted clients" threat-model table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ByzantineKind {
    /// Scale the uploaded update by a large factor (gradient boosting).
    GradScale,
    /// Negate the uploaded update (model-poisoning sign flip).
    SignFlip,
    /// Train on rotated (poisoned) labels.
    LabelFlip,
    /// Corrupt the packed PQ codeword stream (FedLite uploads only; the
    /// coordinator's codeword validation rejects it).
    CorruptCodeword,
    /// Replay the previously synced state: a zero update at full weight
    /// (free-riding / stale-upload replay).
    Replay,
}

impl ByzantineKind {
    pub const ALL: [ByzantineKind; 5] = [
        ByzantineKind::GradScale,
        ByzantineKind::SignFlip,
        ByzantineKind::LabelFlip,
        ByzantineKind::CorruptCodeword,
        ByzantineKind::Replay,
    ];

    pub fn parse(s: &str) -> anyhow::Result<ByzantineKind> {
        Ok(match s {
            "grad_scale" => ByzantineKind::GradScale,
            "sign_flip" => ByzantineKind::SignFlip,
            "label_flip" => ByzantineKind::LabelFlip,
            "corrupt_codeword" => ByzantineKind::CorruptCodeword,
            "replay" => ByzantineKind::Replay,
            other => anyhow::bail!(
                "unknown byzantine kind '{other}' (try grad_scale, sign_flip, \
                 label_flip, corrupt_codeword, or replay)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ByzantineKind::GradScale => "grad_scale",
            ByzantineKind::SignFlip => "sign_flip",
            ByzantineKind::LabelFlip => "label_flip",
            ByzantineKind::CorruptCodeword => "corrupt_codeword",
            ByzantineKind::Replay => "replay",
        }
    }
}

/// How survivor updates fold into the round aggregate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationRule {
    /// Survivor-weighted mean — the paper's aggregation, and the rule
    /// every golden fixture pins byte-for-byte.
    Mean,
    /// Coordinate-wise trimmed mean over survivor updates (unweighted;
    /// robust to a bounded fraction of outliers).
    Trimmed,
    /// Coordinate-wise median over survivor updates (unweighted).
    Median,
}

impl AggregationRule {
    pub fn parse(s: &str) -> anyhow::Result<AggregationRule> {
        Ok(match s {
            "mean" => AggregationRule::Mean,
            "trimmed" => AggregationRule::Trimmed,
            "median" => AggregationRule::Median,
            other => anyhow::bail!(
                "unknown aggregation rule '{other}' (try mean, trimmed, or median)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregationRule::Mean => "mean",
            AggregationRule::Trimmed => "trimmed",
            AggregationRule::Median => "median",
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub task: String,
    pub preset: String,
    pub algorithm: Algorithm,
    /// Federated population size M.
    pub num_clients: usize,
    /// Clients sampled per round S.
    pub clients_per_round: usize,
    pub rounds: usize,
    /// FedAvg local steps H (ignored by split algorithms).
    pub local_steps: usize,
    /// Dirichlet alpha for label/topic skew.
    pub alpha: f64,
    /// PQ settings (FedLite only).
    pub pq: PqConfig,
    /// Gradient-correction strength λ (eq. (5)).
    pub lambda: f32,
    pub quantizer: QuantizerEngine,
    /// Optimizer names + learning rates (client side aggregated model,
    /// server side model). Paper uses one lr for both.
    pub optimizer: String,
    pub client_lr: f32,
    pub server_lr: f32,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
    /// Where artifacts live.
    pub artifacts_dir: String,
    /// Where per-round logs/CSVs go (empty = no files).
    pub out_dir: String,
    /// Dropout keep handled via masks; probability by task (femnist only).
    pub dropout_client: f64,
    pub dropout_server: f64,
    /// Per-client, per-round probability of mid-round client failure
    /// (fault injection; see `coordinator::faults`). 0 = clean runs.
    pub drop_prob: f64,
    /// Fraction of clients that straggle each round (simulated compute
    /// delay). 0 = nobody straggles.
    pub straggler_frac: f64,
    /// Simulated per-round deadline in seconds; stragglers past it are
    /// evicted from the aggregate. 0 = no deadline.
    pub round_deadline: f64,
    /// Abort + resample the round when fewer clients survive (bounded by
    /// `coordinator::engine::MAX_SAMPLING_ATTEMPTS`). 0 = never abort.
    pub min_survivors: usize,
    /// Per-client, per-round probability of acting byzantine (attack
    /// schedules are `(round, attempt, client)` RNG forks; see
    /// `coordinator::faults`). 0 = everyone honest, bit-identical to an
    /// engine without the byzantine layer.
    pub byzantine_frac: f64,
    /// Which attack flagged byzantine clients mount.
    pub byzantine_kind: ByzantineKind,
    /// L2-norm cap applied to each survivor update before aggregation
    /// (defense against scaled gradients). 0 = no clipping.
    pub clip_norm: f64,
    /// Survivor aggregation rule (`mean` reproduces the historical bits;
    /// `trimmed`/`median` are the robust defenses).
    pub aggregation: AggregationRule,
    /// Worker threads for the per-round cohort fan-out (0 = auto:
    /// [`crate::util::pool::ThreadPool::default_size`]). `1` recovers the
    /// serial round loop; results are bit-identical at any value.
    pub workers: usize,
    /// Independent cohort shards per round (`--shards`, >= 1). Each shard
    /// draws its own fault plans and runs its own worker fan-out; shard
    /// partials merge exactly, so results are bit-identical at any value.
    pub shards: usize,
    /// Transport chaos (socket backend only): per-frame probability that
    /// a coordinator→member `StepAssign` frame is lost in flight. Lost
    /// assignments are reassigned, so round records are unchanged.
    /// Schedules fork off `(round, member, frame)` keys; 0 draws nothing.
    pub chaos_drop: f64,
    /// Transport chaos: upper bound (milliseconds) on a uniform artificial
    /// delay each member sleeps before sending a `StepResult`. 0 = off.
    pub chaos_delay_ms: f64,
    /// Transport chaos: per-reply probability that a member truncates its
    /// `StepResult` frame mid-write and drops the connection (the
    /// coordinator reaps it as a peer failure and reassigns its slots).
    pub chaos_truncate: f64,
    /// Real-time floor (seconds) on the socket backend's per-slot
    /// deadline: a member that holds an outstanding `StepAssign` longer
    /// than `max(round_deadline, floor)` without progress is quarantined
    /// and its slots are reassigned. Default 30 preserves the historical
    /// `MIN_SOCKET_DEADLINE` clamp; tests lower it to induce timeouts.
    pub socket_deadline_floor: f64,
    /// Save a `--save` checkpoint every N completed rounds (0 = only at
    /// end of run). Resumable via `fedlite train --resume <path>`.
    pub checkpoint_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            task: "femnist".into(),
            preset: "paper".into(),
            algorithm: Algorithm::FedLite,
            num_clients: 100,
            clients_per_round: 10,
            rounds: 100,
            local_steps: 1,
            alpha: 0.3,
            pq: PqConfig::new(288, 1, 8),
            lambda: 1e-4,
            quantizer: QuantizerEngine::Native,
            optimizer: "sgd".into(),
            client_lr: 0.0316,
            server_lr: 0.0316,
            eval_every: 10,
            eval_batches: 4,
            seed: 17,
            artifacts_dir: "artifacts".into(),
            out_dir: String::new(),
            dropout_client: 0.25,
            dropout_server: 0.5,
            drop_prob: 0.0,
            straggler_frac: 0.0,
            round_deadline: 0.0,
            min_survivors: 0,
            byzantine_frac: 0.0,
            byzantine_kind: ByzantineKind::SignFlip,
            clip_norm: 0.0,
            aggregation: AggregationRule::Mean,
            workers: 0,
            shards: 1,
            chaos_drop: 0.0,
            chaos_delay_ms: 0.0,
            chaos_truncate: 0.0,
            socket_deadline_floor: 30.0,
            checkpoint_every: 0,
        }
    }
}

impl RunConfig {
    /// The paper's §C.2 hyper-parameters for each task.
    pub fn preset(task: &str) -> anyhow::Result<RunConfig> {
        let mut c = RunConfig::default();
        match task {
            "femnist" => {
                c.task = "femnist".into();
                c.preset = "paper".into();
                // paper used 10^-1.5 on TFF FEMNIST; on the synthetic
                // substrate the SplitFed-best rate (paper methodology:
                // tune for SplitFed, reuse for FedLite) is 10^-1.
                c.optimizer = "sgd".into();
                c.client_lr = 0.1;
                c.server_lr = 0.1;
                c.clients_per_round = 10;
                c.pq = PqConfig::new(1152, 1, 2);
                c.lambda = 1e-4;
            }
            "so_tag" => {
                c.task = "so_tag".into();
                c.preset = "small".into();
                // AdaGrad, lr 10^-0.5, 10 clients/round, B=100
                c.optimizer = "adagrad".into();
                c.client_lr = 10f32.powf(-0.5);
                c.server_lr = 10f32.powf(-0.5);
                c.clients_per_round = 10;
                c.pq = PqConfig::new(50, 1, 20);
                c.lambda = 5e-3;
                c.dropout_client = 0.0;
                c.dropout_server = 0.0;
            }
            "so_nwp" => {
                c.task = "so_nwp".into();
                c.preset = "small".into();
                // Adam, lr 0.01, 50 clients/round, B=128 (paper)
                c.optimizer = "adam".into();
                c.client_lr = 0.01;
                c.server_lr = 0.01;
                c.clients_per_round = 10;
                c.pq = PqConfig::new(12, 1, 30);
                c.lambda = 1e-3;
                c.dropout_client = 0.0;
                c.dropout_server = 0.0;
            }
            other => anyhow::bail!("unknown task '{other}'"),
        }
        Ok(c)
    }

    /// A built-in native-engine preset (no AOT artifacts or PJRT
    /// needed): any `<task>_<preset>` variant the native registry
    /// serves — `tiny` (the CI smoke/golden variants, 32-wide cut),
    /// `small` (wider cut/hidden), or `stress` (femnist-only,
    /// paper-scale 1152-wide cut). Task hyper-parameters (optimizer,
    /// lr, λ) come from [`RunConfig::preset`]; the cohort defaults
    /// shrink to smoke scale and the PQ geometry is sized to the
    /// variant's cut width (the `stress` geometry's dsub = 8 exercises
    /// the wide-dot kernel path).
    pub fn native(task: &str, preset: &str) -> anyhow::Result<RunConfig> {
        use crate::runtime::native::NativeModelCfg;
        let mut c = RunConfig::preset(task)?;
        c.preset = preset.into();
        let cfg = NativeModelCfg::by_task_preset(task, preset).ok_or_else(|| {
            anyhow::anyhow!(
                "no native variant '{task}_{preset}' (registered: {:?})",
                NativeModelCfg::registry()
                    .iter()
                    .map(|m| m.variant_key())
                    .collect::<Vec<_>>()
            )
        })?;
        c.pq = match cfg.cut {
            // d = 32: dsub 4 (the historical tiny geometry, bits unchanged)
            32 => PqConfig::new(8, 1, 4).with_iters(4),
            // d = 64: dsub 4
            64 => PqConfig::new(16, 1, 4).with_iters(4),
            // d = 1152: dsub 8 — the paper's FEMNIST subvector width
            1152 => PqConfig::new(144, 1, 8).with_iters(4),
            d => anyhow::bail!("no default PQ geometry for cut width {d}"),
        };
        c.clients_per_round = 4;
        c.eval_batches = 2;
        c.dropout_client = 0.0;
        c.dropout_server = 0.0;
        c.artifacts_dir = "native".into();
        Ok(c)
    }

    /// The CI/smoke preset (`RunConfig::native(task, "tiny")`), kept as a
    /// named constructor because tests and the golden manifest pin it.
    pub fn tiny(task: &str) -> anyhow::Result<RunConfig> {
        RunConfig::native(task, "tiny")
    }

    /// Cohort worker threads after resolving `0` (auto) to the machine
    /// default.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::pool::ThreadPool::default_size()
        } else {
            self.workers
        }
    }

    /// Variant key into the artifact manifest.
    pub fn variant(&self) -> String {
        format!("{}_{}", self.task, self.preset)
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("task", Value::Str(self.task.clone()));
        o.insert("preset", Value::Str(self.preset.clone()));
        o.insert("algorithm", Value::Str(self.algorithm.name().into()));
        o.insert("num_clients", Value::from_usize(self.num_clients));
        o.insert("clients_per_round", Value::from_usize(self.clients_per_round));
        o.insert("rounds", Value::from_usize(self.rounds));
        o.insert("local_steps", Value::from_usize(self.local_steps));
        o.insert("alpha", Value::Num(self.alpha));
        o.insert("q", Value::from_usize(self.pq.q));
        o.insert("r", Value::from_usize(self.pq.r));
        o.insert("l", Value::from_usize(self.pq.l));
        o.insert("kmeans_iters", Value::from_usize(self.pq.iters));
        o.insert("lambda", Value::Num(self.lambda as f64));
        o.insert(
            "quantizer",
            Value::Str(
                match self.quantizer {
                    QuantizerEngine::Native => "native",
                    QuantizerEngine::Pjrt => "pjrt",
                }
                .into(),
            ),
        );
        o.insert("optimizer", Value::Str(self.optimizer.clone()));
        o.insert("client_lr", Value::Num(self.client_lr as f64));
        o.insert("server_lr", Value::Num(self.server_lr as f64));
        o.insert("eval_every", Value::from_usize(self.eval_every));
        o.insert("eval_batches", Value::from_usize(self.eval_batches));
        o.insert("seed", Value::Num(self.seed as f64));
        o.insert("artifacts_dir", Value::Str(self.artifacts_dir.clone()));
        o.insert("out_dir", Value::Str(self.out_dir.clone()));
        o.insert("dropout_client", Value::Num(self.dropout_client));
        o.insert("dropout_server", Value::Num(self.dropout_server));
        o.insert("drop_prob", Value::Num(self.drop_prob));
        o.insert("straggler_frac", Value::Num(self.straggler_frac));
        o.insert("round_deadline", Value::Num(self.round_deadline));
        o.insert("min_survivors", Value::from_usize(self.min_survivors));
        o.insert("byzantine_frac", Value::Num(self.byzantine_frac));
        o.insert("byzantine_kind", Value::Str(self.byzantine_kind.name().into()));
        o.insert("clip_norm", Value::Num(self.clip_norm));
        o.insert("aggregation", Value::Str(self.aggregation.name().into()));
        o.insert("workers", Value::from_usize(self.workers));
        o.insert("shards", Value::from_usize(self.shards));
        o.insert("chaos_drop", Value::Num(self.chaos_drop));
        o.insert("chaos_delay_ms", Value::Num(self.chaos_delay_ms));
        o.insert("chaos_truncate", Value::Num(self.chaos_truncate));
        o.insert(
            "socket_deadline_floor",
            Value::Num(self.socket_deadline_floor),
        );
        o.insert("checkpoint_every", Value::from_usize(self.checkpoint_every));
        Value::Obj(o)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<RunConfig> {
        let mut c = RunConfig::default();
        let get_us = |k: &str, d: usize| v.get(k).as_usize().unwrap_or(d);
        let get_f = |k: &str, d: f64| v.get(k).as_f64().unwrap_or(d);
        let get_s = |k: &str, d: &str| {
            v.get(k).as_str().unwrap_or(d).to_string()
        };
        c.task = get_s("task", &c.task);
        c.preset = get_s("preset", &c.preset);
        c.algorithm = Algorithm::parse(&get_s("algorithm", "fedlite"))?;
        c.num_clients = get_us("num_clients", c.num_clients);
        c.clients_per_round = get_us("clients_per_round", c.clients_per_round);
        c.rounds = get_us("rounds", c.rounds);
        c.local_steps = get_us("local_steps", c.local_steps);
        c.alpha = get_f("alpha", c.alpha);
        c.pq = PqConfig::new(
            get_us("q", c.pq.q),
            get_us("r", c.pq.r),
            get_us("l", c.pq.l),
        )
        .with_iters(get_us("kmeans_iters", c.pq.iters));
        c.lambda = get_f("lambda", c.lambda as f64) as f32;
        c.quantizer = match get_s("quantizer", "native").as_str() {
            "pjrt" => QuantizerEngine::Pjrt,
            _ => QuantizerEngine::Native,
        };
        c.optimizer = get_s("optimizer", &c.optimizer);
        c.client_lr = get_f("client_lr", c.client_lr as f64) as f32;
        c.server_lr = get_f("server_lr", c.server_lr as f64) as f32;
        c.eval_every = get_us("eval_every", c.eval_every);
        c.eval_batches = get_us("eval_batches", c.eval_batches);
        c.seed = get_f("seed", c.seed as f64) as u64;
        c.artifacts_dir = get_s("artifacts_dir", &c.artifacts_dir);
        c.out_dir = get_s("out_dir", &c.out_dir);
        c.dropout_client = get_f("dropout_client", c.dropout_client);
        c.dropout_server = get_f("dropout_server", c.dropout_server);
        c.drop_prob = get_f("drop_prob", c.drop_prob);
        c.straggler_frac = get_f("straggler_frac", c.straggler_frac);
        c.round_deadline = get_f("round_deadline", c.round_deadline);
        c.min_survivors = get_us("min_survivors", c.min_survivors);
        // byzantine/defense knobs default tolerant of pre-PR-9 JSON
        c.byzantine_frac = get_f("byzantine_frac", c.byzantine_frac);
        c.byzantine_kind =
            ByzantineKind::parse(&get_s("byzantine_kind", c.byzantine_kind.name()))?;
        c.clip_norm = get_f("clip_norm", c.clip_norm);
        c.aggregation =
            AggregationRule::parse(&get_s("aggregation", c.aggregation.name()))?;
        c.workers = get_us("workers", c.workers);
        c.shards = get_us("shards", c.shards);
        // transport chaos / deadline-floor / checkpoint knobs default
        // tolerant of pre-PR-10 JSON
        c.chaos_drop = get_f("chaos_drop", c.chaos_drop);
        c.chaos_delay_ms = get_f("chaos_delay_ms", c.chaos_delay_ms);
        c.chaos_truncate = get_f("chaos_truncate", c.chaos_truncate);
        c.socket_deadline_floor =
            get_f("socket_deadline_floor", c.socket_deadline_floor);
        c.checkpoint_every = get_us("checkpoint_every", c.checkpoint_every);
        Ok(c)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.clients_per_round >= 1, "need >= 1 client per round");
        anyhow::ensure!(
            self.clients_per_round <= self.num_clients,
            "clients_per_round {} > population {}",
            self.clients_per_round,
            self.num_clients
        );
        anyhow::ensure!(self.rounds >= 1, "need >= 1 round");
        anyhow::ensure!(self.local_steps >= 1, "need >= 1 local step");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.drop_prob),
            "drop_prob {} outside [0, 1]",
            self.drop_prob
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.straggler_frac),
            "straggler_frac {} outside [0, 1]",
            self.straggler_frac
        );
        anyhow::ensure!(
            self.round_deadline >= 0.0 && self.round_deadline.is_finite(),
            "round_deadline {} must be finite and >= 0",
            self.round_deadline
        );
        anyhow::ensure!(
            self.min_survivors <= self.clients_per_round,
            "min_survivors {} > clients_per_round {}",
            self.min_survivors,
            self.clients_per_round
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.byzantine_frac),
            "byzantine_frac {} outside [0, 1]",
            self.byzantine_frac
        );
        anyhow::ensure!(
            self.clip_norm >= 0.0 && self.clip_norm.is_finite(),
            "clip_norm {} must be finite and >= 0",
            self.clip_norm
        );
        anyhow::ensure!(self.shards >= 1, "need >= 1 shard");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.chaos_drop),
            "chaos_drop {} outside [0, 1]",
            self.chaos_drop
        );
        anyhow::ensure!(
            self.chaos_delay_ms >= 0.0 && self.chaos_delay_ms.is_finite(),
            "chaos_delay_ms {} must be finite and >= 0",
            self.chaos_delay_ms
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.chaos_truncate),
            "chaos_truncate {} outside [0, 1]",
            self.chaos_truncate
        );
        anyhow::ensure!(
            self.socket_deadline_floor > 0.0 && self.socket_deadline_floor.is_finite(),
            "socket_deadline_floor {} must be finite and > 0",
            self.socket_deadline_floor
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn presets_match_paper_c2() {
        let f = RunConfig::preset("femnist").unwrap();
        assert!((f.client_lr - 0.1).abs() < 1e-6); // SplitFed-best on substrate
        assert_eq!(f.optimizer, "sgd");
        assert_eq!(f.clients_per_round, 10);
        let t = RunConfig::preset("so_tag").unwrap();
        assert_eq!(t.optimizer, "adagrad");
        let n = RunConfig::preset("so_nwp").unwrap();
        assert_eq!(n.optimizer, "adam");
        assert!((n.client_lr - 0.01).abs() < 1e-9);
        assert!(RunConfig::preset("mnist").is_err());
    }

    #[test]
    fn tiny_preset_targets_native_variant() {
        let c = RunConfig::tiny("femnist").unwrap();
        assert_eq!(c.variant(), "femnist_tiny");
        assert_eq!(c.artifacts_dir, "native");
        assert_eq!(c.pq, PqConfig::new(8, 1, 4).with_iters(4));
        assert!(c.validate().is_ok());
        // the SO tasks have native tiny variants of their own now
        let t = RunConfig::tiny("so_tag").unwrap();
        assert_eq!(t.variant(), "so_tag_tiny");
        assert_eq!(t.artifacts_dir, "native");
    }

    #[test]
    fn native_presets_match_their_variants() {
        // every registered engine variant must be reachable as a native
        // preset carrying a PQ geometry that divides its cut width
        use crate::runtime::native::NativeModelCfg;
        for cfg in NativeModelCfg::registry() {
            let c = RunConfig::native(cfg.task, cfg.preset).unwrap();
            assert_eq!(c.variant(), cfg.variant_key());
            assert_eq!(c.artifacts_dir, "native");
            c.pq.validate(cfg.cut).unwrap();
            assert!(c.validate().is_ok());
        }
        // task hyper-parameters survive the native override
        let t = RunConfig::native("so_tag", "small").unwrap();
        assert_eq!(t.optimizer, "adagrad");
        let n = RunConfig::native("so_nwp", "tiny").unwrap();
        assert_eq!(n.optimizer, "adam");
        assert!(RunConfig::native("femnist", "paper").is_err());
        assert!(RunConfig::native("so_tag", "stress").is_err());
    }

    #[test]
    fn workers_resolution() {
        let mut c = RunConfig::default();
        assert!(c.resolved_workers() >= 1);
        c.workers = 3;
        assert_eq!(c.resolved_workers(), 3);
    }

    #[test]
    fn fault_knob_validation() {
        let mut c = RunConfig::default();
        c.drop_prob = 0.3;
        c.straggler_frac = 0.5;
        c.round_deadline = 2.0;
        c.min_survivors = c.clients_per_round;
        assert!(c.validate().is_ok());
        c.drop_prob = 1.5;
        assert!(c.validate().is_err());
        c.drop_prob = 0.3;
        c.straggler_frac = -0.1;
        assert!(c.validate().is_err());
        c.straggler_frac = 0.5;
        c.round_deadline = -1.0;
        assert!(c.validate().is_err());
        c.round_deadline = 0.0;
        c.min_survivors = c.clients_per_round + 1;
        assert!(c.validate().is_err());
        c.min_survivors = 0;
        c.shards = 0;
        assert!(c.validate().is_err());
        c.shards = 1;
        c.byzantine_frac = 1.2;
        assert!(c.validate().is_err());
        c.byzantine_frac = 0.5;
        c.clip_norm = -1.0;
        assert!(c.validate().is_err());
        c.clip_norm = f64::NAN;
        assert!(c.validate().is_err());
        c.clip_norm = 2.0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn chaos_and_deadline_floor_validation() {
        let mut c = RunConfig::default();
        c.chaos_drop = 0.05;
        c.chaos_delay_ms = 50.0;
        c.chaos_truncate = 0.1;
        assert!(c.validate().is_ok());
        c.chaos_drop = 1.5;
        assert!(c.validate().is_err());
        c.chaos_drop = 0.0;
        c.chaos_delay_ms = -1.0;
        assert!(c.validate().is_err());
        c.chaos_delay_ms = f64::INFINITY;
        assert!(c.validate().is_err());
        c.chaos_delay_ms = 0.0;
        c.chaos_truncate = -0.1;
        assert!(c.validate().is_err());
        c.chaos_truncate = 0.0;
        c.socket_deadline_floor = 0.0;
        assert!(c.validate().is_err());
        c.socket_deadline_floor = 0.2;
        assert!(c.validate().is_ok());
        // pre-PR-10 JSON (no chaos keys) parses to the quiet defaults
        let old = r#"{"task": "femnist", "rounds": 3, "drop_prob": 0.25}"#;
        let back = RunConfig::from_json(&json::parse(old).unwrap()).unwrap();
        assert_eq!(back.chaos_drop, 0.0);
        assert_eq!(back.chaos_delay_ms, 0.0);
        assert_eq!(back.chaos_truncate, 0.0);
        assert_eq!(back.socket_deadline_floor, 30.0);
        assert_eq!(back.checkpoint_every, 0);
    }

    #[test]
    fn byzantine_and_aggregation_parse() {
        for k in ByzantineKind::ALL {
            assert_eq!(ByzantineKind::parse(k.name()).unwrap(), k);
        }
        assert!(ByzantineKind::parse("ddos").is_err());
        for r in [AggregationRule::Mean, AggregationRule::Trimmed, AggregationRule::Median] {
            assert_eq!(AggregationRule::parse(r.name()).unwrap(), r);
        }
        assert!(AggregationRule::parse("krum").is_err());
        // pre-PR-9 JSON (no byzantine keys) parses to the honest defaults
        let old = r#"{"task": "femnist", "rounds": 3, "drop_prob": 0.25}"#;
        let back = RunConfig::from_json(&json::parse(old).unwrap()).unwrap();
        assert_eq!(back.byzantine_frac, 0.0);
        assert_eq!(back.byzantine_kind, ByzantineKind::SignFlip);
        assert_eq!(back.clip_norm, 0.0);
        assert_eq!(back.aggregation, AggregationRule::Mean);
        assert_eq!(back.rounds, 3);
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut c = RunConfig::preset("femnist").unwrap();
        c.rounds = 321;
        c.lambda = 5e-4;
        c.workers = 6;
        c.shards = 4;
        c.algorithm = Algorithm::SplitFed;
        c.quantizer = QuantizerEngine::Pjrt;
        c.drop_prob = 0.25;
        c.straggler_frac = 0.75;
        c.round_deadline = 3.5;
        c.min_survivors = 2;
        c.byzantine_frac = 0.4;
        c.byzantine_kind = ByzantineKind::CorruptCodeword;
        c.clip_norm = 1.5;
        c.aggregation = AggregationRule::Trimmed;
        c.chaos_drop = 0.05;
        c.chaos_delay_ms = 50.0;
        c.chaos_truncate = 0.02;
        c.socket_deadline_floor = 2.5;
        c.checkpoint_every = 7;
        let j = c.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.rounds, 321);
        assert_eq!(back.workers, 6);
        assert_eq!(back.shards, 4);
        assert!((back.drop_prob - 0.25).abs() < 1e-12);
        assert!((back.straggler_frac - 0.75).abs() < 1e-12);
        assert!((back.round_deadline - 3.5).abs() < 1e-12);
        assert_eq!(back.min_survivors, 2);
        assert!((back.byzantine_frac - 0.4).abs() < 1e-12);
        assert_eq!(back.byzantine_kind, ByzantineKind::CorruptCodeword);
        assert!((back.clip_norm - 1.5).abs() < 1e-12);
        assert_eq!(back.aggregation, AggregationRule::Trimmed);
        assert!((back.chaos_drop - 0.05).abs() < 1e-12);
        assert!((back.chaos_delay_ms - 50.0).abs() < 1e-12);
        assert!((back.chaos_truncate - 0.02).abs() < 1e-12);
        assert!((back.socket_deadline_floor - 2.5).abs() < 1e-12);
        assert_eq!(back.checkpoint_every, 7);
        assert!((back.lambda - 5e-4).abs() < 1e-9);
        assert_eq!(back.algorithm, Algorithm::SplitFed);
        assert_eq!(back.quantizer, QuantizerEngine::Pjrt);
        assert_eq!(back.pq, c.pq);
        // and via text
        let text = j.to_string_pretty();
        let back2 = RunConfig::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back2.task, "femnist");
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = RunConfig::default();
        c.clients_per_round = 200;
        c.num_clients = 100;
        assert!(c.validate().is_err());
        c.clients_per_round = 10;
        assert!(c.validate().is_ok());
        c.rounds = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("fedavg").unwrap(), Algorithm::FedAvg);
        assert!(Algorithm::parse("sgd").is_err());
    }
}
