//! Bench: end-to-end round latency per algorithm + per-stage breakdown.
//!
//! Two sections:
//!
//! 1. **Cohort scaling (always runs, native engine)** — one FedLite round
//!    over a 16-client cohort at `workers = 1` vs `workers = N` (machine
//!    default). This is the wall-clock trajectory of the parallel cohort
//!    engine; on a 4+ core machine the parallel case should be ≥ 2×
//!    faster while producing bit-identical round records (see
//!    `rust/tests/determinism.rs`).
//! 2. **PJRT rounds + stage breakdown** — regenerates the *measured* side
//!    of Table 1 and the §Perf L3 round profile (client_fwd / quantize /
//!    server_step / client_bwd, isolated). Skips gracefully when
//!    artifacts are missing.

use std::sync::Arc;

use fedlite::config::{Algorithm, QuantizerEngine, RunConfig};
use fedlite::coordinator::client::{assemble, draw_masks, InputSources};
use fedlite::coordinator::quantize::QuantizeBackend;
use fedlite::coordinator::{build_dataset, build_trainer, Trainer};
use fedlite::data::Array;
use fedlite::runtime::Runtime;
use fedlite::util::bench::Bench;
use fedlite::util::pool::ThreadPool;
use fedlite::util::rng::Rng;

fn cohort_scaling(b: &mut Bench) {
    let rt = Arc::new(Runtime::native());
    let auto = ThreadPool::default_size();
    let mut workers: Vec<usize> = vec![1];
    if auto > 1 {
        workers.push(auto);
    }
    for w in workers {
        for algo in [Algorithm::FedLite, Algorithm::FedAvg] {
            let mut cfg = RunConfig::tiny("femnist").unwrap();
            cfg.algorithm = algo;
            cfg.rounds = 2;
            cfg.num_clients = 16;
            cfg.clients_per_round = 16;
            cfg.eval_every = 0;
            cfg.workers = w;
            // trainer (dataset gen + param init) built outside the timed
            // region so the measurement isolates the round loop; each
            // iteration re-runs `rounds` fresh rounds on the same trainer
            let mut t = build_trainer(cfg, Arc::clone(&rt)).unwrap();
            b.case(
                &format!("2 rounds femnist_tiny/{} S=16 workers={w}", algo.name()),
                1,
                5,
                0.0,
                move || {
                    std::hint::black_box(t.run().unwrap());
                },
            );
        }
    }
}

fn main() {
    let mut b = Bench::new("round");
    cohort_scaling(&mut b);

    if !cfg!(feature = "pjrt") || !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("bench_round: no pjrt feature or artifacts, skipping the PJRT section");
        b.finish_to(Some("BENCH_round.json"));
        return;
    }
    let rt = Arc::new(Runtime::open("artifacts").expect("runtime"));

    // whole rounds, each algorithm (FEMNIST paper config, 4 clients/round)
    for algo in [Algorithm::FedLite, Algorithm::SplitFed, Algorithm::FedAvg] {
        let mut cfg = RunConfig::preset("femnist").unwrap();
        cfg.algorithm = algo;
        cfg.rounds = 1;
        cfg.num_clients = 10;
        cfg.clients_per_round = 4;
        cfg.eval_every = 0;
        let rt2 = Arc::clone(&rt);
        b.case(&format!("one round femnist/{} S=4", algo.name()), 1, 3, 0.0, move || {
            let mut t = build_trainer(cfg.clone(), Arc::clone(&rt2)).unwrap();
            std::hint::black_box(t.run().unwrap());
        });
    }

    // stage breakdown at the headline FedLite config
    let variant = "femnist_paper";
    let spec = rt.manifest.variant(variant).unwrap().spec.clone();
    let rng = Rng::new(0);
    let wc = spec.client.init_tensors(&mut rng.fork(1));
    let ws = spec.server.init_tensors(&mut rng.fork(2));
    let cfg = RunConfig::preset("femnist").unwrap();
    let data = build_dataset(&cfg).unwrap();
    let batch = data.train_batch(0, spec.batch, &mut rng.fork(3));
    let fwd = rt.manifest.artifact(variant, "client_fwd").unwrap().clone();
    let step = rt.manifest.artifact(variant, "server_step").unwrap().clone();
    let bwd = rt.manifest.artifact(variant, "client_bwd").unwrap().clone();
    let masks = draw_masks(&[&fwd, &step, &bwd], 0.25, 0.5, &mut rng.fork(4));

    let src = InputSources {
        wc: Some(&wc), batch: Some(&batch), masks: Some(&masks), ..Default::default()
    };
    let fwd_inputs = assemble(&fwd, &src).unwrap();
    rt.run(variant, "client_fwd", &fwd_inputs).unwrap(); // compile warmup
    b.case("stage: client_fwd (PJRT)", 2, 10, 0.0, || {
        std::hint::black_box(rt.run(variant, "client_fwd", &fwd_inputs).unwrap());
    });
    let z_arr = rt.run(variant, "client_fwd", &fwd_inputs).unwrap().remove(0);
    let z = z_arr.as_f32().unwrap().to_vec();

    for engine in [QuantizerEngine::Native, QuantizerEngine::Pjrt] {
        let qb = QuantizeBackend::new(engine, cfg.pq, spec.cut_dim, Arc::clone(&rt), variant)
            .unwrap();
        let mut qrng = Rng::new(5);
        // warmup compiles the artifact on the pjrt path
        qb.quantize(&z, spec.act_batch, &mut qrng).unwrap();
        b.case(
            &format!("stage: quantize q=1152 L=2 ({})", qb.engine_name()),
            1,
            5,
            (z.len() * 4) as f64,
            || {
                std::hint::black_box(qb.quantize(&z, spec.act_batch, &mut qrng).unwrap());
            },
        );
    }

    let qb = QuantizeBackend::new(
        QuantizerEngine::Native, cfg.pq, spec.cut_dim, Arc::clone(&rt), variant,
    ).unwrap();
    let out = qb.quantize(&z, spec.act_batch, &mut Rng::new(6)).unwrap();
    let z_tilde = Array::f32(&[spec.act_batch, spec.cut_dim], out.z_tilde.clone());
    let src = InputSources {
        ws: Some(&ws), batch: Some(&batch), masks: Some(&masks),
        z_tilde: Some(&z_tilde), ..Default::default()
    };
    let step_inputs = assemble(&step, &src).unwrap();
    rt.run(variant, "server_step", &step_inputs).unwrap();
    b.case("stage: server_step (PJRT)", 2, 10, 0.0, || {
        std::hint::black_box(rt.run(variant, "server_step", &step_inputs).unwrap());
    });
    let outs = rt.run(variant, "server_step", &step_inputs).unwrap();
    let grad_z = outs[2].clone(); // loss, correct, grad_z, ...

    let src = InputSources {
        wc: Some(&wc), batch: Some(&batch), masks: Some(&masks),
        z_tilde: Some(&z_tilde), grad_z: Some(&grad_z), lambda: Some(1e-4),
        ..Default::default()
    };
    let bwd_inputs = assemble(&bwd, &src).unwrap();
    rt.run(variant, "client_bwd", &bwd_inputs).unwrap();
    b.case("stage: client_bwd (PJRT, incl. correction)", 2, 10, 0.0, || {
        std::hint::black_box(rt.run(variant, "client_bwd", &bwd_inputs).unwrap());
    });

    b.finish_to(Some("BENCH_round.json"));
}
