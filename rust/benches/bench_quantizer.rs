//! Bench: the L3 quantizer hot path (Figure 3's configurations).
//!
//! Measures native grouped-PQ throughput on FEMNIST-shaped activations
//! (d=9216, B=20) across the paper's operating points, plus codeword
//! packing and wire encode/decode. This is the §Perf baseline for the
//! coordinator-side hot loop: in a FedLite round the quantizer runs once
//! per client.
//!
//! Each sweep point runs twice: the allocating `quantize` entry point
//! (the historical baseline shape of the measurement) and the
//! steady-state `quantize_into` with a warm scratch arena — the path the
//! round engine actually drives — plus, where the config allows it, a
//! multi-worker scratch (`R > 1` fans groups across lanes, `R == 1`
//! chunks the assignment pass over points). All three produce
//! bit-identical outputs; only the wall clock differs.
//!
//! Knobs (used by the CI `bench` job): `FEDLITE_BENCH_REPS=<n>` overrides
//! the timed iteration counts; `FEDLITE_BENCH_SMALL=1` shrinks the
//! activation shape 4× for quick smoke runs.
//!
//! Output: `results/bench/quantizer.{csv,json}` plus the repo-root
//! trajectory file `BENCH_quantizer.json` (schema in `util::bench`).

use fedlite::comm::message::Message;
use fedlite::quantizer::packing;
use fedlite::quantizer::pq::{GroupedPq, PqConfig, PqOutput, QuantizeScratch};
use fedlite::util::bench::{reps_or, small_shape, Bench};
use fedlite::util::pool::ThreadPool;
use fedlite::util::rng::Rng;

fn main() {
    let mut b = Bench::new("quantizer");
    // FEMNIST paper shape, or 4x smaller with FEDLITE_BENCH_SMALL=1
    let scale = if small_shape() { 4usize } else { 1 };
    let (batch, d) = (20usize / scale.min(4), 9216usize / scale);
    let reps = reps_or(5);
    let mut rng = Rng::new(0);
    let z: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let work = (batch * d * 4) as f64;
    let auto_workers = ThreadPool::default_size();

    // the paper's headline + representative sweep points (q, R, L, iters)
    for (q, r, l) in [
        (1152usize, 1usize, 2usize), // 490x point
        (288, 1, 8),
        (288, 1, 32),
        (4608, 1, 8),
        (4608, 384, 8), // grouped, many codebooks
        (288, 288, 8),  // vanilla PQ
        (1, 1, 8),      // K-means over whole vectors
    ] {
        let (q, r) = ((q / scale).max(1), (r / scale).max(1));
        let pq = GroupedPq::new(PqConfig::new(q, r, l).with_iters(8), d).unwrap();
        let mut qrng = Rng::new(42);
        b.case(
            &format!("quantize q={q} R={r} L={l} iters=8"),
            1,
            reps,
            work,
            || {
                let out = pq.quantize(&z, batch, &mut qrng);
                std::hint::black_box(out.sq_error);
            },
        );
        // steady-state scratch path (what the round engine drives)
        let mut scratch = QuantizeScratch::new();
        let mut out = PqOutput::default();
        let mut qrng = Rng::new(42);
        b.case(
            &format!("quantize_into q={q} R={r} L={l} iters=8 (warm scratch)"),
            1,
            reps,
            work,
            || {
                pq.quantize_into(&z, batch, &mut qrng, &mut scratch, &mut out);
                std::hint::black_box(out.sq_error);
            },
        );
        // nested fan-out: groups across lanes (R > 1) or assignment
        // chunking over points (R == 1)
        if auto_workers > 1 {
            let mut scratch = QuantizeScratch::with_workers(auto_workers);
            let mut out = PqOutput::default();
            let mut qrng = Rng::new(42);
            b.case(
                &format!(
                    "quantize_into q={q} R={r} L={l} iters=8 (workers={auto_workers})"
                ),
                1,
                reps,
                work,
                || {
                    pq.quantize_into(&z, batch, &mut qrng, &mut scratch, &mut out);
                    std::hint::black_box(out.sq_error);
                },
            );
        }
    }

    // Lloyd iteration scaling at the headline config
    for iters in [1usize, 4, 8, 16] {
        let q = (1152 / scale).max(1);
        let pq = GroupedPq::new(PqConfig::new(q, 1, 2).with_iters(iters), d).unwrap();
        let mut qrng = Rng::new(42);
        let mut scratch = QuantizeScratch::new();
        let mut out = PqOutput::default();
        b.case(&format!("quantize q={q} L=2 iters={iters}"), 1, reps, work, || {
            pq.quantize_into(&z, batch, &mut qrng, &mut scratch, &mut out);
            std::hint::black_box(out.sq_error);
        });
    }

    // packing + wire
    let q = (1152 / scale).max(1);
    let pq = GroupedPq::new(PqConfig::new(q, 1, 2).with_iters(2), d).unwrap();
    let mut qrng = Rng::new(7);
    let out = pq.quantize(&z, batch, &mut qrng);
    let pack_reps = reps_or(100);
    b.case(
        &format!("pack codes ({} @ 1 bit)", out.codes.len()),
        10,
        pack_reps,
        out.codes.len() as f64 * 4.0,
        || {
            std::hint::black_box(packing::pack(&out.codes, 2));
        },
    );
    let packed = packing::pack(&out.codes, 2);
    b.case("unpack codes", 10, pack_reps, out.codes.len() as f64 * 4.0, || {
        std::hint::black_box(packing::unpack(&packed, out.codes.len(), 2).unwrap());
    });
    let msg = Message::from_pq(&out.config, batch, d, &out.codebooks, &out.codes);
    let wire_reps = reps_or(200);
    b.case("wire encode quantized upload", 10, wire_reps, msg.wire_len() as f64, || {
        std::hint::black_box(msg.encode(0, 0));
    });
    let bytes = msg.encode(0, 0);
    b.case("wire decode quantized upload", 10, wire_reps, bytes.len() as f64, || {
        std::hint::black_box(Message::decode(&bytes).unwrap());
    });
    let raw = Message::ActivationUpload { z: z.clone(), b: batch, d };
    b.case("wire encode raw activations (SplitFed)", 5, reps_or(50), work, || {
        std::hint::black_box(raw.encode(0, 0));
    });

    b.finish_to(Some("BENCH_quantizer.json"));
}
