//! Bench: the L3 quantizer hot path (Figure 3's configurations).
//!
//! Measures native grouped-PQ throughput on FEMNIST-shaped activations
//! (d=9216, B=20) across the paper's operating points, plus codeword
//! packing and wire encode/decode. This is the §Perf baseline for the
//! coordinator-side hot loop: in a FedLite round the quantizer runs once
//! per client.

use fedlite::comm::message::Message;
use fedlite::quantizer::packing;
use fedlite::quantizer::pq::{GroupedPq, PqConfig};
use fedlite::util::bench::Bench;
use fedlite::util::rng::Rng;

fn main() {
    let mut b = Bench::new("quantizer");
    let (batch, d) = (20usize, 9216usize);
    let mut rng = Rng::new(0);
    let z: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let work = (batch * d * 4) as f64;

    // the paper's headline + representative sweep points (q, R, L, iters)
    for (q, r, l) in [
        (1152usize, 1usize, 2usize), // 490x point
        (288, 1, 8),
        (288, 1, 32),
        (4608, 1, 8),
        (4608, 384, 8), // grouped, many codebooks
        (288, 288, 8),  // vanilla PQ
        (1, 1, 8),      // K-means over whole vectors
    ] {
        let pq = GroupedPq::new(PqConfig::new(q, r, l).with_iters(8), d).unwrap();
        let mut qrng = Rng::new(42);
        b.case(
            &format!("quantize q={q} R={r} L={l} iters=8"),
            1,
            5,
            work,
            || {
                let out = pq.quantize(&z, batch, &mut qrng);
                std::hint::black_box(out.sq_error);
            },
        );
    }

    // Lloyd iteration scaling at the headline config
    for iters in [1usize, 4, 8, 16] {
        let pq = GroupedPq::new(PqConfig::new(1152, 1, 2).with_iters(iters), d).unwrap();
        let mut qrng = Rng::new(42);
        b.case(&format!("quantize q=1152 L=2 iters={iters}"), 1, 5, work, || {
            std::hint::black_box(pq.quantize(&z, batch, &mut qrng).sq_error);
        });
    }

    // packing + wire
    let pq = GroupedPq::new(PqConfig::new(1152, 1, 2).with_iters(2), d).unwrap();
    let mut qrng = Rng::new(7);
    let out = pq.quantize(&z, batch, &mut qrng);
    b.case("pack codes (23040 @ 1 bit)", 10, 100, out.codes.len() as f64 * 4.0, || {
        std::hint::black_box(packing::pack(&out.codes, 2));
    });
    let packed = packing::pack(&out.codes, 2);
    b.case("unpack codes", 10, 100, out.codes.len() as f64 * 4.0, || {
        std::hint::black_box(packing::unpack(&packed, out.codes.len(), 2).unwrap());
    });
    let msg = Message::from_pq(&out.config, batch, d, &out.codebooks, &out.codes);
    b.case("wire encode quantized upload", 10, 200, msg.wire_len() as f64, || {
        std::hint::black_box(msg.encode(0, 0));
    });
    let bytes = msg.encode(0, 0);
    b.case("wire decode quantized upload", 10, 200, bytes.len() as f64, || {
        std::hint::black_box(Message::decode(&bytes).unwrap());
    });
    let raw = Message::ActivationUpload { z: z.clone(), b: batch, d };
    b.case("wire encode raw activations (SplitFed)", 5, 50, work, || {
        std::hint::black_box(raw.encode(0, 0));
    });

    b.finish();
}
