//! Bench: the native engine's compute kernels, per artifact per variant.
//!
//! For every registered FEMNIST native variant (`femnist_tiny` /
//! `femnist_small` / `femnist_stress`) and every artifact (`client_fwd`,
//! `server_step`, `client_bwd`, `full_grad`, `full_eval`), times three
//! kernel policies
//! that produce **bit-identical** outputs (asserted here before timing):
//!
//! * `naive` — the historical triple-loop kernels (the baseline PR 5
//!   replaced);
//! * `tiled` — the cache-blocked kernels from `tensor::gemm` (what the
//!   round engine's cohort workers run);
//! * `tiled+parallel` — tiled + row fan-out across the machine's cores
//!   (skipped on single-core machines; the case list in
//!   `BENCH_engine.json::expected_cases` still names it).
//!
//! Every case runs through `run_scratch` with a warm [`EngineScratch`],
//! so the measurement is the kernels plus the fixed `Array` output copy,
//! not allocator noise. The `work` column is the artifact's FLOP count
//! (2·MACs), so the harness's MB/s column reads as MFLOP/s here.
//!
//! Knobs (used by the CI `bench` job): `FEDLITE_BENCH_REPS=<n>`
//! overrides the timed iteration count; `FEDLITE_BENCH_SMALL=1` skips
//! the `stress` variant (called out loudly — no silent coverage drop).
//!
//! Output: `results/bench/engine.{csv,json}` plus the repo-root
//! trajectory file `BENCH_engine.json` (schema in `util::bench`).

use fedlite::data::Array;
use fedlite::runtime::native::{EngineScratch, NativeEngine, NativeModelCfg};
use fedlite::runtime::Runtime;
use fedlite::tensor::gemm::GemmPolicy;
use fedlite::util::bench::{reps_or, small_shape, Bench};
use fedlite::util::pool::ThreadPool;
use fedlite::util::rng::Rng;

/// Ready-made input lists for every artifact of one variant.
struct VariantInputs {
    fwd: Vec<Array>,
    step: Vec<Array>,
    bwd: Vec<Array>,
    full: Vec<Array>,
    eval: Vec<Array>,
}

fn build_inputs(cfg: &NativeModelCfg) -> VariantInputs {
    let rt = Runtime::native();
    let key = cfg.variant_key();
    let spec = rt.manifest.variant(&key).unwrap().spec.clone();
    let rng = Rng::new(0xB_E7C);
    let wc = spec.client.init_tensors(&mut rng.fork(1));
    let ws = spec.server.init_tensors(&mut rng.fork(2));
    let mut r = rng.fork(3);
    let x = r.uniform_vec(cfg.batch * cfg.input, 0.0, 1.0);
    let y: Vec<i32> = (0..cfg.batch).map(|_| r.below(cfg.classes) as i32).collect();
    let ex = r.uniform_vec(cfg.eval_batch * cfg.input, 0.0, 1.0);
    let ey: Vec<i32> = (0..cfg.eval_batch).map(|_| r.below(cfg.classes) as i32).collect();
    let arr = |t: &fedlite::tensor::Tensor| Array::f32(t.shape(), t.data().to_vec());

    let mut fwd: Vec<Array> = wc.tensors.iter().map(arr).collect();
    fwd.push(Array::f32(&[cfg.batch, 28, 28, 1], x.clone()));

    let mut full: Vec<Array> = wc.tensors.iter().map(arr).collect();
    full.extend(ws.tensors.iter().map(arr));
    full.push(Array::f32(&[cfg.batch, 28, 28, 1], x));
    full.push(Array::i32(&[cfg.batch], y.clone()));

    let mut eval: Vec<Array> = wc.tensors.iter().map(arr).collect();
    eval.extend(ws.tensors.iter().map(arr));
    eval.push(Array::f32(&[cfg.eval_batch, 28, 28, 1], ex));
    eval.push(Array::i32(&[cfg.eval_batch], ey));

    // derive z / grad_z for the split-path artifacts from a real pass
    let engine = NativeEngine::new();
    let z = engine.run(&key, "client_fwd", &fwd).unwrap().remove(0);
    let mut step: Vec<Array> = ws.tensors.iter().map(arr).collect();
    step.push(Array::i32(&[cfg.batch], y));
    step.push(z.clone());
    let souts = engine.run(&key, "server_step", &step).unwrap();

    let mut bwd: Vec<Array> = wc.tensors.iter().map(arr).collect();
    bwd.push(full[6].clone()); // x
    bwd.push(z); // z_tilde = z
    bwd.push(souts[2].clone()); // grad_z
    bwd.push(Array::f32(&[], vec![1e-4]));

    VariantInputs { fwd, step, bwd, full, eval }
}

/// FLOPs (2·MACs) per artifact — the dominant dense-math terms only.
fn flops(cfg: &NativeModelCfg, artifact: &str) -> f64 {
    let (m, e) = (cfg.batch as f64, cfg.eval_batch as f64);
    let (i, c, h, k) = (
        cfg.input as f64,
        cfg.cut as f64,
        cfg.hidden as f64,
        cfg.classes as f64,
    );
    let fwd_cut = m * i * c;
    let server_fwd = m * (c * h + h * k);
    let server_bwd = m * (h * k + k * h + c * h + h * c); // g_w3, dh1, g_w2, gz
    2.0 * match artifact {
        "client_fwd" => fwd_cut,
        "server_step" => server_fwd + server_bwd,
        "client_bwd" => 2.0 * fwd_cut, // recomputed fwd + g_w1
        "full_grad" => 2.0 * fwd_cut + server_fwd + server_bwd,
        "full_eval" => e * (i * c + c * h + h * k),
        _ => unreachable!(),
    }
}

fn main() {
    let mut b = Bench::new("engine");
    let reps = reps_or(5);
    let auto = ThreadPool::default_size();

    for cfg in NativeModelCfg::registry() {
        if cfg.task != "femnist" {
            // build_inputs synthesizes FEMNIST-shaped batches; the SO
            // variants run the same GEMM kernels at different dims, so
            // their kernel perf is covered by the femnist rows (announced
            // here, never a silent coverage drop)
            println!("(skipping {}: bench inputs are femnist-shaped)", cfg.variant_key());
            continue;
        }
        if small_shape() && cfg.preset == "stress" {
            println!("(FEDLITE_BENCH_SMALL=1: skipping the stress variant — its \
                      expected_cases rows will be absent from this run)");
            continue;
        }
        let key = cfg.variant_key();
        let inputs = build_inputs(cfg);
        let mut policies = vec![
            ("naive", GemmPolicy::naive()),
            ("tiled", GemmPolicy::tiled()),
        ];
        if auto > 1 {
            policies.push(("tiled+parallel", GemmPolicy::parallel(auto)));
        } else {
            println!("(single core: skipping the tiled+parallel cases for {key})");
        }

        for (artifact, ins) in [
            ("client_fwd", &inputs.fwd),
            ("server_step", &inputs.step),
            ("client_bwd", &inputs.bwd),
            ("full_grad", &inputs.full),
            ("full_eval", &inputs.eval),
        ] {
            // exactness sanity before any timing: every timed policy must
            // produce bit-identical outputs for this artifact (full_eval
            // is the only eval_batch-shaped case, so it gets its own check)
            let reference = NativeEngine::new().run(&key, artifact, ins).unwrap();
            for (label, p) in &policies {
                let outs = NativeEngine::with_policy(*p).run(&key, artifact, ins).unwrap();
                for (a, r) in outs.iter().zip(&reference) {
                    assert_eq!(
                        a.as_f32().unwrap(),
                        r.as_f32().unwrap(),
                        "{key} {artifact} differs under the {label} policy"
                    );
                }
            }
            let work = flops(cfg, artifact);
            for (label, p) in &policies {
                let engine = NativeEngine::with_policy(*p);
                let mut scratch = EngineScratch::new();
                b.case(&format!("{artifact} {key} {label}"), 1, reps, work, || {
                    std::hint::black_box(
                        engine.run_scratch(&key, artifact, ins, &mut scratch).unwrap(),
                    );
                });
            }
        }
    }

    b.finish_to(Some("BENCH_engine.json"));
}
