//! Bench: regenerate the cost numbers behind Table 1 and the compression
//! sweeps behind Figures 3/5c, measuring the analytic model's agreement
//! with the byte-exact wire encoder across the whole (q, R, L) grid.
//!
//! Output: `results/bench/tables.{csv,json}` plus the repo-root
//! trajectory file `BENCH_tables.json`, whose `expected_cases` list is
//! the suite's coverage contract (checked by `bench_compare.py` in CI).

use fedlite::comm::message::Message;
use fedlite::models::analytics::{self, TaskCosts};
use fedlite::quantizer::cost::CostModel;
use fedlite::quantizer::pq::{GroupedPq, PqConfig};
use fedlite::util::bench::Bench;
use fedlite::util::rng::Rng;

fn main() {
    let mut b = Bench::new("tables");

    // Table 1 analytic rows for all three tasks (cheap; timing the model
    // itself is trivial — the value is the printed reproduction)
    for (task, costs) in [
        ("femnist", analytics::femnist_costs()),
        ("so_tag", analytics::so_tag_costs()),
        ("so_nwp", analytics::so_nwp_costs()),
    ] {
        let rows = analytics::table1(&costs, 4, Some((1152.min(costs.d), 1, 2)));
        println!("table1[{task}]:");
        for r in &rows {
            println!(
                "  {:<22} {:<10} comm={:>14.1}",
                r.algorithm, r.batch, r.communication
            );
        }
        let _ = rows;
    }

    // model-vs-wire agreement across the fig3 grid (this is the check that
    // the paper's formula and our bytes never drift)
    let cm32 = CostModel::new(32);
    let (batch, d) = (20usize, 9216usize);
    let mut rng = Rng::new(1);
    let z: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let mut worst: f64 = 0.0;
    b.case("fig3 grid: quantize+encode (18 configs)", 0, 1, 0.0, || {
        for (q, r) in [(1usize, 1usize), (288, 288), (288, 1), (1152, 1152), (1152, 1),
                       (4608, 4608), (4608, 1152), (4608, 384), (4608, 1)] {
            for l in [2usize, 8] {
                let pq = GroupedPq::new(PqConfig::new(q, r, l).with_iters(1), d).unwrap();
                let mut qr = Rng::new(3);
                let out = pq.quantize(&z, batch, &mut qr);
                let msg = Message::from_pq(&out.config, batch, d, &out.codebooks, &out.codes);
                let wire_bits = (msg.wire_len() * 8) as f64;
                let model_bits = cm32.fedlite_bits(batch, d, q, r, l);
                let rel = (wire_bits - model_bits).abs() / model_bits;
                worst = worst.max(rel);
            }
        }
    });
    println!("worst wire-vs-model relative gap: {:.3} (headers + bit padding)", worst);
    assert!(worst < 0.35, "wire format drifted from the paper model");
    let costs_check: TaskCosts = analytics::femnist_costs();
    assert_eq!(costs_check.wc, 18_816);
    b.finish_to(Some("BENCH_tables.json"));
}
